"""Seeded scheduler fuzz: continuous-batching engines vs a one-request-at-a-
time reference.

Each schedule draws random arrival ticks, prompt lengths, max_tokens, and
eos placement, then drives the ring-cache :class:`Engine` and the paged
:class:`PagedEngine` (random block size, pool size — sometimes tight enough
to force preemption — prefill batch/chunk) through tick-by-tick arrivals.
Every request's greedy output must be **token-identical** to generating it
alone via prefill + decode_step.

``test_serve_fuzz_smoke`` is the 2-schedule subset CI re-runs under
``REPRO_KERNEL_BACKEND=pallas-interpret`` (the interpreter is too slow for
the full sweep there).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import get_model
from repro.serve.engine import Engine, PagedEngine
from repro.serve.kv_cache import blocks_for

MAX_LEN = 96
N_SCHEDULES = 22  # acceptance: >= 20 seeded schedules


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("tinyllama-1.1b", reduced=True).replace(
        compute_dtype="float32", param_dtype="float32")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ref_cache = {}

    def reference(prompt):
        """Greedy reference continuation (no eos/max cut — callers truncate,
        valid because greedy decoding is prefix-deterministic)."""
        key = tuple(prompt)
        if key not in ref_cache:
            toks = jnp.asarray([prompt], jnp.int32)
            logits, cache = model.prefill(params, {"tokens": toks},
                                          cache_dtype=jnp.float32,
                                          max_len=MAX_LEN)
            out = [int(jnp.argmax(logits[0]))]
            pos = len(prompt)
            for _ in range(_MAX_NEW - 1):
                logits, cache = model.decode_step(
                    params, cache, {"tokens": jnp.asarray([[out[-1]]], jnp.int32)},
                    jnp.int32(pos))
                out.append(int(jnp.argmax(logits[0])))
                pos += 1
            ref_cache[key] = out
        return ref_cache[key]

    return model, params, reference


_MAX_NEW = 6


def _schedule(seed):
    """(arrival_tick, prompt, max_tokens, eos) list drawn from ``seed``."""
    rng = np.random.default_rng(1000 + seed)
    n_req = int(rng.integers(3, 6))
    reqs = []
    for _ in range(n_req):
        plen = int(rng.integers(1, 11))
        prompt = [int(t) for t in rng.integers(0, 256, plen)]
        max_tokens = int(rng.integers(1, _MAX_NEW + 1))
        arrival = int(rng.integers(0, 5))
        reqs.append([arrival, prompt, max_tokens, None])
    reqs.sort(key=lambda r: r[0])
    return rng, reqs


def _expected(reference, prompt, max_tokens, eos):
    out = reference(prompt)[:max_tokens]
    if eos is not None and eos in out:
        out = out[:out.index(eos) + 1]
    return out


def _drive(engine, sched):
    """Submit per-arrival-tick, stepping the engine between arrivals."""
    handles = []
    t = 0
    pending = list(sched)
    while pending or engine.pending():
        while pending and pending[0][0] <= t:
            _, prompt, max_tokens, eos = pending.pop(0)
            handles.append(engine.submit(prompt, max_tokens=max_tokens, eos=eos))
        engine.tick()
        t += 1
        assert t < 2000, "scheduler stalled"
    return handles


def _run_schedule(model, params, reference, seed, *, paged_only=False):
    rng, sched = _schedule(seed)
    # give some requests an eos drawn from their own greedy continuation so
    # the eos path actually triggers (a random token id almost never would)
    for r in sched:
        if rng.random() < 0.4:
            cont = reference(r[1])
            r[3] = cont[int(rng.integers(0, len(cont)))]
    expected = [_expected(reference, p, m, e) for _, p, m, e in sched]

    engines = []
    if not paged_only:
        engines.append(Engine(model, params, slots=int(rng.integers(1, 4)),
                              max_len=MAX_LEN))
    block_size = int(rng.choice([4, 8, 16]))
    max_seq = max(len(p) for _, p, _, _ in sched) + _MAX_NEW + 1
    min_blocks = blocks_for(max_seq, block_size)
    # pool between "one sequence + spare" (forces preemption under load) and
    # roomy full occupancy
    slots = int(rng.integers(1, 4))
    roomy = 1 + slots * blocks_for(MAX_LEN, block_size)
    num_blocks = int(rng.integers(min_blocks + 2, max(min_blocks + 3, roomy)))
    engines.append(PagedEngine(
        model, params, slots=slots, max_len=MAX_LEN, block_size=block_size,
        num_blocks=num_blocks, prefill_batch=int(rng.integers(1, 3)),
        prefill_chunk=int(rng.choice([4, 8, 16]))))

    for eng in engines:
        handles = _drive(eng, sched)
        got = [h.out_tokens for h in handles]
        assert got == expected, (
            f"seed {seed} {type(eng).__name__}: {got} != {expected}")
        if isinstance(eng, PagedEngine):
            # all blocks returned once the schedule drains
            assert eng.kv.num_free == eng.kv.num_blocks - 1
            assert eng.kv.manager.live_tokens() == 0


@pytest.mark.parametrize("seed", range(N_SCHEDULES))
def test_serve_fuzz_schedules(seed, setup):
    model, params, reference = setup
    _run_schedule(model, params, reference, seed)


def test_serve_fuzz_smoke(setup):
    """Tiny subset for the CI pallas-interpret smoke step."""
    model, params, reference = setup
    for seed in (100, 101):
        _run_schedule(model, params, reference, seed, paged_only=True)

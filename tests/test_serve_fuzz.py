"""Seeded scheduler fuzz: the unified session engine vs a one-request-at-a-
time reference, across **all five families**.

Each schedule draws random arrival ticks, prompt lengths, max_tokens, and
eos placement, then drives :class:`repro.serve.engine.Engine` through
tick-by-tick arrivals.  Every request's greedy output must be
**token-identical** to generating it alone via ``model.prefill`` +
``model.decode_step``.  Per family this exercises a different state backend
(DESIGN.md §7):

* dense (tinyllama)      — paged block pools *and* per-slot rings
* moe (kimi-k2)          — paged block pools (random tight pools force
                           preemption + recompute re-admission)
* griffin (recurrentgemma) — recurrent state + windowed attention rings
* rwkv (rwkv6)           — pure recurrent state
* encdec (whisper)       — per-request encoder context + paged self-attention

``test_serve_smoke_matrix`` is the 1-schedule-per-family subset CI re-runs
under ``REPRO_KERNEL_BACKEND=pallas-interpret`` (the interpreter is too slow
for the full sweep there).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve.engine import Engine
from repro.serve.kv_cache import blocks_for

MAX_LEN = 96
_MAX_NEW = 6
N_SCHEDULES = 22  # acceptance: >= 20 seeded schedules for the dense family

FAMILY_ARCHS = {
    "dense": "tinyllama-1.1b",
    "moe": "kimi-k2-1t-a32b",
    "griffin": "recurrentgemma-2b",
    "rwkv": "rwkv6-7b",
    "encdec": "whisper-base",
}

_SETUPS: dict = {}


def _frames_for(cfg, prompt):
    """Deterministic per-request encoder frames (enc-dec only)."""
    rng = np.random.default_rng([97, len(prompt)] + list(prompt))
    return rng.standard_normal((cfg.enc_len, cfg.d_model)).astype(np.float32)


def _setup(family):
    """(model, params, reference) per family, cached for the module."""
    if family not in _SETUPS:
        cfg = get_config(FAMILY_ARCHS[family], reduced=True).replace(
            compute_dtype="float32", param_dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        ref_cache = {}

        def reference(prompt):
            """Greedy reference continuation (no eos/max cut — callers
            truncate, valid because greedy decoding is prefix-deterministic)."""
            key = tuple(prompt)
            if key not in ref_cache:
                batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
                if family == "encdec":
                    batch["enc_frames"] = jnp.asarray(_frames_for(cfg, prompt))[None]
                logits, cache = model.prefill(params, batch,
                                              cache_dtype=jnp.float32,
                                              max_len=MAX_LEN)
                out = [int(jnp.argmax(logits[0]))]
                pos = len(prompt)
                for _ in range(_MAX_NEW - 1):
                    logits, cache = model.decode_step(
                        params, cache,
                        {"tokens": jnp.asarray([[out[-1]]], jnp.int32)},
                        jnp.int32(pos))
                    out.append(int(jnp.argmax(logits[0])))
                    pos += 1
                ref_cache[key] = out
            return ref_cache[key]

        _SETUPS[family] = (model, params, reference)
    return _SETUPS[family]


def _schedule(seed):
    """(arrival_tick, prompt, max_tokens, eos) list drawn from ``seed``."""
    rng = np.random.default_rng(1000 + seed)
    n_req = int(rng.integers(3, 6))
    reqs = []
    for _ in range(n_req):
        plen = int(rng.integers(1, 11))
        prompt = [int(t) for t in rng.integers(0, 256, plen)]
        max_tokens = int(rng.integers(1, _MAX_NEW + 1))
        arrival = int(rng.integers(0, 5))
        reqs.append([arrival, prompt, max_tokens, None])
    reqs.sort(key=lambda r: r[0])
    return rng, reqs


def _expected(reference, prompt, max_tokens, eos):
    out = reference(prompt)[:max_tokens]
    if eos is not None and eos in out:
        out = out[:out.index(eos) + 1]
    return out


def _drive(engine, sched, cfg, family):
    """Submit per-arrival-tick, stepping the engine between arrivals."""
    handles = []
    t = 0
    pending = list(sched)
    while pending or engine.pending():
        while pending and pending[0][0] <= t:
            _, prompt, max_tokens, eos = pending.pop(0)
            frames = _frames_for(cfg, prompt) if family == "encdec" else None
            handles.append(engine.submit(prompt, max_tokens=max_tokens,
                                         eos=eos, enc_frames=frames))
        engine.tick()
        t += 1
        assert t < 2000, "scheduler stalled"
    return handles


def _run_schedule(family, seed, *, backends=None, chunks=(4, 8, 16)):
    model, params, reference = _setup(family)
    cfg = model.cfg
    rng, sched = _schedule(seed)
    # give some requests an eos drawn from their own greedy continuation so
    # the eos path actually triggers (a random token id almost never would)
    for r in sched:
        if rng.random() < 0.4:
            cont = reference(r[1])
            r[3] = cont[int(rng.integers(0, len(cont)))]
    expected = [_expected(reference, p, m, e) for _, p, m, e in sched]

    slots = int(rng.integers(1, 4))
    block_size = int(rng.choice([4, 8, 16]))
    max_seq = max(len(p) for _, p, _, _ in sched) + _MAX_NEW + 1
    min_blocks = blocks_for(max_seq, block_size)
    # pool between "one sequence + spare" (forces preemption under load) and
    # roomy full occupancy
    roomy = 1 + slots * blocks_for(MAX_LEN, block_size)
    num_blocks = int(rng.integers(min_blocks + 2, max(min_blocks + 3, roomy)))
    kw = dict(slots=slots, max_len=MAX_LEN, block_size=block_size,
              num_blocks=num_blocks, prefill_batch=int(rng.integers(1, 3)),
              prefill_chunk=int(rng.choice(chunks)))
    for backend in (backends or (None,)):
        eng = Engine(model, params, backend=backend, **kw)
        handles = _drive(eng, sched, cfg, family)
        got = [h.out_tokens for h in handles]
        assert got == expected, (
            f"{family} seed {seed} backend {eng.session.backend}: "
            f"{got} != {expected}")
        if eng.manager is not None:
            # all blocks returned once the schedule drains
            assert eng.manager.num_free == eng.manager.num_blocks - 1
            assert eng.manager.live_tokens() == 0


@pytest.mark.parametrize("seed", range(N_SCHEDULES))
def test_serve_fuzz_dense(seed):
    # both dense backends: paged block pools and per-slot rings
    _run_schedule("dense", seed,
                  backends=("paged",) if seed % 2 else ("paged", "ring"))


@pytest.mark.parametrize("family,seed", [
    (f, s)
    for f, n in (("moe", 3), ("griffin", 5), ("rwkv", 5), ("encdec", 3))
    for s in range(n)
])
def test_serve_fuzz_families(family, seed):
    # fixed chunk width: raggedness is fuzzed via prompts/arrivals/slots;
    # the chunk-grid shape sweep already runs on the dense family above
    _run_schedule(family, 50 + seed, chunks=(8,))


def test_serve_smoke_matrix():
    """One schedule per family — the CI pallas-interpret smoke matrix."""
    for family in FAMILY_ARCHS:
        _run_schedule(family, 100, chunks=(8,))


@pytest.mark.parametrize("family,seed", [
    ("dense", 201),    # int8 per-slot rings (k/v + scale tables)
    ("griffin", 202),  # int8 conv tails + windowed rings, f32 RG-LRU carry
    ("griffin", 203),
    ("rwkv", 204),     # int8 wkv matrix state + scale tables
    ("rwkv", 205),
])
def test_serve_fuzz_int8_schedule_invariance(family, seed):
    """int8 ring/recurrent state is *scheduling-invariant*: a fuzzed
    multi-slot schedule emits exactly the tokens each request gets alone
    through a slots=1 int8 engine.  (int8 outputs are not bit-identical to
    the f32 reference — quantization legitimately moves logits — so the
    invariant under test is that co-scheduling, idle-row ride-alongs and
    chunk interleaving never perturb a request's quantized state: idle rows
    must preserve payload and scale bitwise.)"""
    model, params, _ = _setup(family)
    cfg = model.cfg
    _, sched = _schedule(seed)
    kw = dict(max_len=MAX_LEN, block_size=8, prefill_chunk=8,
              cache_dtype="int8",
              backend="ring" if family == "dense" else None)
    eng = Engine(model, params, slots=3, prefill_batch=2, **kw)
    got = [h.out_tokens for h in _drive(eng, sched, cfg, family)]
    solo = []
    for _, prompt, max_tokens, eos in sched:
        e1 = Engine(model, params, slots=1, prefill_batch=1, **kw)
        h, = _drive(e1, [[0, prompt, max_tokens, eos]], cfg, family)
        solo.append(h.out_tokens)
    assert got == solo, f"{family} seed {seed}: {got} != {solo}"

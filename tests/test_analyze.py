"""repro.analyze: rule-family fixtures, suppressions, baseline, CLI, bench.

Pure-AST tests — nothing here traces jax.  Each committed bad-snippet
fixture under ``tests/analyze_fixtures/`` must trip its rule family
(exit 1 through the CLI), the good/suppressed twins must not, and the live
repo tree must be clean under ``--strict`` — that last test is the same
gate CI runs ahead of pytest.
"""
from __future__ import annotations

import json
from pathlib import Path

import pytest

from repro.analyze import ALL_RULES, BY_FAMILY, analyze_paths
from repro.analyze import bench
from repro.analyze.__main__ import main as analyze_main
from repro.analyze.core import Finding, baselined

ROOT = Path(__file__).resolve().parents[1]
FIX = ROOT / "tests" / "analyze_fixtures"


def codes_of(path, rules=None):
    findings, _ = analyze_paths([path], ROOT, rules)
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# One test per rule family: the committed bad snippet must trip it
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fixture,expected", [
    ("bad_clock.py", {"CLK001"}),
    ("bad_host_sync.py", {"SYNC001"}),
    ("bad_jit_cache.py", {"JIT001"}),
    ("bad_jit_static.py", {"JIT002"}),
    ("bad_jit_module_state.py", {"JIT003"}),
    ("bad_pallas_grid.py", {"PAL001"}),
    ("bad_pallas_arity.py", {"PAL002"}),
    ("bad_pallas_effect.py", {"PAL003"}),
    ("bad_pallas_vmem.py", {"PAL004"}),
    ("bad_pallas_divis.py", {"PAL005"}),
    ("bad_trace.py", {"TRACE001", "TRACE002", "TRACE003"}),
    ("bad_deprecated.py", {"DEP001"}),
])
def test_bad_fixture_trips_rule(fixture, expected):
    got = codes_of(FIX / fixture)
    assert expected <= got, f"{fixture}: wanted {expected}, got {got}"


@pytest.mark.parametrize("fixture,expected", [
    ("bad_clock.py", 1),
    ("bad_host_sync.py", 1),
    ("good_host_sync.py", 0),
])
def test_cli_exit_codes(fixture, expected, capsys):
    rc = analyze_main([str(FIX / fixture), "--root", str(ROOT)])
    assert rc == expected, capsys.readouterr().out


def test_good_fixture_is_clean():
    assert codes_of(FIX / "good_host_sync.py") == set()


def test_inline_allow_suppresses_and_is_counted():
    findings, suppressed = analyze_paths([FIX / "suppressed_sync.py"], ROOT)
    assert not findings
    assert {f.rule for f in suppressed} == {"SYNC001"}


def test_bad_dispatch_tree_flags_every_missing_leg():
    tree = FIX / "bad_dispatch_tree"
    findings, _ = analyze_paths(
        [tree / "src"], tree, [BY_FAMILY["dispatch-registry"]])
    got = {f.rule for f in findings}
    assert {"DISP001", "DISP002", "DISP003", "DISP004", "DISP005",
            "DISP006", "DISP007", "DISP008"} <= got, got


def test_at_least_six_rule_families():
    assert len(ALL_RULES) >= 6
    for mod in ALL_RULES:
        assert mod.FAMILY and mod.CODES and callable(mod.check)


def test_findings_carry_location_and_hint():
    findings, _ = analyze_paths([FIX / "bad_clock.py"], ROOT)
    f = findings[0]
    assert f.path.endswith("bad_clock.py") and f.line > 0
    assert "perf_counter" in f.hint
    rendered = f.render()
    assert f"{f.path}:{f.line}" in rendered and f.rule in rendered


# ---------------------------------------------------------------------------
# Baseline
# ---------------------------------------------------------------------------
def test_baseline_grandfathers_by_rule_and_path(tmp_path):
    bl = tmp_path / "baseline.json"
    bl.write_text(json.dumps({"findings": [
        {"rule": "wall-clock", "path": "tests/analyze_fixtures/bad_clock.py"},
    ]}))
    rc = analyze_main([str(FIX / "bad_clock.py"), "--root", str(ROOT),
                       "--baseline", str(bl)])
    assert rc == 0


def test_strict_fails_on_stale_baseline_entry(tmp_path):
    bl = tmp_path / "baseline.json"
    bl.write_text(json.dumps({"findings": [
        {"rule": "CLK001", "path": "no/such/file.py"},
    ]}))
    rc = analyze_main([str(FIX / "good_host_sync.py"), "--root", str(ROOT),
                       "--baseline", str(bl), "--strict"])
    assert rc == 1


def test_baselined_matching_semantics():
    f = Finding("CLK001", "wall-clock", "src/a/b.py", 3, 0, "time.time() x")
    assert baselined(f, [{"rule": "*", "path": "src/**"}])
    assert baselined(f, [{"rule": "wall-clock", "path": "src/a/*.py"}])
    assert baselined(f, [{"rule": "CLK001", "path": "src/a/b.py",
                          "message": "time.time()"}])
    assert not baselined(f, [{"rule": "CLK001", "path": "tests/*"}])
    assert not baselined(f, [{"rule": "SYNC001", "path": "src/a/b.py"}])


# ---------------------------------------------------------------------------
# The CI gates: live tree clean under --strict; BENCH reports valid
# ---------------------------------------------------------------------------
def test_live_tree_clean_under_strict(capsys):
    rc = analyze_main(["--strict", "--root", str(ROOT)])
    out = capsys.readouterr().out
    assert rc == 0, f"live tree has findings:\n{out}"
    assert "0 finding(s)" in out


def test_bench_reports_all_valid():
    errors = bench.check_all(ROOT, report=lambda *_: None)
    assert errors == []


def test_bench_checker_catches_breakage():
    rec = json.loads((ROOT / "BENCH_kernels.json").read_text())
    rec.pop("mode")
    rec["rows"][0]["max_rel_err"] = 0.5
    del rec["rows"][1]["kind"]
    errors = bench.check_report("kernels", rec)
    assert any("missing top-level key 'mode'" in e for e in errors)
    assert any("max_rel_err" in e for e in errors)
    assert any("missing field 'kind'" in e for e in errors)


def test_bench_cli_exit_code():
    assert analyze_main(["--bench", "--root", str(ROOT)]) == 0


def test_bench_missing_file_is_an_error(tmp_path):
    errs = bench.check_file("kernels", tmp_path / "BENCH_kernels.json")
    assert errs and "does not exist" in errs[0]

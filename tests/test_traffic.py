"""repro.traffic: workload determinism, replay integration, shared schema.

Workload expansion must be bit-deterministic by seed (the BENCH rows embed
the spec, so a row is re-runnable), arrival processes must have their
declared shape, and the runner's outcome accounting must agree with the obs
registry — the goodput and cancel numbers in ``BENCH_traffic.json`` are only
trustworthy if the two bookkeeping paths cannot drift.
"""
import numpy as np
import pytest
import test_serve_fuzz as fuzz

from repro.obs import Observer
from repro.serve import AsyncEngine
from repro.serve.engine import Engine, Request
from repro.traffic import (
    WorkloadSpec,
    check_traffic_schema,
    drive,
    goodput_tok_per_s,
    make_workload,
    outcome_of,
    pct_row,
    registry_summary,
    traffic_row,
)


# ---------------------------------------------------------------------------
# Workload expansion
# ---------------------------------------------------------------------------
def test_workload_deterministic_by_seed():
    spec = WorkloadSpec(n_requests=20, cancel_prob=0.3, ttft_slo_s=0.2,
                        deadline_s=5.0, seed=42)
    a, b = make_workload(spec), make_workload(spec)
    assert [(r.t_arrival, r.prompt, r.max_tokens, r.cancel_after_s)
            for r in a] == \
           [(r.t_arrival, r.prompt, r.max_tokens, r.cancel_after_s)
            for r in b]
    c = make_workload(WorkloadSpec(n_requests=20, cancel_prob=0.3,
                                   ttft_slo_s=0.2, deadline_s=5.0, seed=43))
    assert [r.t_arrival for r in a] != [r.t_arrival for r in c]
    # fields ride through; arrivals are sorted; lengths come from the buckets
    for i, r in enumerate(a):
        assert r.idx == i
        assert r.ttft_slo_s == 0.2 and r.deadline_s == 5.0
        assert len(r.prompt) in spec.prompt_len_buckets
        assert r.max_tokens in spec.out_tokens_buckets
        assert all(1 <= t < spec.vocab for t in r.prompt)
    assert [r.t_arrival for r in a] == sorted(r.t_arrival for r in a)
    assert any(r.cancel_after_s is not None for r in a)


def test_workload_bursty_arrivals_grouped():
    spec = WorkloadSpec(n_requests=10, arrival="bursty", burst_size=4, seed=1)
    reqs = make_workload(spec)
    times = [r.t_arrival for r in reqs]
    # bursts of burst_size share one arrival instant (last burst may be short)
    assert times[0] == times[1] == times[2] == times[3]
    assert times[4] == times[5] == times[6] == times[7]
    assert times[8] == times[9]
    assert times[3] < times[4] < times[8]


def test_workload_validation():
    for bad in (dict(n_requests=0), dict(arrival="uniform"),
                dict(rate_rps=0.0), dict(arrival="bursty", burst_size=0),
                dict(prompt_len_weights=(1.0,)),  # length mismatch
                dict(out_tokens_buckets=(0, 4)),
                dict(prompt_len_weights=(0.0, 0.0, 0.0)),
                dict(vocab=1), dict(cancel_prob=1.5),
                dict(cancel_window_s=(0.5, 0.1)), dict(ttft_slo_s=0.0),
                dict(deadline_s=-1.0)):
        with pytest.raises(ValueError):
            make_workload(WorkloadSpec(**bad))
    # to_dict round-trips through the constructor (BENCH rows re-runnable)
    spec = WorkloadSpec(arrival="bursty", cancel_prob=0.2, seed=9)
    d = spec.to_dict()
    d["prompt_len_buckets"] = tuple(d["prompt_len_buckets"])
    d["prompt_len_weights"] = tuple(d["prompt_len_weights"])
    d["out_tokens_buckets"] = tuple(d["out_tokens_buckets"])
    d["out_tokens_weights"] = tuple(d["out_tokens_weights"])
    d["cancel_window_s"] = tuple(d["cancel_window_s"])
    assert make_workload(WorkloadSpec(**d)) == make_workload(spec)


# ---------------------------------------------------------------------------
# Report helpers (the schema BENCH_serve and BENCH_traffic share)
# ---------------------------------------------------------------------------
def test_pct_row_none_safe():
    assert pct_row(None) == {"count": 0, "mean": None, "p50": None,
                             "p95": None, "p99": None}
    from repro.obs import Histogram
    h = Histogram(boundaries=[1.0, 2.0])
    assert pct_row(h)["count"] == 0 and pct_row(h)["p99"] is None
    h.observe(0.5)
    row = pct_row(h)
    assert row["count"] == 1 and row["p50"] == 0.5 and row["mean"] == 0.5


def test_outcome_and_goodput_accounting():
    def req(n_out, *, t_first, t_done, cancelled=False, reason="max_tokens"):
        r = Request(rid=0, prompt=[1], max_tokens=8, t_submit=10.0)
        r.out_tokens = list(range(n_out))
        r.done = True
        r.cancelled = cancelled
        r.finish_reason = reason
        r.t_first, r.t_done = t_first, t_done
        return r

    fast = outcome_of(req(8, t_first=10.1, t_done=10.5), ttft_slo_s=0.2)
    slow = outcome_of(req(8, t_first=10.4, t_done=10.9), ttft_slo_s=0.2)
    gone = outcome_of(req(3, t_first=10.1, t_done=10.2, cancelled=True,
                          reason="user"), ttft_slo_s=0.2)
    assert fast.slo_attained and fast.completed
    assert fast.ttft_s == pytest.approx(0.1)
    assert not slow.slo_attained and slow.completed  # finished but late
    assert not gone.slo_attained and not gone.completed
    # goodput counts only SLO-attained tokens; throughput counts them all
    assert goodput_tok_per_s([fast, slow, gone], 2.0) == pytest.approx(4.0)
    # no SLO: every completed request attains
    assert outcome_of(req(8, t_first=10.4, t_done=10.9)).slo_attained
    with pytest.raises(ValueError):
        goodput_tok_per_s([fast], 0.0)


def test_registry_summary_absent_metrics():
    from repro.obs import MetricsRegistry
    s = registry_summary(MetricsRegistry())
    assert s["tokens"] == 0 and s["cancels"] == 0 and s["preempts"] == 0
    assert s["ttft_s"]["count"] == 0 and s["inter_token_s"]["p99"] is None


# ---------------------------------------------------------------------------
# Replay integration: runner outcomes must agree with the obs registry
# ---------------------------------------------------------------------------
def test_traffic_replay_smoke():
    model, params, _ = fuzz._setup("dense")
    spec = WorkloadSpec(
        n_requests=8, arrival="poisson", rate_rps=200.0,
        prompt_len_buckets=(3, 8), prompt_len_weights=(0.6, 0.4),
        out_tokens_buckets=(3, 10), out_tokens_weights=(0.5, 0.5),
        vocab=model.cfg.vocab_size, ttft_slo_s=0.5, cancel_prob=0.4,
        cancel_window_s=(0.001, 0.01), seed=5)
    requests = make_workload(spec)
    obs = Observer()
    frontend = AsyncEngine(engine=Engine(model, params, slots=2, max_len=96,
                                         block_size=8, prefill_chunk=8,
                                         obs=obs))
    result = drive(frontend, requests, time_scale=1.0)
    outs = result.outcomes
    assert len(outs) == 8 and result.wall_s > 0
    n_completed = sum(o.completed for o in outs)
    n_cancelled = sum(o.finish_reason == "user" for o in outs)
    assert n_completed + n_cancelled == 8  # no deadlines in this spec
    # the two bookkeeping paths agree: registry vs outcome accounting
    reg = obs.registry
    assert reg.get("serve_tokens_total").value == \
        sum(o.n_tokens for o in outs)
    cancels = reg.get("serve_cancellations_total")
    assert (cancels.value if cancels else 0) == n_cancelled
    row = traffic_row(result=result, registry=reg, family="dense",
                      arch="tinyllama-1.1b", scenario="poisson",
                      workload=spec.to_dict())
    assert row["goodput_tok_per_s"] <= row["tok_per_s"] + 1e-9
    assert row["ttft_s"]["count"] > 0
    assert row["n_completed"] == n_completed


def test_time_scale_stretches_schedule():
    model, params, _ = fuzz._setup("dense")
    spec = WorkloadSpec(n_requests=3, rate_rps=50.0, vocab=64,
                        prompt_len_buckets=(3,), prompt_len_weights=(1.0,),
                        out_tokens_buckets=(3,), out_tokens_weights=(1.0,),
                        seed=2)
    requests = make_workload(spec)
    frontend = AsyncEngine(model, params, slots=2, max_len=96,
                           prefill_chunk=8)
    result = drive(frontend, requests, time_scale=4.0)
    # the last arrival alone bounds the wall clock from below
    assert result.wall_s >= requests[-1].t_arrival * 4.0
    assert all(o.completed for o in result.outcomes)
    with pytest.raises(ValueError):
        drive(frontend, requests, time_scale=0.0)


def test_check_traffic_schema_rejects_malformed():
    with pytest.raises(AssertionError):
        check_traffic_schema({"rows": []})
    ok_pct = {"count": 1, "mean": 0.1, "p50": 0.1, "p95": 0.1, "p99": 0.1}
    rows = [{"family": f, "arch": "a", "scenario": s, "workload": {},
             "n_requests": 1, "n_completed": 1, "n_cancelled": 0,
             "n_deadline_missed": 0, "n_slo_attained": 1, "wall_s": 1.0,
             "time_scale": 1.0, "tok_per_s": 5.0, "goodput_tok_per_s": 5.0,
             "ttft_s": dict(ok_pct), "inter_token_s": dict(ok_pct),
             "queue_s": dict(ok_pct), "tokens": 5, "decode_ticks": 5,
             "preempts": 0, "cancels": 0, "deadline_misses": 0}
            for f in ("a", "b", "c") for s in ("poisson", "bursty")]
    rec = {"scenarios": {}, "note": "", "rows": rows}
    check_traffic_schema(rec)  # well-formed passes
    bad = {**rec, "rows": [dict(r, goodput_tok_per_s=99.0) for r in rows]}
    with pytest.raises(AssertionError, match="goodput"):
        check_traffic_schema(bad)
    bad = {**rec, "rows": [dict(r, cancels=3) for r in rows]}
    with pytest.raises(AssertionError, match="cancel"):
        check_traffic_schema(bad)

"""Rank/factorization auto-search."""
import numpy as np
import pytest

from repro.core.ranksearch import RankChoice, search_spec, spec_for_layer, tt_error


def test_target_cr():
    c = search_spec(4096, 4096, target_cr=100.0)
    assert c.cr >= 100.0
    assert c.spec.n_in == 4096 and c.spec.n_out == 4096


def test_error_budget_semantics():
    w = np.random.randn(64, 128)
    c = search_spec(128, 64, max_error=0.95, weight=w, ranks=(2, 4, 8))
    # budget satisfiable at 0.95 for random matrices -> returned spec honors it
    assert c.rel_error is not None and c.rel_error <= 0.95
    # and it is the max-CR spec among those that honor it
    c_lower = search_spec(128, 64, max_error=0.5, weight=w, ranks=(2, 4, 8))
    if c_lower.rel_error <= 0.5:  # if satisfiable, tighter budget can't raise CR
        assert c.cr >= c_lower.cr


def test_paper_default_d4_r16():
    c = search_spec(4096, 11008)
    assert c.spec.d == 4 and max(c.spec.ranks) == 16


def test_error_decreases_with_rank():
    w = np.random.randn(64, 64)
    errs = [tt_error(w, spec_for_layer(64, 64, rank=r, d=3)) for r in (2, 8, 32)]
    assert errs[0] > errs[1] > errs[2]


def test_target_cr_tie_broken_by_lower_error():
    """512x512 with ds=(2,3), ranks=(4,8) has two candidates tied at
    CR 51.2 (d=2/r=4 and d=3/r=8); the docstring promises the tie resolves
    to the lower reconstruction error when a weight is supplied."""
    from repro.core.ttd import TTSpec

    w = np.random.default_rng(7).standard_normal((512, 512))
    c = search_spec(512, 512, target_cr=30.0, weight=w, ds=(2, 3), ranks=(4, 8))
    tie_errs = []
    for d in (2, 3):
        for r in (4, 8):
            sp = TTSpec.make(512, 512, r, d=d)
            if abs(sp.compression_ratio() - c.cr) < 1e-9:
                tie_errs.append(tt_error(w, sp))
    assert len(tie_errs) >= 2, "expected a genuine CR tie in this sweep"
    assert c.rel_error == pytest.approx(min(tie_errs))

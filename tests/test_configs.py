"""Full-size configs: dims match the assignment; param counts sane."""
import math

import jax
import pytest

from repro.config import QuantConfig, TTDConfig
from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, get_config
from repro.models import build_model

EXPECTED_PARAMS_B = {  # dense (uncompressed) totals, ±12%
    "tinyllama-1.1b": 1.1,
    "phi4-mini-3.8b": 3.84,
    "llama2-7b": 6.74,
    "chatglm3-6b": 6.24,
    "granite-3-8b": 8.4,
    "qwen2-vl-7b": 7.6,
    "rwkv6-7b": 7.5,
    "recurrentgemma-2b": 2.9,
    "qwen1.5-110b": 111.0,
    "mixtral-8x22b": 140.0,
    "kimi-k2-1t-a32b": 1041.0,
    "whisper-base": 0.08,
}


def _dense(cfg):
    return cfg.replace(ttd=TTDConfig(enabled=False), quant=QuantConfig(enabled=False))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_counts(arch):
    cfg = _dense(get_config(arch))
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    n = sum(math.prod(x.shape) for x in jax.tree.leaves(shapes))
    expect = EXPECTED_PARAMS_B[arch] * 1e9
    assert abs(n - expect) / expect < 0.12, f"{arch}: {n/1e9:.2f}B vs {expect/1e9:.2f}B"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_ttd_enabled_by_default(arch):
    cfg = get_config(arch)
    assert cfg.ttd.enabled  # the paper's technique is first-class everywhere


def test_assigned_arch_list():
    assert len(ASSIGNED_ARCHS) == 10
    assert "chatglm3-6b" in ALL_ARCHS and "llama2-7b" in ALL_ARCHS


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_configs_are_small(arch):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    n = sum(math.prod(x.shape) for x in jax.tree.leaves(shapes))
    assert n < 2_000_000, f"{arch} reduced too big: {n}"

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.losses import chunked_cross_entropy


def test_chunked_equals_direct(key):
    b, s, d, v = 2, 32, 16, 50
    hidden = jax.random.normal(key, (b, s, d))
    head = jax.random.normal(jax.random.PRNGKey(1), (d, v))
    targets = jax.random.randint(key, (b, s), 0, v)
    mask = (jax.random.uniform(jax.random.PRNGKey(2), (b, s)) > 0.3).astype(jnp.float32)

    loss_c, m = chunked_cross_entropy(hidden, head, targets, mask, chunk=8)
    # direct
    logits = (hidden.astype(jnp.bfloat16) @ head.astype(jnp.bfloat16)).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, targets[..., None], -1)[..., 0]
    ref = jnp.sum((lse - gold) * mask) / jnp.sum(mask)
    # bf16 logits: chunked vs direct differ by summation order only
    np.testing.assert_allclose(float(loss_c), float(ref), rtol=1e-3)
    assert abs(float(m["tokens"]) - float(mask.sum())) < 1e-6


def test_odd_seq_fallback(key):
    hidden = jax.random.normal(key, (1, 7, 8))
    head = jax.random.normal(key, (8, 11))
    targets = jnp.zeros((1, 7), jnp.int32)
    mask = jnp.ones((1, 7))
    loss, _ = chunked_cross_entropy(hidden, head, targets, mask, chunk=4)
    assert np.isfinite(float(loss))

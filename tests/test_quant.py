"""INT4 weight quantization (paper w4a16)."""
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: skip only the property-based tests
    from conftest import given, settings, st  # noqa: F401

from repro.core import dequantize_int4, fake_quant_int4, pack_int4, quantize_int4, unpack_int4


def test_pack_unpack_roundtrip():
    q = np.random.randint(-8, 8, size=(5, 64)).astype(np.int8)
    out = np.asarray(unpack_int4(pack_int4(q)))
    np.testing.assert_array_equal(out, q)


@settings(max_examples=20, deadline=None)
@given(rows=st.integers(1, 9), groups=st.integers(1, 4), scale=st.floats(0.01, 100.0))
def test_property_quant_error_bound(rows, groups, scale):
    g = 32
    w = (np.random.randn(rows, groups * g) * scale).astype(np.float32)
    q = quantize_int4(w, group_size=g)
    wd = np.asarray(dequantize_int4(q, jnp.float32))
    # symmetric int4: |err| <= scale/2 + |q|*scale*2^-8 (bf16-stored scales)
    gmax = np.abs(w.reshape(rows, groups, g)).max(-1, keepdims=True)
    bound = gmax / 7 / 2 + gmax * 2.0 ** -8
    bound = np.broadcast_to(bound, w.reshape(rows, groups, g).shape).reshape(w.shape)
    assert np.all(np.abs(w - wd) <= bound * 1.01 + 1e-7)


def test_fake_quant_idempotent():
    w = np.random.randn(8, 128).astype(np.float32)
    w1 = np.asarray(fake_quant_int4(jnp.asarray(w)))
    w2 = np.asarray(fake_quant_int4(jnp.asarray(w1)))
    np.testing.assert_allclose(w1, w2, atol=1e-6)

"""Per-arch smoke tests (reduced configs) + serve-path consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.models import build_model
from repro.models.modules import unembed

FAMILIES = ["tinyllama-1.1b", "mixtral-8x22b", "kimi-k2-1t-a32b",
            "recurrentgemma-2b", "rwkv6-7b", "whisper-base",
            "chatglm3-6b", "qwen2-vl-7b"]


def _batch(cfg, key, b=2, s=16):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.pos_type == "mrope":
        batch["positions"] = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (3, b, s))
    if cfg.family == "encdec":
        batch["enc_frames"] = jax.random.normal(key, (b, cfg.enc_len, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward(arch, key):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = model.init(key)
    batch = _batch(cfg, key)
    hidden, aux = jax.jit(lambda p, b: model.forward(p, b))(params, batch)
    assert hidden.shape == (2, 16, cfg.d_model)
    assert bool(jnp.isfinite(hidden.astype(jnp.float32)).all())
    hw = model.head_weight(params)
    assert hw.shape == (cfg.d_model, cfg.vocab_size)


@pytest.mark.parametrize("arch", FAMILIES)
def test_decode_matches_forward(arch, key):
    cfg = get_config(arch, reduced=True).replace(compute_dtype="float32",
                                                 param_dtype="float32")
    model = build_model(cfg)
    params = model.init(key)
    T = 12
    batch = _batch(cfg, key, b=2, s=T)
    hidden, _ = model.forward(params, batch)
    full_logits = unembed(hidden[:, -1:], model.head_weight(params).T, jnp.float32)[:, 0]
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :T - 1]
    if cfg.pos_type == "mrope":
        pre["positions"] = batch["positions"][..., :T - 1]
    _, cache = model.prefill(params, pre, cache_dtype=jnp.float32, max_len=T + 4)
    dec = {"tokens": batch["tokens"][:, T - 1:T]}
    if cfg.pos_type == "mrope":
        dec["positions"] = batch["positions"][..., T - 1:T]
    logits, _ = model.decode_step(params, cache, dec, jnp.int32(T - 1))
    scale = float(jnp.max(jnp.abs(full_logits))) or 1.0
    assert float(jnp.max(jnp.abs(logits - full_logits))) < 1e-3 * max(scale, 1.0)


def test_sliding_window_prefill_beyond_window(key):
    """SWA ring cache: prefill longer than the window, then decode."""
    cfg = get_config("mixtral-8x22b", reduced=True).replace(
        compute_dtype="float32", param_dtype="float32", window=8)
    model = build_model(cfg)
    params = model.init(key)
    T = 24  # 3x window
    toks = jax.random.randint(key, (1, T), 0, cfg.vocab_size)
    hidden, _ = model.forward(params, {"tokens": toks})
    full_logits = unembed(hidden[:, -1:], model.head_weight(params).T, jnp.float32)[:, 0]
    _, cache = model.prefill(params, {"tokens": toks[:, :T - 1]},
                             cache_dtype=jnp.float32, max_len=T)
    logits, _ = model.decode_step(params, cache, {"tokens": toks[:, T - 1:]},
                                  jnp.int32(T - 1))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full_logits),
                               rtol=1e-3, atol=1e-3)


def test_multi_step_decode_chain(key):
    """Decode 6 tokens one-by-one == forward over the full sequence."""
    cfg = get_config("tinyllama-1.1b", reduced=True).replace(
        compute_dtype="float32", param_dtype="float32")
    model = build_model(cfg)
    params = model.init(key)
    toks = jax.random.randint(key, (1, 14), 0, cfg.vocab_size)
    _, cache = model.prefill(params, {"tokens": toks[:, :8]},
                             cache_dtype=jnp.float32, max_len=20)
    outs = []
    for t in range(8, 14):
        logits, cache = model.decode_step(params, cache, {"tokens": toks[:, t:t + 1]},
                                          jnp.int32(t))
        outs.append(logits)
    hidden, _ = model.forward(params, {"tokens": toks})
    ref = unembed(hidden[:, -1:], model.head_weight(params).T, jnp.float32)[:, 0]
    np.testing.assert_allclose(np.asarray(outs[-1]), np.asarray(ref), rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "rwkv6-7b", "recurrentgemma-2b",
                                  "mixtral-8x22b", "whisper-base"])
def test_train_step_smoke(arch, key):
    from repro.config import TrainConfig
    from repro.train.step import build_train_step, init_train_state
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    tc = TrainConfig(global_batch=2, seq_len=16, optimizer="adamw", remat="dots")
    state = init_train_state(model, tc, key)
    step = jax.jit(build_train_step(model, tc))
    batch = _batch(cfg, key)
    batch["targets"] = jnp.roll(batch["tokens"], -1, axis=1)
    batch["loss_mask"] = jnp.ones((2, 16), jnp.float32)
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_state.step) == 1
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in
                zip(jax.tree.leaves(state.params), jax.tree.leaves(new_state.params)))
    assert delta > 0

"""Async front-end: token identity under load, cancel/deadline semantics.

The core acceptance test fuzzes the asyncio front-end with seeded Poisson
arrivals and random mid-stream cancellations, with dispatch-ahead both on
and off: every request that *completes* must emit tokens bitwise-identical
to generating it alone through ``model.prefill`` + ``model.decode_step``
(the same reference the synchronous scheduler fuzz pins), and every
cancelled request must hold a strict greedy prefix.  The satellites pin the
submit-time validation, drained-engine reuse, deadline expiry, and that
dispatch-ahead actually engages (``stats["ahead_ticks"]``).

Tests drive the event loop with ``asyncio.run`` inside ordinary sync test
functions — no asyncio pytest plugin required.
"""
import asyncio

import jax.numpy as jnp
import numpy as np
import pytest
import test_serve_fuzz as fuzz

from repro.serve import AsyncEngine
from repro.serve.engine import Engine


def _ref(model, params, prompt, n, max_len=96):
    """Greedy one-request-at-a-time reference (any length)."""
    logits, cache = model.prefill(params,
                                  {"tokens": jnp.asarray([prompt], jnp.int32)},
                                  cache_dtype=jnp.float32, max_len=max_len)
    out = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    for _ in range(n - 1):
        logits, cache = model.decode_step(
            params, cache, {"tokens": jnp.asarray([[out[-1]]], jnp.int32)},
            jnp.int32(pos))
        out.append(int(jnp.argmax(logits[0])))
        pos += 1
    return out


async def _play(frontend, schedule):
    """Submit per-Poisson-gap with consumers and cancel timers attached."""
    handles, tasks = [], []

    async def consume(h):
        async for _ in h.stream():
            pass

    async def cancel_later(h, delay):
        try:
            await asyncio.wait_for(h.wait_done(), timeout=delay)
        except asyncio.TimeoutError:
            h.cancel()

    for gap, prompt, max_tokens, eos, cancel_after in schedule:
        await asyncio.sleep(gap)
        h = frontend.submit(prompt, max_tokens=max_tokens, eos=eos)
        handles.append(h)
        tasks.append(asyncio.create_task(consume(h)))
        if cancel_after is not None:
            tasks.append(asyncio.create_task(cancel_later(h, cancel_after)))
    await frontend.drain()
    await asyncio.gather(*tasks)
    return handles


def _fuzz_schedule(reference, seed):
    """Poisson gaps, mixed lengths, reference-drawn eos, random cancels."""
    rng = np.random.default_rng(3000 + seed)
    schedule = []
    for _ in range(int(rng.integers(4, 8))):
        prompt = [int(t) for t in rng.integers(0, 256, int(rng.integers(1, 11)))]
        max_tokens = int(rng.integers(1, 7))
        eos = None
        if rng.random() < 0.3:
            cont = reference(prompt)
            eos = cont[int(rng.integers(0, len(cont)))]
        cancel_after = (float(rng.uniform(0.001, 0.02))
                        if rng.random() < 0.35 else None)
        schedule.append((float(rng.exponential(0.004)), prompt, max_tokens,
                         eos, cancel_after))
    kw = dict(slots=int(rng.integers(1, 4)), max_len=96, block_size=8,
              num_blocks=int(rng.integers(5, 20)), prefill_batch=2,
              prefill_chunk=8)
    return schedule, kw


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("dispatch_ahead", [True, False])
def test_async_token_identity_fuzz(seed, dispatch_ahead):
    """Completed requests match the solo reference bitwise; cancelled ones
    hold a strict greedy prefix — under Poisson arrivals + random cancels,
    with and without dispatch-ahead double buffering."""
    model, params, reference = fuzz._setup("dense")
    schedule, kw = _fuzz_schedule(reference, seed)
    frontend = AsyncEngine(model, params, dispatch_ahead=dispatch_ahead, **kw)
    handles = asyncio.run(_play(frontend, schedule))
    for h, (_, prompt, max_tokens, eos, _) in zip(handles, schedule):
        expected = fuzz._expected(reference, prompt, max_tokens, eos)
        if h.cancelled:
            assert h.finish_reason == "user"
            assert len(h.out_tokens) < len(expected)
            assert h.out_tokens == expected[:len(h.out_tokens)], \
                f"seed {seed}: cancelled rid {h.rid} diverged from reference"
        else:
            assert h.done
            assert h.out_tokens == expected, \
                f"seed {seed}: rid {h.rid} {h.out_tokens} != {expected}"


def test_dispatch_ahead_engages_and_matches_reference():
    """A long single-stream decode must run mostly ahead ticks and still be
    bitwise-identical to the solo reference."""
    model, params, _ = fuzz._setup("dense")
    prompt = [5, 3, 8, 1]
    n = 24
    expected = _ref(model, params, prompt, n)

    async def scenario():
        fe = AsyncEngine(model, params, slots=2, max_len=96, block_size=8,
                         prefill_chunk=8)
        toks = [t async for t in fe.submit(prompt, max_tokens=n).stream()]
        await fe.drain()
        return toks, fe.stats

    toks, stats = asyncio.run(scenario())
    assert toks == expected
    assert stats["ahead_ticks"] > 0, "dispatch-ahead never engaged"
    assert stats["ahead_ticks"] <= stats["ticks"]


def test_cancel_mid_stream_keeps_prefix_and_frees_slot():
    model, params, _ = fuzz._setup("dense")
    prompt = [2, 7, 1]
    expected = _ref(model, params, prompt, 30)

    async def scenario():
        fe = AsyncEngine(model, params, slots=1, max_len=96, block_size=8,
                         prefill_chunk=8)
        h = fe.submit(prompt, max_tokens=30)
        got = []
        async for tok in h.stream():
            got.append(tok)
            if len(got) == 3:
                h.cancel()
                h.cancel()  # idempotent
        await fe.drain()
        # the freed slot must serve a fresh request afterwards
        h2 = fe.submit(prompt, max_tokens=4)
        after = await h2.result()
        await fe.drain()
        return h, got, after, fe

    h, got, after, fe = asyncio.run(scenario())
    assert h.cancelled and h.finish_reason == "user"
    assert got == h.out_tokens
    assert 3 <= len(got) < 30  # cancel applies at the next safe point
    assert got == expected[:len(got)]
    assert after == expected[:4]
    assert fe.engine.manager.num_free == fe.engine.manager.num_blocks - 1


def test_deadline_expires_queued_request():
    from repro.obs import Observer

    model, params, _ = fuzz._setup("dense")
    obs = Observer()

    async def scenario():
        fe = AsyncEngine(engine=Engine(model, params, slots=1, max_len=96,
                                       block_size=8, prefill_chunk=8, obs=obs))
        ok = fe.submit([1, 2, 3], max_tokens=6)
        doomed = fe.submit([4, 5, 6], max_tokens=6, deadline_s=1e-9)
        toks = [t async for t in doomed.stream()]
        await fe.drain()
        return ok, doomed, toks

    ok, doomed, toks = asyncio.run(scenario())
    assert ok.done and not ok.cancelled and len(ok.out_tokens) == 6
    assert doomed.cancelled and doomed.finish_reason == "deadline"
    assert toks == [] and doomed.out_tokens == []
    assert obs.registry.get("serve_deadline_miss_total").value == 1
    assert obs.registry.get("serve_cancellations_total").value == 1
    assert [e["rid"] for e in obs.trace.by_type("deadline_miss")] == [doomed.rid]


def test_submit_validation():
    model, params, _ = fuzz._setup("dense")
    fe = AsyncEngine(model, params, slots=1, max_len=96, prefill_chunk=8)
    # outside an event loop: no handle, no queued request
    with pytest.raises(RuntimeError):
        fe.submit([1, 2, 3])
    assert not fe.engine.pending()

    async def scenario():
        for bad in (0, -1.5):
            with pytest.raises(ValueError, match="deadline_s"):
                fe.submit([1, 2, 3], max_tokens=4, deadline_s=bad)
        assert not fe.engine.pending()  # rejected before enqueue
        with pytest.raises(ValueError):
            fe.submit([], max_tokens=4)

    asyncio.run(scenario())
    with pytest.raises(ValueError, match="prebuilt engine"):
        AsyncEngine(model, params, engine=fe.engine)


def test_drained_engine_reuse():
    """After the pump drains, a later submit restarts it — the front-end is
    never silently stale."""
    model, params, _ = fuzz._setup("dense")
    prompt = [9, 9, 1]
    expected = _ref(model, params, prompt, 5)

    async def scenario():
        fe = AsyncEngine(model, params, slots=1, max_len=96, prefill_chunk=8)
        first = await fe.submit(prompt, max_tokens=5).result()
        await fe.drain()
        pump1 = fe._pump_task
        assert pump1.done()
        second = await fe.submit(prompt, max_tokens=5).result()
        await fe.drain()
        assert fe._pump_task is not pump1  # fresh pump, not the stale one
        return first, second

    first, second = asyncio.run(scenario())
    assert first == expected and second == expected


def test_frontend_smoke():
    """CI smoke (pallas-interpret matrix): two concurrent streams, one
    cancelled, tokens identical to the solo reference."""
    model, params, reference = fuzz._setup("dense")
    p1, p2 = [1, 2, 3, 4], [7, 6, 5]
    expected = reference(p1)[:6]

    async def scenario():
        fe = AsyncEngine(model, params, slots=2, max_len=96, block_size=8,
                         prefill_chunk=8)
        h1 = fe.submit(p1, max_tokens=6)
        h2 = fe.submit(p2, max_tokens=30)
        toks1 = [t async for t in h1.stream()]
        h2.cancel()
        await fe.drain()
        return toks1, h2

    toks1, h2 = asyncio.run(scenario())
    assert toks1 == expected
    assert h2.cancelled

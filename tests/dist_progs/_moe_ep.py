import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models import build_model

cfg_ep = get_config("mixtral-8x22b", reduced=True).replace(
    moe_impl="ep", n_experts=8, capacity_factor=8.0,
    compute_dtype="float32", param_dtype="float32")
cfg_dn = cfg_ep.replace(moe_impl="dense")
model_ep, model_dn = build_model(cfg_ep), build_model(cfg_dn)
params = model_dn.init(jax.random.PRNGKey(0))
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg_ep.vocab_size)
h_dn, _ = jax.jit(lambda p, t: model_dn.forward(p, {"tokens": t}))(params, toks)
mesh = jax.make_mesh((2, 4), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2)
with jax.set_mesh(mesh):
    h_ep, _ = jax.jit(lambda p, t: model_ep.forward(p, {"tokens": t}))(params, toks)
    h_ps, _ = jax.jit(lambda p, t: model_ep.forward(p, {"tokens": t}))(params, toks[:, :1])
h_dn1, _ = jax.jit(lambda p, t: model_dn.forward(p, {"tokens": t}))(params, toks[:, :1])
assert float(jnp.max(jnp.abs(h_dn - h_ep))) < 1e-3
assert float(jnp.max(jnp.abs(h_dn1 - h_ps))) < 1e-3
print("OK")

# replicated-expert EP: 2 experts on a 4-way model axis (replicas=2)
cfg_rep = cfg_ep.replace(n_experts=2, experts_per_token=1)
cfg_rep_dn = cfg_rep.replace(moe_impl="dense")
m_rep, m_rep_dn = build_model(cfg_rep), build_model(cfg_rep_dn)
params_r = m_rep_dn.init(jax.random.PRNGKey(2))
h_dn2, _ = jax.jit(lambda p, t: m_rep_dn.forward(p, {"tokens": t}))(params_r, toks)
with jax.set_mesh(mesh):
    h_rep, _ = jax.jit(lambda p, t: m_rep.forward(p, {"tokens": t}))(params_r, toks)
    h_rep1, _ = jax.jit(lambda p, t: m_rep.forward(p, {"tokens": t}))(params_r, toks[:, :1])
h_dn21, _ = jax.jit(lambda p, t: m_rep_dn.forward(p, {"tokens": t}))(params_r, toks[:, :1])
assert float(jnp.max(jnp.abs(h_dn2 - h_rep))) < 1e-3, float(jnp.max(jnp.abs(h_dn2 - h_rep)))
assert float(jnp.max(jnp.abs(h_dn21 - h_rep1))) < 1e-3
print("REPLICATED-EP OK")

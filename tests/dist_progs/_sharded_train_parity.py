import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.config import TrainConfig
from repro.configs import get_config
from repro.models import build_model
from repro.train.step import batch_pspec, build_train_step, init_train_state, state_pspecs

cfg = get_config("tinyllama-1.1b", reduced=True).replace(
    compute_dtype="float32", param_dtype="float32")
model = build_model(cfg)
tc = TrainConfig(global_batch=8, seq_len=32, lr=1e-3, optimizer="adamw", remat="none")
step = build_train_step(model, tc)

key = jax.random.PRNGKey(0)
toks = jax.random.randint(key, (8, 32), 0, cfg.vocab_size)
batch = {"tokens": toks, "targets": jnp.roll(toks, -1, 1),
         "loss_mask": jnp.ones((8, 32), jnp.float32)}

# single device
s0 = init_train_state(model, tc, key)
s1, m1 = jax.jit(step)(s0, batch)

# sharded 2x4 mesh
mesh = jax.make_mesh((2, 4), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2)
with jax.set_mesh(mesh):
    specs = state_pspecs(model, tc, mesh)
    sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                      is_leaf=lambda x: isinstance(x, P))
    s0s = init_train_state(model, tc, key, mesh=mesh)
    bsh = jax.tree.map(lambda x: NamedSharding(mesh, batch_pspec(mesh, x.ndim - 1)), batch)
    batch_s = jax.device_put(batch, bsh)
    s1s, m1s = jax.jit(step, in_shardings=(sh, bsh), out_shardings=(sh, None))(s0s, batch_s)

l1, l2 = float(m1["loss"]), float(m1s["loss"])
assert abs(l1 - l2) < 5e-3, (l1, l2)
d = max(float(jnp.max(jnp.abs(a - jax.device_get(b)))) for a, b in
        zip(jax.tree.leaves(s1.params), jax.tree.leaves(s1s.params)))
assert d < 5e-3, d
print("OK")

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.dist.pipeline import pipeline_apply

P_STAGES, M, MB, D = 4, 6, 8, 16
mesh = jax.make_mesh((P_STAGES,), ("pipe",), axis_types=(jax.sharding.AxisType.Auto,))
key = jax.random.PRNGKey(0)
ws = jax.random.normal(key, (P_STAGES, D, D)) / jnp.sqrt(D)
x = jax.random.normal(jax.random.PRNGKey(1), (M, MB, D))

def stage_fn(w, h):
    return jnp.tanh(h @ w)

out = pipeline_apply(stage_fn, ws, x, mesh)
# sequential oracle
ref = x
for s in range(P_STAGES):
    ref = jnp.tanh(ref @ ws[s])
assert float(jnp.max(jnp.abs(out - ref))) < 1e-5, float(jnp.max(jnp.abs(out - ref)))

# gradients flow through the pipeline
def loss(ws_):
    return jnp.sum(pipeline_apply(stage_fn, ws_, x, mesh) ** 2)
def loss_ref(ws_):
    h = x
    for s in range(P_STAGES):
        h = jnp.tanh(h @ ws_[s])
    return jnp.sum(h ** 2)
g = jax.grad(loss)(ws)
g_ref = jax.grad(loss_ref)(ws)
assert float(jnp.max(jnp.abs(g - g_ref))) < 1e-4
print("OK")

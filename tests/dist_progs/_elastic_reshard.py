import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import tempfile
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import restore_checkpoint, save_checkpoint

tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (16, 32)),
        "b": jnp.arange(8.0)}
mesh_a = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
mesh_b = jax.make_mesh((2, 4), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2)

sh_a = {"w": NamedSharding(mesh_a, P("data", None)), "b": NamedSharding(mesh_a, P())}
tree_a = jax.device_put(tree, sh_a)
with tempfile.TemporaryDirectory() as td:
    save_checkpoint(td, 3, tree_a)
    # restore onto a *different* mesh/sharding (elastic scale change)
    sh_b = {"w": NamedSharding(mesh_b, P("model", "data")), "b": NamedSharding(mesh_b, P())}
    restored, _ = restore_checkpoint(td, 3, tree, shardings=sh_b)
    np.testing.assert_allclose(np.asarray(restored["w"]), np.asarray(tree["w"]))
    assert restored["w"].sharding == sh_b["w"]
print("OK")

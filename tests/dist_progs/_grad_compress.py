import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.dist.compression import compressed_pmean

mesh = jax.make_mesh((8,), ("pod",), axis_types=(jax.sharding.AxisType.Auto,))
g = jax.random.normal(jax.random.PRNGKey(0), (8, 64, 32))

def island(g_local, key):
    tree = {"w": g_local[0]}
    out = compressed_pmean(tree, "pod", key)
    return out["w"]

out = jax.jit(jax.shard_map(island, mesh=mesh, in_specs=(P("pod"), P()),
                            out_specs=P(), check_vma=False))(g, jax.random.PRNGKey(1))
ref = g.mean(0)
err = float(jnp.max(jnp.abs(out - ref)))
scale = float(jnp.max(jnp.abs(ref)))
# int8 stochastic rounding: error bounded by ~scale_amax/127
amax = float(jnp.max(jnp.abs(g)))
assert err <= amax / 127 * 1.5, (err, amax / 127)
# unbiasedness: repeat with many keys, mean error -> 0
errs = []
for i in range(20):
    o = jax.jit(jax.shard_map(island, mesh=mesh, in_specs=(P("pod"), P()),
                              out_specs=P(), check_vma=False))(g, jax.random.PRNGKey(i))
    errs.append(np.asarray(o - ref))
bias = np.abs(np.mean(errs, axis=0)).max()
assert bias < amax / 127 * 0.5, bias
print("OK")

import jax
import numpy as np
import pytest


# --- optional-hypothesis fallback ------------------------------------------
# When hypothesis isn't installed (offline container), these stand-ins let
# property-based test modules still import; each @given test becomes a skip.
class _AnyStrategy:
    def __getattr__(self, name):
        return lambda *a, **k: None


st = _AnyStrategy()


def settings(*a, **k):
    return lambda fn: fn


def given(*a, **k):
    return pytest.mark.skip(reason="hypothesis not installed")


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _seed_numpy():
    np.random.seed(0)

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _seed_numpy():
    np.random.seed(0)

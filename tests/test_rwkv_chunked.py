"""Chunked-parallel wkv == sequential scan (exactness of the Finch/GLA-style
chunk factorization, including cross-chunk state carry and the bonus term).
Both forms live in ``kernels/ref.py`` as the oracles behind
``dispatch.wkv_scan``."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import wkv_chunked as _wkv_chunked
from repro.kernels.ref import wkv_scan as _wkv_scan_masked
from repro.kernels.ref import wkv_scan_sequential as _wkv_scan


@pytest.mark.parametrize("b,s,h,hd,chunk", [
    (2, 32, 2, 8, 8),
    (1, 64, 4, 16, 16),
    (3, 48, 1, 4, 12),
])
def test_chunked_matches_sequential(b, s, h, hd, chunk, key):
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    # decays in (0, 1) with realistic spread
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, hd)) * 2 - 1) * 0.98 + 0.01
    u = jax.random.normal(ks[4], (h, hd)) * 0.1
    s0 = jax.random.normal(key, (b, h, hd, hd)) * 0.3

    y_seq, st_seq = _wkv_scan(r, k, v, w, u, s0)
    y_chk, st_chk = _wkv_chunked(r, k, v, w, u, s0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_seq), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_chk), np.asarray(st_seq), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("s", [2, 5, 16, 20, 32])
def test_wkv_scan_pads_short_prompts_to_parallel_form(s, key):
    """Regression: the old eligibility test (``s % C == 0 and s > C``) sent a
    sequence of exactly one chunk (s == 16) — and every ragged length — down
    the 16-step sequential scan.  ``ref.wkv_scan`` now pads to a chunk
    multiple with identity steps so every prefill length takes the parallel
    matmul form, and stays parity-exact vs the sequential oracle."""
    b, h, hd = 2, 2, 8
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, hd)) * 2 - 1) * 0.98 + 0.01
    u = jax.random.normal(ks[4], (h, hd)) * 0.1
    s0 = jax.random.normal(key, (b, h, hd, hd)) * 0.3

    y_seq, st_seq = _wkv_scan(r, k, v, w, u, s0)
    y, st, sc = _wkv_scan_masked(r, k, v, w, u, s0)
    assert sc is None
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_seq), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_seq), rtol=2e-4, atol=2e-4)


def test_chunked_with_strong_decay(key):
    """Near-zero decays (long-range forget) must stay numerically stable."""
    b, s, h, hd = 1, 128, 2, 8
    ks = jax.random.split(key, 4)
    r = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    w = jnp.full((b, s, h, hd), 0.01)  # aggressive decay
    u = jnp.zeros((h, hd))
    s0 = jnp.zeros((b, h, hd, hd))
    y_seq, _ = _wkv_scan(r, k, v, w, u, s0)
    y_chk, _ = _wkv_chunked(r, k, v, w, u, s0, chunk=16)
    assert np.isfinite(np.asarray(y_chk)).all()
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_seq), rtol=1e-3, atol=1e-3)

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              restore_checkpoint, save_checkpoint)


def _tree(key):
    return {"a": jax.random.normal(key, (8, 4)),
            "nested": {"b": jnp.arange(6, dtype=jnp.int32),
                       "cores": [jnp.ones((2, 3)), jnp.zeros((3,))]}}


def test_roundtrip(tmp_path, key):
    t = _tree(key)
    save_checkpoint(tmp_path, 7, t, extra={"foo": 1})
    assert latest_step(tmp_path) == 7
    restored, extra = restore_checkpoint(tmp_path, 7, t)
    assert extra == {"foo": 1}
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_partial_checkpoint_invisible(tmp_path, key):
    t = _tree(key)
    save_checkpoint(tmp_path, 1, t)
    # fake a torn write: directory without COMMIT
    (tmp_path / "step_00000002").mkdir()
    assert latest_step(tmp_path) == 1


def test_gc_keeps_last(tmp_path, key):
    t = _tree(key)
    for s in range(5):
        save_checkpoint(tmp_path, s, t, keep=2)
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(steps) == 2 and steps[-1] == "step_00000004"


def test_async_checkpointer(tmp_path, key):
    t = _tree(key)
    ck = AsyncCheckpointer(tmp_path, every=2)
    assert not ck.maybe_save(1, t)
    assert ck.maybe_save(2, t)
    ck.wait()
    assert latest_step(tmp_path) == 2


def test_missing_leaf_raises(tmp_path, key):
    t = _tree(key)
    save_checkpoint(tmp_path, 0, {"a": t["a"]})
    with pytest.raises(KeyError):
        restore_checkpoint(tmp_path, 0, t)

"""repro.obs: registry math, trace schema/ordering invariants, overhead
contract, and the dispatch counters (DESIGN.md §9).

The serving-side tests replay the seeded schedules from
``test_serve_fuzz.py`` through an obs-enabled engine and assert the trace
tells a causally consistent story (submit ≤ admit ≤ first token ≤ finish,
preemptions bracketed by re-admissions) and that the TTFT histogram agrees
with the raw per-request stamps to one bucket width; the overhead guard
pins the disabled path to bitwise-identical tokens, identical tick counts,
and zero additional device syncs.
"""
import bisect
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
import test_serve_fuzz as fuzz

from repro.kernels import dispatch
from repro.obs import (
    Histogram,
    MetricsRegistry,
    ObsConfig,
    Observer,
    bench_summary,
    default_observer,
    exp_buckets,
    prometheus_text,
    read_jsonl,
    reset_default_observer,
    resolve_observer,
    validate_events,
    validate_jsonl,
)
from repro.serve.engine import Engine


# ---------------------------------------------------------------------------
# Histogram / registry math
# ---------------------------------------------------------------------------
def test_histogram_percentiles_exact_to_bucket():
    h = Histogram(boundaries=[1.0, 2.0, 4.0, 8.0])
    for v in [0.5, 1.5, 1.5, 3.0, 3.5, 5.0, 6.0, 7.0, 7.5, 100.0]:
        h.observe(v)
    assert h.count == 10 and h.vmin == 0.5 and h.vmax == 100.0
    assert h.mean() == pytest.approx(sum([0.5, 1.5, 1.5, 3.0, 3.5, 5.0,
                                          6.0, 7.0, 7.5, 100.0]) / 10)
    # rank-q observation's bucket upper edge (overflow bucket -> vmax)
    assert h.percentile(0.0) == 1.0    # rank 1 = 0.5, bucket (0, 1]
    assert h.percentile(0.5) == 4.0    # rank 5 = 3.5, bucket (2, 4]
    assert h.percentile(0.9) == 8.0    # rank 9 = 7.5, bucket (4, 8]
    assert h.percentile(1.0) == 100.0  # overflow bucket reports observed max
    with pytest.raises(ValueError):
        h.percentile(1.5)


def test_histogram_single_bucket_reports_seen_value():
    h = Histogram(boundaries=[10.0])
    h.observe(2.0)
    # clamped to the observed max, not the (far) bucket edge
    assert h.percentile(0.5) == 2.0


def test_histogram_empty_edges():
    h = Histogram(boundaries=[1.0, 2.0])
    assert h.count == 0
    assert h.percentile(0.5) is None
    assert h.percentile(0.99) is None
    assert h.mean() is None
    other = Histogram(boundaries=[1.0, 2.0])
    h.merge(other)  # merging two empties stays empty
    assert h.count == 0 and h.percentile(0.5) is None


def test_histogram_merge_matches_combined_stream():
    rng = np.random.default_rng(0)
    a_vals = rng.exponential(0.01, 200)
    b_vals = rng.exponential(0.1, 100)
    a, b, both = (Histogram() for _ in range(3))
    for v in a_vals:
        a.observe(v)
        both.observe(v)
    for v in b_vals:
        b.observe(v)
        both.observe(v)
    a.merge(b)
    assert a.counts == both.counts
    assert a.count == both.count and a.sum == pytest.approx(both.sum)
    assert a.vmin == both.vmin and a.vmax == both.vmax
    for q in (0.5, 0.95, 0.99):
        assert a.percentile(q) == both.percentile(q)
    with pytest.raises(ValueError):
        a.merge(Histogram(boundaries=[1.0, 2.0]))


def test_histogram_bad_buckets():
    for bad in ([], [2.0, 1.0], [1.0, 1.0]):
        with pytest.raises(ValueError):
            Histogram(boundaries=bad)
    with pytest.raises(ValueError):
        exp_buckets(0.0, 2.0, 4)
    b = exp_buckets(1e-3, 2.0, 4)
    assert b == (1e-3, 2e-3, 4e-3, 8e-3)


def test_registry_kinds_labels_merge():
    reg = MetricsRegistry()
    reg.counter("reqs", family="dense").inc()
    reg.counter("reqs", family="dense").inc(2)
    reg.counter("reqs", family="moe").inc()
    assert reg.get("reqs", family="dense").value == 3
    assert reg.get("reqs", family="moe").value == 1
    assert reg.get("reqs", family="rwkv") is None
    reg.gauge("util").set(0.5)
    reg.histogram("lat").observe(1e-3)
    with pytest.raises(ValueError):  # name pinned to its first kind
        reg.gauge("reqs", family="dense")
    with pytest.raises(ValueError):
        reg.histogram("reqs")  # ...even with a fresh label set
    assert reg.counter("reqs").value == 0  # same kind, new labels: fine
    other = MetricsRegistry()
    other.counter("reqs", family="dense").inc(10)
    other.gauge("util").set(0.9)
    other.histogram("lat").observe(2e-3)
    reg.merge(other)
    assert reg.get("reqs", family="dense").value == 13
    assert reg.get("util").value == 0.9
    assert reg.get("lat").count == 2
    reg.reset()
    assert reg.get("util") is None


def test_prometheus_text_and_bench_summary():
    reg = MetricsRegistry()
    reg.counter("serve_tokens_total").inc(5)
    reg.gauge("serve_pool_utilization").set(0.75)
    h = reg.histogram("serve_ttft_seconds", buckets=[0.1, 1.0])
    h.observe(0.05)
    h.observe(0.5)
    h.observe(3.0)
    text = prometheus_text(reg)
    assert "# TYPE serve_tokens_total counter" in text
    assert "serve_tokens_total 5.0" in text
    assert "serve_pool_utilization 0.75" in text
    assert 'serve_ttft_seconds_bucket{le="0.1"} 1' in text
    assert 'serve_ttft_seconds_bucket{le="1.0"} 2' in text
    assert 'serve_ttft_seconds_bucket{le="+Inf"} 3' in text
    assert "serve_ttft_seconds_count 3" in text
    summ = bench_summary(reg)
    row = summ["serve_ttft_seconds"][0]
    assert row["count"] == 3 and row["p50"] == 1.0 and row["max"] == 3.0
    assert summ["serve_pool_utilization"][0]["value"] == 0.75


# ---------------------------------------------------------------------------
# Event schema validation
# ---------------------------------------------------------------------------
def test_validate_events_catches_malformed():
    good = [
        {"ev": "submit", "t": 1.0, "seq": 0, "rid": 0, "prompt_len": 3,
         "max_tokens": 4},
        {"ev": "finish", "t": 2.0, "seq": 1, "rid": 0, "tick": 5,
         "reason": "eos", "n_out": 2},
    ]
    assert validate_events(good) == []
    assert validate_events([{"ev": "nope", "t": 1.0, "seq": 0}])
    missing = [{"ev": "submit", "t": 1.0, "seq": 0, "rid": 0}]
    errs = validate_events(missing)
    assert any("missing field" in e for e in errs)
    wrong_type = [dict(good[0], rid="zero")]
    assert any("rid" in e for e in validate_events(wrong_type))
    bad_seq = [dict(good[0], seq=5), dict(good[1], seq=1)]
    assert any("seq" in e for e in validate_events(bad_seq))
    bool_rid = [dict(good[0], rid=True)]  # bool must not pass as int
    assert validate_events(bool_rid)
    inf_t = [dict(good[0], t=float("inf"))]
    assert any("non-finite" in e for e in validate_events(inf_t))


def test_validate_jsonl_bad_file(tmp_path):
    p = tmp_path / "trace.jsonl"
    assert validate_jsonl(p)  # missing file is an error
    p.write_text("")
    assert validate_jsonl(p) == [f"{p}: no events"]
    p.write_text('{"ev": "submit"\n')
    assert validate_jsonl(p)


# ---------------------------------------------------------------------------
# Observer resolution / env config
# ---------------------------------------------------------------------------
def test_resolve_observer_and_env(monkeypatch, tmp_path):
    assert resolve_observer(False) is None
    obs = Observer()
    assert resolve_observer(obs) is obs
    assert resolve_observer(ObsConfig(enabled=False)) is None
    assert isinstance(resolve_observer(ObsConfig()), Observer)
    with pytest.raises(TypeError):
        resolve_observer("yes")
    try:
        monkeypatch.delenv("REPRO_OBS", raising=False)
        reset_default_observer()
        assert default_observer() is None
        assert resolve_observer(None) is None
        monkeypatch.setenv("REPRO_OBS", "1")
        monkeypatch.setenv("REPRO_OBS_JSONL", str(tmp_path / "t.jsonl"))
        monkeypatch.setenv("REPRO_OBS_POOL_EVERY", "3")
        reset_default_observer()
        d = default_observer()
        assert d is not None and default_observer() is d  # memoized
        assert d.config.jsonl_path == str(tmp_path / "t.jsonl")
        assert d.config.pool_sample_every == 3
        assert resolve_observer(None) is d
    finally:
        reset_default_observer()  # next default_observer() re-reads real env


# ---------------------------------------------------------------------------
# Fuzz-schedule replay: trace ordering invariants + TTFT histogram agreement
# ---------------------------------------------------------------------------
def _replay(family, seed, tmp_path):
    """Drive one fuzz schedule through an obs-enabled engine."""
    model, params, _ = fuzz._setup(family)
    cfg = model.cfg
    rng, sched = fuzz._schedule(seed)
    slots = int(rng.integers(1, 4))
    kw = dict(slots=slots, max_len=fuzz.MAX_LEN, block_size=8,
              prefill_batch=2, prefill_chunk=8)
    obs = Observer(ObsConfig(enabled=True,
                             jsonl_path=str(tmp_path / "trace.jsonl")))
    eng = Engine(model, params, obs=obs, **kw)
    handles = fuzz._drive(eng, sched, cfg, family)
    obs.close()
    return eng, obs, handles


@pytest.mark.parametrize("family,seed", [("dense", 0), ("dense", 3),
                                         ("rwkv", 51)])
def test_trace_ordering_invariants(family, seed, tmp_path):
    eng, obs, handles = _replay(family, seed, tmp_path)
    events = obs.trace.events
    assert validate_events(events) == []
    # the JSONL on disk is the same stream, schema-valid
    disk = read_jsonl(tmp_path / "trace.jsonl")
    assert validate_jsonl(tmp_path / "trace.jsonl") == []
    assert [e["seq"] for e in disk] == [e["seq"] for e in events]

    by_rid: dict[int, dict[str, list]] = {}
    for e in events:
        if "rid" in e:
            by_rid.setdefault(e["rid"], {}).setdefault(e["ev"], []).append(e)
    assert set(by_rid) == {h.rid for h in handles}
    for h in handles:
        evs = by_rid[h.rid]
        submit, = evs["submit"]
        admits = evs["admit"]
        first, = evs["first_token"]
        finish, = evs["finish"]
        # submit <= first admit <= first token <= finish
        assert submit["t"] <= admits[0]["t"] <= first["t"] <= finish["t"]
        assert finish["n_out"] == len(h.out_tokens)
        assert finish["reason"] in ("eos", "max_tokens", "max_len")
        assert first["ttft_s"] == pytest.approx(h.t_first - h.t_submit)
        # every preempt is bracketed by a later re-admission
        for p in evs.get("preempt", []):
            assert any(a["t"] >= p["t"] for a in admits), \
                f"rid {h.rid}: preempt at {p['t']} never re-admitted"
        # re-admissions only ever follow a preemption
        assert len(admits) == 1 + len(evs.get("preempt", []))
    # decode ticks count active slots truthfully
    for e in events:
        if e["ev"] == "decode_tick":
            assert 1 <= e["active"] <= eng.slots
        if e["ev"] == "pool_sample":
            assert 0.0 <= e["utilization"] <= 1.0


def test_preemption_trace_bracketing():
    """A deliberately tight pool must preempt, and the trace must show every
    preempted request re-admitted and finished."""
    model, params, _ = fuzz._setup("dense")
    # two slots, 7 usable blocks of 4: both sequences admit at 3 blocks
    # (prompt 8 + lookahead) but grow to 4 while decoding — 8 > 7 preempts
    obs = Observer()
    eng = Engine(model, params, slots=2, max_len=96, block_size=4,
                 num_blocks=8, prefill_batch=2, prefill_chunk=8, obs=obs)
    handles = [eng.submit(list(range(1, 9)), max_tokens=6) for _ in range(3)]
    eng.run()
    assert all(h.done for h in handles)
    events = obs.trace.events
    assert validate_events(events) == []
    preempts = [e for e in events if e["ev"] == "preempt"]
    assert preempts, "tight pool never preempted — test geometry is stale"
    assert eng.obs.registry.get("serve_preemptions_total").value == len(preempts)
    admits = [e for e in events if e["ev"] == "admit"]
    finishes = {e["rid"] for e in events if e["ev"] == "finish"}
    for p in preempts:
        assert any(a["rid"] == p["rid"] and a["seq"] > p["seq"] for a in admits)
        assert p["rid"] in finishes


def test_ttft_histogram_matches_raw_stamps(tmp_path):
    """Acceptance: histogram percentiles agree with the raw per-request
    ``t_first - t_submit`` values to one bucket width."""
    raw = []
    hist = None
    for seed in (1, 2, 4):
        eng, obs, handles = _replay("dense", seed, tmp_path / str(seed))
        raw.extend(h.t_first - h.t_submit for h in handles)
        h = obs.registry.get("serve_ttft_seconds")
        if hist is None:
            hist = h
        else:
            hist.merge(h)
    assert hist.count == len(raw)
    bounds = hist.boundaries
    raw.sort()
    for q in (0.5, 0.95, 0.99):
        rank_val = raw[max(0, int(np.ceil(q * len(raw))) - 1)]
        hp = hist.percentile(q)
        i = bisect.bisect_left(bounds, rank_val)
        lo = bounds[i - 1] if i else 0.0
        hi = bounds[i] if i < len(bounds) else float("inf")
        assert lo < hp <= hi or hp == rank_val, \
            f"p{q}: hist {hp} not within one bucket of raw {rank_val}"


# ---------------------------------------------------------------------------
# Overhead contract: obs disabled == bitwise-identical behavior, no syncs
# ---------------------------------------------------------------------------
def test_disabled_obs_identical_tokens_ticks_and_syncs(monkeypatch):
    model, params, _ = fuzz._setup("dense")
    prompts = [[1, 2, 3], [4, 5, 6, 7], [8, 9], [10, 11, 12, 13, 14]]

    def run(obs):
        syncs = []
        real = jax.block_until_ready
        monkeypatch.setattr(jax, "block_until_ready",
                            lambda x: (syncs.append(1), real(x))[1])
        eng = Engine(model, params, slots=2, max_len=96, block_size=8,
                     prefill_batch=2, prefill_chunk=8, obs=obs)
        reqs = [eng.submit(p, max_tokens=5) for p in prompts]
        eng.run()
        monkeypatch.setattr(jax, "block_until_ready", real)
        return [r.out_tokens for r in reqs], eng._tick_no, len(syncs)

    toks_off, ticks_off, syncs_off = run(False)
    toks_on, ticks_on, syncs_on = run(Observer())
    assert toks_on == toks_off  # bitwise-identical schedule + tokens
    assert ticks_on == ticks_off
    # enabling obs must not add device syncs; disabling it certainly must not
    assert syncs_on == syncs_off


# ---------------------------------------------------------------------------
# kernels.dispatch counters, resolved_backend, kernel timing
# ---------------------------------------------------------------------------
def test_dispatch_counts_and_resolved_backend():
    dispatch.reset_dispatch_metrics()
    x = jnp.ones((2, 8), jnp.float32)
    w = jnp.ones((8, 4), jnp.float32)
    dispatch.dense_linear(x, w, role="mlp_up")
    dispatch.dense_linear(x, w, role="mlp_up")
    dispatch.dense_linear(x, w)  # falls back to the kind label
    counts = dispatch.dispatch_counts()
    assert counts[("mlp_up", "xla")] == 2
    assert counts[("dense", "xla")] == 1
    assert dispatch.resolved_backend("mlp_up") == "xla"
    assert dispatch.resolved_backend("never_dispatched") is None
    # trace-time semantics: a jitted program counts once per trace, and the
    # baked-in backend is what resolved_backend reports afterwards
    dispatch.reset_dispatch_metrics()
    f = jax.jit(lambda a: dispatch.dense_linear(a, w, role="probe"))
    f(x)
    f(x)
    f(x)  # cached executions re-run nothing at trace level
    assert dispatch.dispatch_counts()[("probe", "xla")] == 1


def test_dispatch_kernel_timing_env(monkeypatch):
    dispatch.reset_dispatch_metrics()
    monkeypatch.setenv("REPRO_OBS_KERNEL_TIMING", "1")
    x = jnp.ones((2, 8), jnp.float32)
    w = jnp.ones((8, 4), jnp.float32)
    dispatch.dense_linear(x, w, role="timed")
    h = dispatch.kernel_metrics().get("kernel_wall_seconds", role="timed",
                                      backend="xla")
    assert h is not None and h.count == 1 and h.vmax > 0
    # under a jit trace the inputs are Tracers: the fence must NOT fire
    jax.jit(lambda a: dispatch.dense_linear(a, w, role="timed"))(x)
    assert h.count == 1
    monkeypatch.delenv("REPRO_OBS_KERNEL_TIMING")
    dispatch.dense_linear(x, w, role="timed")
    assert h.count == 1  # timing off again
    dispatch.reset_dispatch_metrics()


def test_engine_records_prefill_dispatch():
    """The engine's jitted steps surface which attention backend actually
    traced — the benchmark reads this instead of self-reporting."""
    model, params, _ = fuzz._setup("dense")
    dispatch.reset_dispatch_metrics()
    eng = Engine(model, params, slots=2, max_len=96, block_size=8,
                 kernel_backend="ref")
    req = eng.submit([1, 2, 3], max_tokens=3)
    eng.run()
    assert req.done
    # steps are memoized across engines, so the trace may have happened in an
    # earlier test of this process — but with reset_dispatch_metrics() above,
    # a fresh count here proves this engine's programs re-used or re-traced
    # through the dispatcher; at minimum the resolved backend is queryable
    rb = dispatch.resolved_backend("attn_prefill")
    assert rb in (None, "ref", "pallas-interpret", "pallas")


# ---------------------------------------------------------------------------
# Trainer metrics ride the same registry
# ---------------------------------------------------------------------------
def test_trainer_metrics(tmp_path, key):
    from repro.config import TrainConfig
    from repro.configs import get_config
    from repro.data.pipeline import DataConfig
    from repro.models import build_model
    from repro.train.step import build_train_step, init_train_state
    from repro.train.trainer import Trainer

    cfg = get_config("tinyllama-1.1b", reduced=True).replace(
        compute_dtype="float32", param_dtype="float32")
    model = build_model(cfg)
    tc = TrainConfig(global_batch=2, seq_len=16, lr=3e-3, warmup_steps=2,
                     total_steps=6, optimizer="adamw", remat="none")
    state = init_train_state(model, tc, key)
    step = jax.jit(build_train_step(model, tc))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=2,
                    seed=0)
    obs = Observer()
    tr = Trainer(step, state, dc, obs=obs)
    rep = tr.run(4, log_every=0)
    assert rep.steps_done == 4
    h = obs.registry.get("train_step_seconds")
    assert h.count == 4
    # bucket-resolution agreement with the report's own perf_counter stamps
    assert h.vmax == pytest.approx(max(rep.step_times))
    assert obs.registry.get("train_steps_total").value == 4
    assert obs.registry.get("train_tokens_per_second").value > 0
    # JSON round-trip of the summary (what BENCH files embed)
    json.dumps(bench_summary(obs.registry))


# ---------------------------------------------------------------------------
# Cancellation / deadline events: schema + causal ordering + slot reuse
# ---------------------------------------------------------------------------
def test_cancel_trace_ordering_and_slot_reuse():
    """submit <= admit <= cancel in the trace, the cancel names the freed
    slot, and a later admit reuses that slot after the cancel's seq."""
    model, params, _ = fuzz._setup("dense")
    obs = Observer()
    eng = Engine(model, params, slots=1, max_len=96, block_size=8,
                 prefill_chunk=8, obs=obs)
    victim = eng.submit([1, 2, 3], max_tokens=40)
    waiter = eng.submit([4, 5, 6], max_tokens=4)
    for _ in range(3):
        eng.tick()
    assert eng.cancel(victim)
    eng.run()
    assert waiter.done and not waiter.cancelled
    events = obs.trace.events
    assert validate_events(events) == []
    by = {e["ev"]: e for e in events if e.get("rid") == victim.rid}
    assert by["submit"]["seq"] <= by["admit"]["seq"] <= by["cancel"]["seq"]
    assert by["submit"]["t"] <= by["admit"]["t"] <= by["cancel"]["t"]
    assert by["cancel"]["slot"] == by["admit"]["slot"] == 0
    assert by["cancel"]["reason"] == "user"
    assert "finish" not in by  # a cancel is terminal, never double-finished
    waiter_admit, = [e for e in events if e["ev"] == "admit"
                     and e["rid"] == waiter.rid]
    assert waiter_admit["slot"] == 0  # the cancelled request's slot, reused
    assert waiter_admit["seq"] > by["cancel"]["seq"]
    assert obs.registry.get("serve_cancellations_total").value == 1


def test_cancel_queued_and_deadline_events_validate():
    model, params, _ = fuzz._setup("dense")
    obs = Observer()
    eng = Engine(model, params, slots=1, max_len=96, block_size=8,
                 prefill_chunk=8, obs=obs)
    active = eng.submit([1, 2, 3], max_tokens=4)
    queued = eng.submit([4, 5, 6], max_tokens=4)
    doomed = eng.submit([7, 8, 9], max_tokens=4, deadline_s=1e-9)
    eng.tick()
    assert eng.cancel(queued)
    eng.run()
    assert active.done and not active.cancelled
    events = obs.trace.events
    assert validate_events(events) == []
    cancel_q, = [e for e in events if e["ev"] == "cancel"
                 and e["rid"] == queued.rid]
    assert cancel_q["slot"] == -1  # cancelled before ever holding a slot
    miss, = [e for e in events if e["ev"] == "deadline_miss"]
    assert miss["rid"] == doomed.rid and miss["deadline_s"] == 1e-9
    cancel_d, = [e for e in events if e["ev"] == "cancel"
                 and e["rid"] == doomed.rid]
    assert cancel_d["reason"] == "deadline" and cancel_d["seq"] > miss["seq"]
    assert obs.registry.get("serve_deadline_miss_total").value == 1
    assert obs.registry.get("serve_cancellations_total").value == 2

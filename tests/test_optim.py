"""Optimizers + schedule."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import apply_optimizer, init_optimizer, warmup_cosine


def test_adamw_matches_reference():
    p = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    g = {"w": jnp.asarray([0.1, -0.2, 0.3])}
    st = init_optimizer("adamw", p)
    new_p, st2, _ = apply_optimizer(st, p, g, lr=jnp.float32(0.1), b1=0.9, b2=0.999)
    # reference: step 1 with bias correction => update = sign-ish g/|g|
    mu = 0.1 * np.asarray(g["w"]); nu = 0.001 * np.asarray(g["w"])**2
    u = (mu / (1 - 0.9)) / (np.sqrt(nu / (1 - 0.999)) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), np.asarray(p["w"]) - 0.1 * u, rtol=1e-5)


def _rosenbrockish(kind, steps, lr):
    def loss(p):
        return jnp.sum((p["a"] - 3.0) ** 2) + jnp.sum((p["b"] @ p["b"].T - jnp.eye(4)) ** 2)
    p = {"a": jnp.zeros((5,)), "b": jnp.eye(4) * 0.1}
    st = init_optimizer(kind, p)
    for _ in range(steps):
        l, g = jax.value_and_grad(loss)(p)
        p, st, _ = apply_optimizer(st, p, g, lr=jnp.float32(lr))
    return float(loss(p))


def test_adamw_converges():
    assert _rosenbrockish("adamw", 200, 0.05) < 0.05


def test_adafactor_converges():
    assert _rosenbrockish("adafactor", 200, 0.05) < 0.2


def test_adafactor_factored_state_small():
    p = {"w": jnp.zeros((64, 128))}
    st = init_optimizer("adafactor", p)
    n_state = sum(x.size for x in jax.tree.leaves(st.inner))
    assert n_state == 64 + 128  # vr + vc, no full second moment


def test_grad_clip():
    p = {"w": jnp.asarray([0.0])}
    g = {"w": jnp.asarray([100.0])}
    st = init_optimizer("adamw", p)
    _, _, m = apply_optimizer(st, p, g, lr=jnp.float32(0.1), grad_clip=1.0)
    assert abs(float(m["grad_norm"]) - 100.0) < 1e-3  # reported pre-clip


def test_warmup_cosine_shape():
    s = warmup_cosine(1.0, 10, 100)
    assert abs(float(s(jnp.int32(0))) - 0.1) < 1e-6  # warms from lr/warmup
    assert abs(float(s(jnp.int32(9))) - 1.0) < 1e-6
    assert float(s(jnp.int32(100))) < 0.11
    assert float(s(jnp.int32(55))) < float(s(jnp.int32(20)))

"""Fault tolerance: restore-on-failure, straggler watchdog, resume."""
import logging

import jax
import numpy as np
import pytest

from repro.config import TrainConfig
from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.models import build_model
from repro.train.step import build_train_step, init_train_state
from repro.train.trainer import Trainer


def _setup(tmp_path, key, steps=60):
    cfg = get_config("tinyllama-1.1b", reduced=True).replace(
        compute_dtype="float32", param_dtype="float32")
    model = build_model(cfg)
    tc = TrainConfig(global_batch=4, seq_len=32, lr=3e-3, warmup_steps=5,
                     total_steps=steps, optimizer="adamw", remat="none")
    state = init_train_state(model, tc, key)
    step = jax.jit(build_train_step(model, tc))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4, seed=0)
    return step, state, dc


def test_loss_decreases(tmp_path, key):
    step, state, dc = _setup(tmp_path, key)
    tr = Trainer(step, state, dc, ckpt_dir=tmp_path, ckpt_every=25)
    rep = tr.run(40, log_every=0)
    assert np.mean(rep.losses[-5:]) < np.mean(rep.losses[:5]) - 0.2


def test_fault_injection_recovers(tmp_path, key):
    step, state, dc = _setup(tmp_path, key)
    tr = Trainer(step, state, dc, ckpt_dir=tmp_path, ckpt_every=5, max_retries=3)
    boom = {"armed": True}

    def injector(s):
        if s == 12 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("simulated node failure")

    rep = tr.run(20, log_every=0, fault_injector=injector)
    assert rep.restarts == 1
    assert rep.steps_done == 20
    assert np.isfinite(rep.losses).all()


def test_straggler_watchdog(tmp_path, key):
    step, state, dc = _setup(tmp_path, key)
    events = []
    import time

    def slow_injector(s):
        if s == 15:
            time.sleep(1.0)

    tr = Trainer(step, state, dc, straggler_factor=2.5,
                 on_straggler=lambda s, dt, med: events.append((s, dt, med)))
    tr.run(20, log_every=0, fault_injector=slow_injector)
    assert any(s == 15 for s, _, _ in events)


def test_stop_and_resume(tmp_path, key):
    step, state, dc = _setup(tmp_path, key)
    tr1 = Trainer(step, state, dc, ckpt_dir=tmp_path, ckpt_every=5)
    tr1.run(10, log_every=0)
    step_after = tr1.current_step()
    # new trainer restores from the checkpoint dir and continues the stream
    tr2 = Trainer(step, state, dc, ckpt_dir=tmp_path, ckpt_every=5)
    assert tr2._restore_latest()
    assert tr2.current_step() == step_after
    rep2 = tr2.run(5, log_every=0)
    assert rep2.steps_done == 5

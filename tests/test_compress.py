"""Whole-model compression pipeline: Table I reproduction + exactness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import QuantConfig, TTDConfig
from repro.configs import get_config
from repro.core.compress import compress_model, compression_report
from repro.models import build_model


def test_table1_chatglm3():
    rep = compression_report(get_config("chatglm3-6b"))
    assert abs(rep.block_cr - 10.72) < 0.01
    assert abs(rep.network_cr - 1.94) < 0.005
    crs = {r.role: r.cr for r in rep.roles}
    assert abs(crs["wo"] - 481.88) < 0.01
    assert abs(crs["gate"] - 1446.44) < 0.01


def test_table1_llama2():
    rep = compression_report(get_config("llama2-7b"))
    assert abs(rep.block_cr - 4.01) < 0.005
    # paper's stated 1.60 corresponds to ~16 TT blocks; the formula with the
    # stated 19 blocks gives 1.80 (documented inconsistency, EXPERIMENTS.md)
    assert abs(rep.network_cr - 1.80) < 0.01
    crs = {r.role: r.cr for r in rep.roles}
    assert abs(crs["wo"] - 481.88) < 0.01
    assert abs(crs["gate"] - 1007.89) < 0.01


def test_bit_accounting_pins():
    """Bit-CR accounting (regenerated pins, benchmarks/table1_cr.py):
    the dense baseline width derives from cfg.param_dtype (float32 Table-I
    configs -> bits-CR == param-CR when no int4 mixes in); the deployment
    recipe (int4 non-TT linears vs an FP16 baseline) shifts it."""
    from benchmarks.table1_cr import DEPLOY_BITS, deploy_bits_cr

    for arch in ("chatglm3-6b", "llama2-7b"):
        cfg = get_config(arch)
        rep = compression_report(cfg)  # param_dtype float32 -> 32-bit baseline
        assert abs(rep.network_cr_bits - rep.network_cr) < 1e-9
        assert abs(deploy_bits_cr(cfg) - DEPLOY_BITS[arch]) < 0.005, arch
    # explicit param_bits still overrides the derived default
    cfg = get_config("chatglm3-6b")
    assert compression_report(cfg, param_bits=16).network_cr_bits == \
        compression_report(cfg.replace(param_dtype="bfloat16")).network_cr_bits


def test_embed_accounting():
    """Tied tables count once; TT embed compression moves only the
    compressed side of network_cr_with_embed (untied head stays dense)."""
    import dataclasses

    cfg = get_config("tinyllama-1.1b")  # untied
    rep = compression_report(cfg)
    assert rep.embed_params == 2 * cfg.vocab_size * cfg.d_model
    assert rep.embed_params_comp == rep.embed_params  # TT embed off
    tied = compression_report(cfg.replace(tie_embeddings=True))
    assert tied.embed_params == cfg.vocab_size * cfg.d_model
    assert tied.network_cr_with_embed > rep.network_cr_with_embed

    emb = compression_report(cfg.replace(
        ttd=dataclasses.replace(cfg.ttd, embed=True)))
    assert emb.embed_params == rep.embed_params  # dense baseline unchanged
    assert emb.embed_params_comp < rep.embed_params_comp
    assert emb.embed_params_comp > cfg.vocab_size * cfg.d_model  # dense head rides
    assert emb.network_cr_with_embed > rep.network_cr_with_embed
    assert emb.network_cr == rep.network_cr  # blocks-only CR untouched


def test_every_arch_has_positive_block_cr():
    for arch in ("tinyllama-1.1b", "qwen1.5-110b", "mixtral-8x22b", "kimi-k2-1t-a32b"):
        rep = compression_report(get_config(arch))
        assert rep.block_cr > 1.5, (arch, rep.block_cr)


def test_compress_model_full_rank_exact(key):
    cfg_t = get_config("llama2-7b", reduced=True).replace(
        compute_dtype="float32", param_dtype="float32",
        ttd=TTDConfig(enabled=True, rank=10**6, d=2))
    cfg_d = cfg_t.replace(ttd=TTDConfig(enabled=False), quant=QuantConfig(enabled=False))
    m_d, m_t = build_model(cfg_d), build_model(cfg_t)
    params_d = m_d.init(key)
    params_t = compress_model(params_d, cfg_d, cfg_t, svd_method="svd")
    toks = jax.random.randint(key, (2, 16), 0, cfg_t.vocab_size)
    h_d, _ = m_d.forward(params_d, {"tokens": toks})
    h_t, _ = m_t.forward(params_t, {"tokens": toks})
    assert float(jnp.linalg.norm(h_d - h_t) / jnp.linalg.norm(h_d)) < 1e-4


def test_compress_model_segment_resplit(key):
    """Paper recipe: only the last k blocks TT'd; dense stack re-splits."""
    base = get_config("llama2-7b", reduced=True).replace(
        n_layers=4, compute_dtype="float32", param_dtype="float32")
    cfg_t = base.replace(ttd=TTDConfig(enabled=True, rank=10**6, d=2, first_tt_block=2))
    cfg_d = base.replace(ttd=TTDConfig(enabled=False), quant=QuantConfig(enabled=False))
    m_d, m_t = build_model(cfg_d), build_model(cfg_t)
    params_d = m_d.init(key)
    params_t = compress_model(params_d, cfg_d, cfg_t, svd_method="svd")
    assert len(params_t["segments"]) == 2
    toks = jax.random.randint(key, (2, 8), 0, base.vocab_size)
    h_d, _ = m_d.forward(params_d, {"tokens": toks})
    h_t, _ = m_t.forward(params_t, {"tokens": toks})
    assert float(jnp.linalg.norm(h_d - h_t) / jnp.linalg.norm(h_d)) < 1e-4


def test_compress_int4_only(key):
    cfg_d = get_config("tinyllama-1.1b", reduced=True).replace(
        compute_dtype="float32", param_dtype="float32",
        ttd=TTDConfig(enabled=False), quant=QuantConfig(enabled=False))
    cfg_q = cfg_d.replace(quant=QuantConfig(enabled=True, group_size=32))
    m_d, m_q = build_model(cfg_d), build_model(cfg_q)
    params_d = m_d.init(key)
    params_q = compress_model(params_d, cfg_d, cfg_q)
    toks = jax.random.randint(key, (2, 16), 0, cfg_d.vocab_size)
    h_d, _ = m_d.forward(params_d, {"tokens": toks})
    h_q, _ = m_q.forward(params_q, {"tokens": toks})
    # int4 noise compounds through a random-init residual stack; require the
    # representation to stay directionally faithful (per-layer error bounds
    # are covered exactly in test_quant.py)
    cos = float(jnp.sum(h_d * h_q) /
                (jnp.linalg.norm(h_d) * jnp.linalg.norm(h_q)))
    assert cos > 0.9, cos


def test_walk_length_mismatch_raises():
    """A malformed spec tree used to zip-truncate silently, leaving trailing
    layers uncompressed; it must now raise and name the offending path."""
    from repro.core.compress import _walk

    params = {"segments": [{"x": 1}, {"x": 2}, {"x": 3}]}
    spec = {"segments": [None, None]}
    with pytest.raises(ValueError, match=r"'segments'.*3 param.*2 spec"):
        _walk(params, spec, "auto")
    # equal lengths (with nested lists) still walk fine
    out = _walk({"segments": [{"x": 1}, {"x": 2}]}, {"segments": [None, None]},
                "auto")
    assert out == {"segments": [{"x": 1}, {"x": 2}]}
    # nested mismatches name the indexed path
    with pytest.raises(ValueError, match=r"'segments\[0\]/mlp'"):
        _walk({"segments": [{"mlp": [1, 2]}]}, {"segments": [{"mlp": [None]}]},
              "auto")


def test_walk_dangling_spec_key_raises():
    """A typoed spec key (no matching param) must fail loudly too, not drop
    the conversion."""
    from repro.core.compress import _walk

    with pytest.raises(ValueError, match=r"\['atn'\].*'segments\[0\]'"):
        _walk({"segments": [{"attn": {"x": 1}}]},
              {"segments": [{"atn": None}]}, "auto")

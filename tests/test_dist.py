"""Multi-device tests: each runs in a subprocess with 8 fake CPU devices so
the main pytest process keeps its single-device jax (per the dry-run rule:
device-count flags are never set globally)."""
import subprocess
import sys
from pathlib import Path

import pytest

PROGS = Path(__file__).parent / "dist_progs"
SRC = str(Path(__file__).parent.parent / "src")


def _run(name):
    env = {"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
           "HOME": "/root", "JAX_PLATFORMS": "cpu"}
    r = subprocess.run([sys.executable, str(PROGS / name)], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"{name} failed:\n{r.stdout}\n{r.stderr}"
    assert "OK" in r.stdout


def test_moe_expert_parallel_all_to_all():
    _run("_moe_ep.py")


def test_pipeline_parallel_gpipe():
    _run("_pipeline.py")


def test_gradient_compression_int8_allreduce():
    _run("_grad_compress.py")


def test_sharded_train_step_parity():
    _run("_sharded_train_parity.py")


def test_elastic_checkpoint_reshard():
    _run("_elastic_reshard.py")

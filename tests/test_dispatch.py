"""Unified linear dispatch: ref vs pallas-interpret backend parity.

Sweeps kinds {dense, tt, int4} × epilogues {none, bias, bn, res, bn+res} on
both (B, N) and (B, S, N) inputs, then checks the full transformer forward
(prefill + decode_step) agrees between backends with residual/bias fused at
the attention-out and MLP-down call sites.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import QuantConfig
from repro.configs import get_config
from repro.core.ttd import TTSpec
from repro.kernels import dispatch
from repro.models import build_model
from repro.models.modules import LinearSpec, apply_linear, init_linear

KINDS = ["dense", "tt", "int4"]
EPILOGUES = ["none", "bias", "bn", "res", "bn+res"]
N, M = 256, 512


def _spec(kind: str, bias: bool) -> LinearSpec:
    if kind == "tt":
        return LinearSpec("tt", N, M, bias=bias, tt=TTSpec.make(N, M, 8, d=4),
                          role="test")
    if kind == "int4":
        return LinearSpec("int4", N, M, bias=bias, quant_group=64, role="test")
    return LinearSpec("dense", N, M, bias=bias, role="test")


@pytest.mark.parametrize("lead", [(9,), (2, 7)], ids=["BN", "BSN"])
@pytest.mark.parametrize("epi", EPILOGUES)
@pytest.mark.parametrize("kind", KINDS)
def test_backend_parity(kind, epi, lead, key):
    bias = epi in ("bias", "bn", "bn+res")
    spec = _spec(kind, bias)
    params = init_linear(key, spec, jnp.float32)
    if bias:  # nonzero bias so a dropped bias-only epilogue would be caught
        params["b"] = jax.random.normal(jax.random.fold_in(key, 1), (M,))
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], lead + (N,), jnp.float32)
    scale = jax.random.normal(ks[1], (M,)) if "bn" in epi else None
    residual = jax.random.normal(ks[2], lead + (M,)) if "res" in epi else None
    y_ref = apply_linear(params, x, spec, jnp.float32, scale=scale,
                         residual=residual, backend="ref")
    y_pl = apply_linear(params, x, spec, jnp.float32, scale=scale,
                        residual=residual, backend="pallas-interpret")
    assert y_pl.shape == lead + (M,)
    scale_ref = float(jnp.max(jnp.abs(y_ref))) or 1.0
    assert float(jnp.max(jnp.abs(y_pl - y_ref))) / scale_ref < 1e-4, (kind, epi)


@pytest.mark.parametrize("kind", KINDS)
def test_fused_activation_parity(kind, key):
    spec = _spec(kind, bias=True)
    params = init_linear(key, spec, jnp.float32)
    x = jax.random.normal(key, (5, N), jnp.float32)
    y_ref = apply_linear(params, x, spec, jnp.float32, activation="silu",
                         backend="ref")
    y_pl = apply_linear(params, x, spec, jnp.float32, activation="silu",
                        backend="pallas-interpret")
    scale_ref = float(jnp.max(jnp.abs(y_ref))) or 1.0
    assert float(jnp.max(jnp.abs(y_pl - y_ref))) / scale_ref < 1e-4


def test_resolve_backend_chain(monkeypatch):
    monkeypatch.delenv(dispatch.ENV_VAR, raising=False)
    # auto resolves by device (CPU here -> ref)
    assert dispatch.resolve_backend(None) == "ref"
    assert dispatch.resolve_backend("auto") == "ref"
    # explicit arg wins over everything
    monkeypatch.setenv(dispatch.ENV_VAR, "pallas-interpret")
    assert dispatch.resolve_backend("ref") == "ref"
    # env wins over the config preference
    assert dispatch.resolve_backend(None, preferred="ref") == "pallas-interpret"
    # per-role env wins over the global env
    monkeypatch.setenv(f"{dispatch.ENV_VAR}_ATTN_O", "ref")
    assert dispatch.resolve_backend(None, role="attn_o") == "ref"
    assert dispatch.resolve_backend(None, role="mlp_down") == "pallas-interpret"
    # context override wins over env
    with dispatch.backend_override("ref"):
        assert dispatch.resolve_backend(None, role="mlp_down") == "ref"
    with pytest.raises(ValueError):
        dispatch.resolve_backend("cuda")


def test_transformer_forward_backend_parity(key, monkeypatch):
    """Acceptance: full prefill + decode under REPRO_KERNEL_BACKEND matches
    ref, with tt (attn_o / mlp_*) and int4 (q/k/v) kinds both on the path."""
    cfg = get_config("tinyllama-1.1b", reduced=True).replace(
        compute_dtype="float32", param_dtype="float32",
        quant=QuantConfig(enabled=True, bits=4, group_size=32))
    model = build_model(cfg)
    params = model.init(key)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    dec = {"tokens": toks[:, -1:]}

    outs = {}
    for backend in ("ref", "pallas-interpret"):
        monkeypatch.setenv(dispatch.ENV_VAR, backend)
        hidden, _ = model.forward(params, batch)
        logits, cache = model.prefill(params, {"tokens": toks[:, :15]},
                                      cache_dtype=jnp.float32, max_len=20)
        dlogits, _ = model.decode_step(params, cache, dec, jnp.int32(15))
        outs[backend] = (hidden, logits, dlogits)
    monkeypatch.delenv(dispatch.ENV_VAR)
    for a, b in zip(outs["ref"], outs["pallas-interpret"]):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=2e-4, atol=2e-4)

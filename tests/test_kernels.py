"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import TTSpec, init_tt_linear, quantize_int4
from repro.kernels import ref
from repro.kernels.int4_matmul import int4_matmul_pallas
from repro.kernels.tt_linear import pick_block_b, tt_linear_pallas


@pytest.mark.parametrize("n,m,r,d,b,dtype", [
    (256, 512, 8, 4, 7, jnp.float32),
    (4096, 4096, 16, 4, 32, jnp.float32),   # paper LinearO
    (512, 256, 4, 3, 64, jnp.bfloat16),
    (64, 64, 2, 2, 1, jnp.float32),
    (2048, 5632, 8, 4, 13, jnp.bfloat16),   # tinyllama MLP shape
])
def test_tt_kernel_matches_ref(n, m, r, d, b, dtype, key):
    spec = TTSpec.make(n, m, r, d=d)
    cores = [c.astype(dtype) for c in init_tt_linear(key, spec, jnp.float32)["cores"]]
    x = jax.random.normal(key, (b, n), jnp.float32).astype(dtype)
    y_k = tt_linear_pallas(x, cores, spec, interpret=True).astype(jnp.float32)
    y_r = ref.tt_linear_staged(x, cores, spec).astype(jnp.float32)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    scale = float(jnp.max(jnp.abs(y_r))) or 1.0
    assert float(jnp.max(jnp.abs(y_k - y_r))) / scale < tol


def test_tt_kernel_paper_factorization(key):
    spec = TTSpec.make(4096, 13696, 16, in_modes=(8, 8, 8, 8), out_modes=(4, 4, 8, 107))
    cores = init_tt_linear(key, spec, jnp.float32)["cores"]
    x = jax.random.normal(key, (16, 4096))
    y_k = tt_linear_pallas(x, cores, spec, interpret=True)
    y_r = ref.tt_linear_staged(x, cores, spec)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), rtol=1e-4, atol=1e-4)


def test_tt_kernel_fused_bn_res_epilogue(key):
    """The paper's TTDLinear-BN-Res operator fusion (§III.A)."""
    spec = TTSpec.make(256, 512, 8, d=4)
    cores = init_tt_linear(key, spec, jnp.float32)["cores"]
    k1, k2, k3, k4 = jax.random.split(key, 4)
    x = jax.random.normal(k1, (10, 256))
    sc = jax.random.normal(k2, (512,))
    bi = jax.random.normal(k3, (512,))
    res = jax.random.normal(k4, (10, 512))
    y_k = tt_linear_pallas(x, cores, spec, scale=sc, bias=bi, residual=res, interpret=True)
    y_r = ref.tt_linear_bn_res(x, cores, spec, scale=sc, bias=bi, residual=res)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), rtol=1e-5, atol=1e-5)


def test_tt_kernel_bias_only_epilogue(key):
    """bias without scale must still be applied in-kernel (regression: the
    old epilogue only handled bias through the "bn" branch)."""
    spec = TTSpec.make(256, 512, 8, d=4)
    cores = init_tt_linear(key, spec, jnp.float32)["cores"]
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (10, 256))
    bi = jax.random.normal(k2, (512,))
    y_k = tt_linear_pallas(x, cores, spec, bias=bi, interpret=True)
    y_r = ref.tt_linear_bn_res(x, cores, spec, bias=bi)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), rtol=1e-5, atol=1e-5)
    # and the bias really landed (vs the silently-dropped behaviour)
    y_no = tt_linear_pallas(x, cores, spec, interpret=True)
    assert float(jnp.max(jnp.abs(y_k - (y_no + bi)))) < 1e-5
    assert float(jnp.max(jnp.abs(y_k - y_no))) > 1e-3


def test_tt_kernel_fused_activation(key):
    spec = TTSpec.make(256, 512, 8, d=4)
    cores = init_tt_linear(key, spec, jnp.float32)["cores"]
    x = jax.random.normal(key, (6, 256))
    y_k = tt_linear_pallas(x, cores, spec, activation="gelu", interpret=True)
    y_r = ref.tt_linear_bn_res(x, cores, spec, activation="gelu")
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), rtol=1e-5, atol=1e-5)


def test_tt_kernel_block_picker():
    spec = TTSpec.make(4096, 13696, 16, in_modes=(8, 8, 8, 8), out_modes=(4, 4, 8, 107))
    bb = pick_block_b(spec, 1024)
    assert bb >= 1 and (bb & (bb - 1)) == 0  # power of two
    per_token = (spec.n_in + spec.n_out + 2 * spec.max_intermediate()) * 4
    assert bb * per_token <= 12 * 2**20  # VMEM budget honored


def test_tt_kernel_block_picker_uses_dtype_bytes():
    """The VMEM footprint (cores included) must scale with the element size:
    halving dtype_bytes must never shrink the chosen block."""
    spec = TTSpec.make(4096, 13696, 16, in_modes=(8, 8, 8, 8), out_modes=(4, 4, 8, 107))
    bb4 = pick_block_b(spec, 4096, dtype_bytes=4)
    bb2 = pick_block_b(spec, 4096, dtype_bytes=2)
    assert bb2 >= bb4
    # fp16/bf16 budget accounting: cores also counted at dtype_bytes
    per_token = (spec.n_in + spec.n_out + 2 * spec.max_intermediate()) * 2
    assert bb2 * per_token + spec.n_params() * 2 <= 12 * 2**20


@pytest.mark.parametrize("b,block_b,dtype,use_res", [
    (7, 4, jnp.float32, True),    # pad 7 -> 8, residual padded too
    (13, 8, jnp.bfloat16, True),  # pad 13 -> 16
    (5, 8, jnp.float32, False),   # batch smaller than one block
    (9, 2, jnp.float32, True),    # odd batch, tiny block
])
def test_tt_kernel_padding_with_fused_epilogue(b, block_b, dtype, use_res, key):
    """Batch not divisible by block_b combined with the scale/bias(/residual)
    epilogue, checked against the kernels/ref.py oracle."""
    spec = TTSpec.make(256, 512, 8, d=4)
    cores = [c.astype(dtype) for c in init_tt_linear(key, spec, jnp.float32)["cores"]]
    k1, k2, k3, k4 = jax.random.split(key, 4)
    x = jax.random.normal(k1, (b, 256), jnp.float32).astype(dtype)
    sc = jax.random.normal(k2, (512,), jnp.float32).astype(dtype)
    bi = jax.random.normal(k3, (512,), jnp.float32).astype(dtype)
    res = jax.random.normal(k4, (b, 512), jnp.float32).astype(dtype) if use_res else None
    y_k = tt_linear_pallas(x, cores, spec, scale=sc, bias=bi, residual=res,
                           block_b=block_b, interpret=True)
    y_r = ref.tt_linear_bn_res(x, cores, spec, scale=sc, bias=bi, residual=res)
    assert y_k.shape == (b, 512)
    y_k32, y_r32 = y_k.astype(jnp.float32), y_r.astype(jnp.float32)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    scale_ref = float(jnp.max(jnp.abs(y_r32))) or 1.0
    assert float(jnp.max(jnp.abs(y_k32 - y_r32))) / scale_ref < tol


@pytest.mark.parametrize("b,k,m,g,dtype", [
    (8, 256, 128, 64, jnp.float32),
    (130, 4096, 300, 128, jnp.bfloat16),
    (1, 512, 512, 128, jnp.float32),
    (33, 1024, 96, 256, jnp.bfloat16),
])
def test_int4_kernel_matches_ref(b, k, m, g, dtype, key):
    w = np.random.randn(m, k).astype(np.float32)
    q = quantize_int4(w, g)
    x = jax.random.normal(key, (b, k), jnp.float32).astype(dtype)
    y_k = int4_matmul_pallas(x, q["qweight"], q["scales"], group=g, interpret=True)
    y_r = ref.int4_matmul(x, q["qweight"], q["scales"], group=g)
    scale = float(jnp.max(jnp.abs(y_r.astype(jnp.float32)))) or 1.0
    err = float(jnp.max(jnp.abs(y_k.astype(jnp.float32) - y_r.astype(jnp.float32))))
    assert err / scale < 2e-2


@pytest.mark.parametrize("b,k,m,use_scale", [
    (7, 256, 130, False),   # padded batch AND padded out-features
    (16, 256, 128, True),
])
def test_int4_kernel_fused_epilogue(b, k, m, use_scale, key):
    """int4 kernel's bias(/scale)+residual epilogue vs the oracle, including
    m-padding where epilogue columns must be padded alongside qweight."""
    g = 64
    w = np.random.randn(m, k).astype(np.float32)
    q = quantize_int4(w, g)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    x = jax.random.normal(k1, (b, k), jnp.float32)
    sc = jax.random.normal(k2, (m,)) if use_scale else None
    bi = jax.random.normal(k3, (m,))
    res = jax.random.normal(k4, (b, m))
    y_k = int4_matmul_pallas(x, q["qweight"], q["scales"], group=g, scale=sc,
                             bias=bi, residual=res, interpret=True)
    y_r = ref.int4_matmul(x, q["qweight"], q["scales"], group=g, scale=sc,
                          bias=bi, residual=res)
    assert y_k.shape == (b, m)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), rtol=1e-4, atol=1e-4)

"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import TTSpec, init_tt_linear, quantize_int4
from repro.kernels import dispatch, ref
from repro.kernels.int4_matmul import int4_matmul_pallas
from repro.kernels.paged_attention import paged_attention_pallas
from repro.kernels.prefill_attention import prefill_attention_pallas
from repro.kernels.scan_rglru import rglru_scan_pallas
from repro.kernels.scan_wkv import wkv_scan_pallas
from repro.kernels.tt_linear import pick_block_b, tt_linear_pallas
from repro.models.modules import attention_dense


@pytest.mark.parametrize("n,m,r,d,b,dtype", [
    (256, 512, 8, 4, 7, jnp.float32),
    (4096, 4096, 16, 4, 32, jnp.float32),   # paper LinearO
    (512, 256, 4, 3, 64, jnp.bfloat16),
    (64, 64, 2, 2, 1, jnp.float32),
    (2048, 5632, 8, 4, 13, jnp.bfloat16),   # tinyllama MLP shape
])
def test_tt_kernel_matches_ref(n, m, r, d, b, dtype, key):
    spec = TTSpec.make(n, m, r, d=d)
    cores = [c.astype(dtype) for c in init_tt_linear(key, spec, jnp.float32)["cores"]]
    x = jax.random.normal(key, (b, n), jnp.float32).astype(dtype)
    y_k = tt_linear_pallas(x, cores, spec, interpret=True).astype(jnp.float32)
    y_r = ref.tt_linear_staged(x, cores, spec).astype(jnp.float32)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    scale = float(jnp.max(jnp.abs(y_r))) or 1.0
    assert float(jnp.max(jnp.abs(y_k - y_r))) / scale < tol


def test_tt_kernel_paper_factorization(key):
    spec = TTSpec.make(4096, 13696, 16, in_modes=(8, 8, 8, 8), out_modes=(4, 4, 8, 107))
    cores = init_tt_linear(key, spec, jnp.float32)["cores"]
    x = jax.random.normal(key, (16, 4096))
    y_k = tt_linear_pallas(x, cores, spec, interpret=True)
    y_r = ref.tt_linear_staged(x, cores, spec)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), rtol=1e-4, atol=1e-4)


def test_tt_kernel_fused_bn_res_epilogue(key):
    """The paper's TTDLinear-BN-Res operator fusion (§III.A)."""
    spec = TTSpec.make(256, 512, 8, d=4)
    cores = init_tt_linear(key, spec, jnp.float32)["cores"]
    k1, k2, k3, k4 = jax.random.split(key, 4)
    x = jax.random.normal(k1, (10, 256))
    sc = jax.random.normal(k2, (512,))
    bi = jax.random.normal(k3, (512,))
    res = jax.random.normal(k4, (10, 512))
    y_k = tt_linear_pallas(x, cores, spec, scale=sc, bias=bi, residual=res, interpret=True)
    y_r = ref.tt_linear_bn_res(x, cores, spec, scale=sc, bias=bi, residual=res)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), rtol=1e-5, atol=1e-5)


def test_tt_kernel_bias_only_epilogue(key):
    """bias without scale must still be applied in-kernel (regression: the
    old epilogue only handled bias through the "bn" branch)."""
    spec = TTSpec.make(256, 512, 8, d=4)
    cores = init_tt_linear(key, spec, jnp.float32)["cores"]
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (10, 256))
    bi = jax.random.normal(k2, (512,))
    y_k = tt_linear_pallas(x, cores, spec, bias=bi, interpret=True)
    y_r = ref.tt_linear_bn_res(x, cores, spec, bias=bi)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), rtol=1e-5, atol=1e-5)
    # and the bias really landed (vs the silently-dropped behaviour)
    y_no = tt_linear_pallas(x, cores, spec, interpret=True)
    assert float(jnp.max(jnp.abs(y_k - (y_no + bi)))) < 1e-5
    assert float(jnp.max(jnp.abs(y_k - y_no))) > 1e-3


def test_tt_kernel_fused_activation(key):
    spec = TTSpec.make(256, 512, 8, d=4)
    cores = init_tt_linear(key, spec, jnp.float32)["cores"]
    x = jax.random.normal(key, (6, 256))
    y_k = tt_linear_pallas(x, cores, spec, activation="gelu", interpret=True)
    y_r = ref.tt_linear_bn_res(x, cores, spec, activation="gelu")
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), rtol=1e-5, atol=1e-5)


def test_tt_kernel_block_picker():
    spec = TTSpec.make(4096, 13696, 16, in_modes=(8, 8, 8, 8), out_modes=(4, 4, 8, 107))
    bb = pick_block_b(spec, 1024)
    assert bb >= 1 and (bb & (bb - 1)) == 0  # power of two
    per_token = (spec.n_in + spec.n_out + 2 * spec.max_intermediate()) * 4
    assert bb * per_token <= 12 * 2**20  # VMEM budget honored


def test_tt_kernel_block_picker_uses_dtype_bytes():
    """The VMEM footprint (cores included) must scale with the element size:
    halving dtype_bytes must never shrink the chosen block."""
    spec = TTSpec.make(4096, 13696, 16, in_modes=(8, 8, 8, 8), out_modes=(4, 4, 8, 107))
    bb4 = pick_block_b(spec, 4096, dtype_bytes=4)
    bb2 = pick_block_b(spec, 4096, dtype_bytes=2)
    assert bb2 >= bb4
    # fp16/bf16 budget accounting: cores also counted at dtype_bytes
    per_token = (spec.n_in + spec.n_out + 2 * spec.max_intermediate()) * 2
    assert bb2 * per_token + spec.n_params() * 2 <= 12 * 2**20


@pytest.mark.parametrize("b,block_b,dtype,use_res", [
    (7, 4, jnp.float32, True),    # pad 7 -> 8, residual padded too
    (13, 8, jnp.bfloat16, True),  # pad 13 -> 16
    (5, 8, jnp.float32, False),   # batch smaller than one block
    (9, 2, jnp.float32, True),    # odd batch, tiny block
])
def test_tt_kernel_padding_with_fused_epilogue(b, block_b, dtype, use_res, key):
    """Batch not divisible by block_b combined with the scale/bias(/residual)
    epilogue, checked against the kernels/ref.py oracle."""
    spec = TTSpec.make(256, 512, 8, d=4)
    cores = [c.astype(dtype) for c in init_tt_linear(key, spec, jnp.float32)["cores"]]
    k1, k2, k3, k4 = jax.random.split(key, 4)
    x = jax.random.normal(k1, (b, 256), jnp.float32).astype(dtype)
    sc = jax.random.normal(k2, (512,), jnp.float32).astype(dtype)
    bi = jax.random.normal(k3, (512,), jnp.float32).astype(dtype)
    res = jax.random.normal(k4, (b, 512), jnp.float32).astype(dtype) if use_res else None
    y_k = tt_linear_pallas(x, cores, spec, scale=sc, bias=bi, residual=res,
                           block_b=block_b, interpret=True)
    y_r = ref.tt_linear_bn_res(x, cores, spec, scale=sc, bias=bi, residual=res)
    assert y_k.shape == (b, 512)
    y_k32, y_r32 = y_k.astype(jnp.float32), y_r.astype(jnp.float32)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    scale_ref = float(jnp.max(jnp.abs(y_r32))) or 1.0
    assert float(jnp.max(jnp.abs(y_k32 - y_r32))) / scale_ref < tol


@pytest.mark.parametrize("b,k,m,g,dtype", [
    (8, 256, 128, 64, jnp.float32),
    (130, 4096, 300, 128, jnp.bfloat16),
    (1, 512, 512, 128, jnp.float32),
    (33, 1024, 96, 256, jnp.bfloat16),
])
def test_int4_kernel_matches_ref(b, k, m, g, dtype, key):
    w = np.random.randn(m, k).astype(np.float32)
    q = quantize_int4(w, g)
    x = jax.random.normal(key, (b, k), jnp.float32).astype(dtype)
    y_k = int4_matmul_pallas(x, q["qweight"], q["scales"], group=g, interpret=True)
    y_r = ref.int4_matmul(x, q["qweight"], q["scales"], group=g)
    scale = float(jnp.max(jnp.abs(y_r.astype(jnp.float32)))) or 1.0
    err = float(jnp.max(jnp.abs(y_k.astype(jnp.float32) - y_r.astype(jnp.float32))))
    assert err / scale < 2e-2


@pytest.mark.parametrize("b,k,m,use_scale", [
    (7, 256, 130, False),   # padded batch AND padded out-features
    (16, 256, 128, True),
])
def test_int4_kernel_fused_epilogue(b, k, m, use_scale, key):
    """int4 kernel's bias(/scale)+residual epilogue vs the oracle, including
    m-padding where epilogue columns must be padded alongside qweight."""
    g = 64
    w = np.random.randn(m, k).astype(np.float32)
    q = quantize_int4(w, g)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    x = jax.random.normal(k1, (b, k), jnp.float32)
    sc = jax.random.normal(k2, (m,)) if use_scale else None
    bi = jax.random.normal(k3, (m,))
    res = jax.random.normal(k4, (b, m))
    y_k = int4_matmul_pallas(x, q["qweight"], q["scales"], group=g, scale=sc,
                             bias=bi, residual=res, interpret=True)
    y_r = ref.int4_matmul(x, q["qweight"], q["scales"], group=g, scale=sc,
                          bias=bi, residual=res)
    assert y_k.shape == (b, m)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Paged decode attention (serve path) — kernel vs gather oracle vs dense math
# ---------------------------------------------------------------------------
def _paged_case(seed, *, block_size, ctx_lens, hkv=2, g=2, dh=16,
                cache_dtype=jnp.float32):
    """Random paged cache with each sequence's context scattered over a
    shuffled block pool; returns (q, cache, block_tables, qpos)."""
    rng = np.random.default_rng(seed)
    b, h = len(ctx_lens), hkv * g
    w = max(1, max((c + block_size - 1) // block_size for c in ctx_lens))
    nb = 1 + sum((c + block_size - 1) // block_size for c in ctx_lens) + 2
    shape = (nb, block_size, hkv, dh)
    if cache_dtype == jnp.int8:
        cache = {
            "k": jnp.asarray(rng.integers(-127, 128, shape), jnp.int8),
            "v": jnp.asarray(rng.integers(-127, 128, shape), jnp.int8),
            "k_scale": jnp.asarray(rng.uniform(0.005, 0.02, shape[:-1]), jnp.float32),
            "v_scale": jnp.asarray(rng.uniform(0.005, 0.02, shape[:-1]), jnp.float32),
        }
    else:
        cache = {
            "k": jnp.asarray(rng.standard_normal(shape), cache_dtype),
            "v": jnp.asarray(rng.standard_normal(shape), cache_dtype),
        }
    pool = list(rng.permutation(np.arange(1, nb)))
    bt = np.zeros((b, w), np.int32)
    for i, c in enumerate(ctx_lens):
        for j in range((c + block_size - 1) // block_size):
            bt[i, j] = pool.pop()
    q = jnp.asarray(rng.standard_normal((b, h, dh)), jnp.float32)
    qpos = jnp.asarray(np.asarray(ctx_lens, np.int32) - 1)
    return q, cache, jnp.asarray(bt), qpos


@pytest.mark.parametrize("block_size,ctx_lens,cache_dtype", [
    (4, (7, 4, 0, 1), jnp.float32),    # ragged last block + empty + singleton
    (8, (16, 3, 9), jnp.float32),      # exact block multiple + ragged
    (16, (5,), jnp.float32),           # context smaller than one block
    (4, (13, 8, 1), jnp.float16),
    (8, (12, 5), jnp.bfloat16),
    (4, (6, 2, 0), jnp.int8),          # per-block-scale dequant + empty seq
    (8, (17, 1), jnp.int8),
])
def test_paged_attention_kernel_parity(block_size, ctx_lens, cache_dtype):
    """Fused online-softmax kernel vs the gather oracle across block sizes ×
    seq lens × cache dtypes, including the ragged-last-block and
    empty-sequence (qpos = -1) edge cases."""
    q, cache, bt, qpos = _paged_case(block_size * 131 + len(ctx_lens),
                                     block_size=block_size, ctx_lens=ctx_lens,
                                     cache_dtype=cache_dtype)
    y_k = paged_attention_pallas(q, cache, bt, qpos, interpret=True)
    y_r = ref.paged_attention(q[:, None], cache, bt, qpos[:, None])[:, 0]
    tol = 1e-5 if cache_dtype in (jnp.float32, jnp.int8) else 3e-2
    scale = float(jnp.max(jnp.abs(y_r))) or 1.0
    assert float(jnp.max(jnp.abs(y_k - y_r))) / scale < tol
    # empty sequences must return exactly zero from both paths
    for i, c in enumerate(ctx_lens):
        if c == 0:
            assert float(jnp.max(jnp.abs(y_k[i]))) == 0.0
            assert float(jnp.max(jnp.abs(y_r[i]))) == 0.0


def test_paged_attention_dispatch_backends():
    """ref and pallas-interpret agree through the dispatch layer (the policy
    chain the serve engine pins)."""
    q, cache, bt, qpos = _paged_case(7, block_size=4, ctx_lens=(9, 2, 0))
    y_ref = dispatch.paged_attention(q, cache, bt, qpos, backend="ref")
    y_pl = dispatch.paged_attention(q, cache, bt, qpos, backend="pallas-interpret")
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_paged_ref_matches_dense_attention():
    """The gather oracle itself vs models.modules.attention_dense on a
    contiguous (identity block table) layout — ties the paged math back to
    the attention used everywhere else."""
    rng = np.random.default_rng(3)
    bs, ctx, hkv, g, dh = 4, 11, 2, 2, 16
    nb = 1 + (ctx + bs - 1) // bs
    k = rng.standard_normal((nb, bs, hkv, dh)).astype(np.float32)
    v = rng.standard_normal((nb, bs, hkv, dh)).astype(np.float32)
    cache = {"k": jnp.asarray(k), "v": jnp.asarray(v)}
    bt = jnp.asarray(np.arange(1, nb, dtype=np.int32)[None])  # in-order blocks
    q = jnp.asarray(rng.standard_normal((1, hkv * g, dh)), jnp.float32)
    y_p = ref.paged_attention(q[:, None], cache, bt, jnp.asarray([[ctx - 1]]))[:, 0]
    kf = jnp.asarray(k[1:].reshape(1, -1, hkv, dh))
    vf = jnp.asarray(v[1:].reshape(1, -1, hkv, dh))
    kpos = jnp.arange(kf.shape[1], dtype=jnp.int32)
    y_d = attention_dense(q[:, None], kf, vf, qpos=jnp.asarray([ctx - 1]),
                          kpos=kpos, kmask=kpos < ctx)[:, 0]
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_d),
                               rtol=1e-5, atol=1e-5)


def test_paged_int8_write_read_roundtrip():
    """paged_kv_update's int8 quantization round-trips through the oracle
    within int8 rounding error."""
    from repro.models.modules import paged_kv_update
    rng = np.random.default_rng(11)
    bs, hkv, dh = 4, 2, 8
    cache = {
        "k": jnp.zeros((4, bs, hkv, dh), jnp.int8),
        "v": jnp.zeros((4, bs, hkv, dh), jnp.int8),
        "k_scale": jnp.zeros((4, bs, hkv), jnp.float32),
        "v_scale": jnp.zeros((4, bs, hkv), jnp.float32),
    }
    bt = jnp.asarray([[1, 2]], jnp.int32)
    k_new = jnp.asarray(rng.standard_normal((1, 6, hkv, dh)), jnp.float32)
    v_new = jnp.asarray(rng.standard_normal((1, 6, hkv, dh)), jnp.float32)
    pos = jnp.arange(6, dtype=jnp.int32)[None]
    cache = paged_kv_update(cache, k_new, v_new, bt, pos)
    k_rt, v_rt = ref.gather_paged_kv(cache, bt)
    np.testing.assert_allclose(np.asarray(k_rt[0, :6]), np.asarray(k_new[0]),
                               atol=2e-2)
    np.testing.assert_allclose(np.asarray(v_rt[0, :6]), np.asarray(v_new[0]),
                               atol=2e-2)


# ---------------------------------------------------------------------------
# Ragged chunked-prefill flash attention — kernel vs the ref.py oracles over
# both cache layouts (paged block pools / per-slot rings)
# ---------------------------------------------------------------------------
def _prefill_qpos(ctx_lens, chunk):
    """(B, chunk) query positions: each row holds the last ``min(chunk, c)``
    positions of its sequence, tail-padded with -1 (idle rows all -1)."""
    qpos = np.full((len(ctx_lens), chunk), -1, np.int32)
    for i, c in enumerate(ctx_lens):
        n = min(chunk, c)
        qpos[i, :n] = np.arange(c - n, c)
    return jnp.asarray(qpos)


def _prefill_paged_case(seed, *, block_size, ctx_lens, chunk, hkv=2, g=2,
                        dh=16, cache_dtype=jnp.float32, q_dtype=jnp.float32):
    """Random paged pool covering every context position, shuffled block ids;
    returns (q, cache, block_tables, qpos)."""
    rng = np.random.default_rng(seed)
    b, h = len(ctx_lens), hkv * g
    w = max(1, max((c + block_size - 1) // block_size for c in ctx_lens))
    nb = 1 + sum((c + block_size - 1) // block_size for c in ctx_lens) + 2
    shape = (nb, block_size, hkv, dh)
    if cache_dtype == jnp.int8:
        cache = {
            "k": jnp.asarray(rng.integers(-127, 128, shape), jnp.int8),
            "v": jnp.asarray(rng.integers(-127, 128, shape), jnp.int8),
            "k_scale": jnp.asarray(rng.uniform(0.005, 0.02, shape[:-1]), jnp.float32),
            "v_scale": jnp.asarray(rng.uniform(0.005, 0.02, shape[:-1]), jnp.float32),
        }
    else:
        cache = {
            "k": jnp.asarray(rng.standard_normal(shape), cache_dtype),
            "v": jnp.asarray(rng.standard_normal(shape), cache_dtype),
        }
    pool = list(rng.permutation(np.arange(1, nb)))
    bt = np.zeros((b, w), np.int32)
    for i, c in enumerate(ctx_lens):
        for j in range((c + block_size - 1) // block_size):
            bt[i, j] = pool.pop()
    q = jnp.asarray(rng.standard_normal((b, chunk, h, dh)), jnp.float32).astype(q_dtype)
    return q, cache, jnp.asarray(bt), _prefill_qpos(ctx_lens, chunk)


def _prefill_ring_case(seed, *, ring_width, ctx_lens, chunk, hkv=2, g=2,
                       dh=16, cache_dtype=jnp.float32, q_dtype=jnp.float32):
    """Random per-slot rings in ring layout (position p at slot p % WR);
    returns (q, k, v, kpos, qpos)."""
    rng = np.random.default_rng(seed)
    b, h = len(ctx_lens), hkv * g
    k = jnp.asarray(rng.standard_normal((b, ring_width, hkv, dh)), cache_dtype)
    v = jnp.asarray(rng.standard_normal((b, ring_width, hkv, dh)), cache_dtype)
    kpos = np.full((b, ring_width), -1, np.int32)
    for i, c in enumerate(ctx_lens):
        for p in range(max(0, c - ring_width), c):
            kpos[i, p % ring_width] = p
    q = jnp.asarray(rng.standard_normal((b, chunk, h, dh)), jnp.float32).astype(q_dtype)
    return q, k, v, jnp.asarray(kpos), _prefill_qpos(ctx_lens, chunk)


def _assert_close(y_k, y_r, tol):
    y_k = jnp.asarray(y_k, jnp.float32)
    y_r = jnp.asarray(y_r, jnp.float32)
    scale = float(jnp.max(jnp.abs(y_r))) or 1.0
    assert float(jnp.max(jnp.abs(y_k - y_r))) / scale < tol


@pytest.mark.parametrize("block_size,ctx_lens,chunk,g,cache_dtype", [
    (4, (11, 3, 0), 5, 2, jnp.float32),    # ragged + idle row, mid-chunk
    (8, (16, 7, 1), 8, 1, jnp.float32),    # MHA (g=1), exact block multiple
    (4, (9, 2), 3, 4, jnp.float32),        # wide GQA group
    (4, (13, 5, 0), 6, 2, jnp.float16),
    (8, (12, 4), 7, 2, jnp.bfloat16),
    (4, (10, 1, 0), 4, 2, jnp.int8),       # fused per-slot-scale dequant
    (8, (17, 6), 9, 3, jnp.int8),
])
def test_prefill_attention_paged_parity(block_size, ctx_lens, chunk, g, cache_dtype):
    """Streaming prefill kernel vs the gather oracle: block sizes × context
    lens × chunk widths × GQA ratios × cache dtypes, with ragged tails,
    empty rows and shuffled block tables."""
    q_dtype = cache_dtype if cache_dtype in (jnp.float16, jnp.bfloat16) else jnp.float32
    q, cache, bt, qpos = _prefill_paged_case(
        block_size * 977 + chunk, block_size=block_size, ctx_lens=ctx_lens,
        chunk=chunk, g=g, cache_dtype=cache_dtype, q_dtype=q_dtype)
    y_k = prefill_attention_pallas(q, qpos, cache=cache, block_tables=bt,
                                   q_tile=4, interpret=True)
    y_r = ref.paged_attention(q, cache, bt, qpos)
    tol = 1e-5 if q_dtype == jnp.float32 else 3e-2
    _assert_close(y_k, y_r, tol)
    for i, c in enumerate(ctx_lens):
        if c == 0:  # idle rows are exactly zero on both paths
            assert float(jnp.max(jnp.abs(jnp.asarray(y_k, jnp.float32)[i]))) == 0.0
            assert float(jnp.max(jnp.abs(jnp.asarray(y_r, jnp.float32)[i]))) == 0.0


@pytest.mark.parametrize("ring_width,ctx_lens,chunk,g,window,cache_dtype", [
    (16, (11, 3, 0), 5, 2, 0, jnp.float32),    # full attention rings
    (12, (23, 9), 6, 2, 8, jnp.float32),       # SWA: ring wraps, window masks
    (8, (7, 2, 0), 4, 1, 4, jnp.float32),      # MHA + tiny window
    (16, (14, 5), 7, 4, 6, jnp.float32),       # wide GQA group + window
    (12, (19, 8, 1), 5, 2, 7, jnp.float16),
    (16, (21, 4), 8, 2, 9, jnp.bfloat16),
])
def test_prefill_attention_ring_parity(ring_width, ctx_lens, chunk, g, window,
                                       cache_dtype):
    """Streaming prefill kernel vs the ring oracle: ring widths × context
    lens × chunk widths × GQA ratios × sliding windows × cache dtypes,
    including wrapped rings and empty rows."""
    q_dtype = cache_dtype if cache_dtype in (jnp.float16, jnp.bfloat16) else jnp.float32
    q, k, v, kpos, qpos = _prefill_ring_case(
        ring_width * 389 + chunk, ring_width=ring_width, ctx_lens=ctx_lens,
        chunk=chunk, g=g, cache_dtype=cache_dtype, q_dtype=q_dtype)
    y_k = prefill_attention_pallas(q, qpos, k=k, v=v, kpos=kpos, window=window,
                                   q_tile=3, kv_tile=5, interpret=True)
    y_r = ref.ring_attention(q, k, v, qpos, kpos, window=window)
    tol = 1e-5 if q_dtype == jnp.float32 else 3e-2
    _assert_close(y_k, y_r, tol)


def test_prefill_attention_all_idle_rows():
    """A fully idle batch (every qpos -1) walks zero blocks and returns
    exactly zero from the kernel and both oracles."""
    q, cache, bt, _ = _prefill_paged_case(5, block_size=4, ctx_lens=(8, 3),
                                          chunk=4)
    qpos = jnp.full((2, 4), -1, jnp.int32)
    for y in (prefill_attention_pallas(q, qpos, cache=cache, block_tables=bt),
              ref.paged_attention(q, cache, bt, qpos)):
        assert float(jnp.max(jnp.abs(y))) == 0.0
    q, k, v, kpos, _ = _prefill_ring_case(6, ring_width=8, ctx_lens=(6, 2),
                                          chunk=4)
    for y in (prefill_attention_pallas(q, qpos, k=k, v=v, kpos=kpos),
              ref.ring_attention(q, k, v, qpos, kpos)):
        assert float(jnp.max(jnp.abs(y))) == 0.0


def test_prefill_attention_dispatch_backends():
    """ref and pallas-interpret agree through dispatch.prefill_attention for
    both layouts (the policy chain the serve engine pins)."""
    q, cache, bt, qpos = _prefill_paged_case(17, block_size=4,
                                             ctx_lens=(9, 2, 0), chunk=4)
    y_ref = dispatch.prefill_attention(q, qpos, cache=cache, block_tables=bt,
                                       backend="ref")
    y_pl = dispatch.prefill_attention(q, qpos, cache=cache, block_tables=bt,
                                      backend="pallas-interpret")
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    q, k, v, kpos, qpos = _prefill_ring_case(18, ring_width=10,
                                             ctx_lens=(13, 4, 0), chunk=5)
    y_ref = dispatch.prefill_attention(q, qpos, k=k, v=v, kpos=kpos, window=6,
                                       backend="ref")
    y_pl = dispatch.prefill_attention(q, qpos, k=k, v=v, kpos=kpos, window=6,
                                      backend="pallas-interpret")
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    with pytest.raises(ValueError, match="exactly one layout"):
        dispatch.prefill_attention(q, qpos, backend="ref")
    with pytest.raises(ValueError, match="exactly one layout"):
        dispatch.prefill_attention(q, qpos, cache=cache, block_tables=bt,
                                   k=k, v=v, kpos=kpos, backend="ref")
    with pytest.raises(ValueError, match="paged layout needs"):
        dispatch.prefill_attention(q, qpos, cache=cache, backend="ref")
    with pytest.raises(ValueError, match="ring layout needs"):
        dispatch.prefill_attention(q, qpos, k=k, v=v, backend="ref")


def test_prefill_ring_oracle_matches_dense_attention():
    """The ring oracle vs models.modules.attention_dense on an unwrapped
    (identity-layout) ring — ties the ragged per-sequence math back to the
    attention used everywhere else, including the window mask."""
    rng = np.random.default_rng(21)
    ctx, chunk, hkv, g, dh, win = 9, 4, 2, 2, 16, 5
    k = jnp.asarray(rng.standard_normal((1, ctx, hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, ctx, hkv, dh)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((1, chunk, hkv * g, dh)), jnp.float32)
    pos = jnp.arange(ctx, dtype=jnp.int32)
    qpos = pos[None, ctx - chunk:]
    y_o = ref.ring_attention(q, k, v, qpos, pos[None], window=win)
    y_d = attention_dense(q, k, v, qpos=qpos[0], kpos=pos, causal=True,
                          window=win)
    np.testing.assert_allclose(np.asarray(y_o), np.asarray(y_d),
                               rtol=1e-5, atol=1e-5)


def test_prefill_chunk_session_parity_ref_vs_interpret():
    """End-to-end: a full multi-layer chunked-prefill step (paged AND ring
    state backends) produces matching logits under ref and pallas-interpret
    — the exact programs serve.steps jits for the engine."""
    from repro.configs import get_config
    from repro.kernels.dispatch import backend_override
    from repro.models import build_model
    from repro.models.sessions import SessionSpec, make_session

    cfg = get_config("tinyllama-1.1b", reduced=True).replace(
        compute_dtype="float32", param_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    spec = SessionSpec(slots=2, max_len=32, prefill_chunk=8, block_size=4)
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
    pos = np.full((2, 8), -1, np.int32)
    pos[0, :8] = np.arange(8)
    pos[1, :3] = np.arange(3)  # ragged second row
    pos = jnp.asarray(pos)
    for backend in ("paged", "ring"):
        session = make_session(cfg, spec, backend=backend)
        state = session.init_state()
        if backend == "paged":
            bt = np.zeros((2, spec.table_width()), np.int32)
            bt[0, :2], bt[1, :2] = (1, 2), (3, 4)
            state = session.with_tables(state, bt)
        outs = {}
        for kb in ("ref", "pallas-interpret"):
            with backend_override(kb):
                logits, _ = session.prefill_chunk(params, state, toks, pos)
            outs[kb] = np.asarray(logits)
        np.testing.assert_allclose(outs["pallas-interpret"], outs["ref"],
                                   rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Recurrent-scan kernels (RG-LRU / wkv) — Pallas kernels vs the kernels/ref.py
# oracles across dtypes × tile/chunk widths × ragged/idle rows, then ref vs
# pallas-interpret through the dispatch layer and a full session-level sweep.
# ---------------------------------------------------------------------------
def _scan_pos(ctx_lens, s):
    """(B, S) positions: row i holds ``min(ctx_lens[i], s)`` real steps then
    -1 padding (0-length rows are fully idle)."""
    pos = np.full((len(ctx_lens), s), -1, np.int32)
    for i, c in enumerate(ctx_lens):
        n = min(c, s)
        pos[i, :n] = np.arange(n)
    return jnp.asarray(pos)


@pytest.mark.parametrize("s,w,ctx_lens,scan_dtype,tt,wt", [
    (8, 16, (8, 3, 0), jnp.float32, 4, 8),      # ragged + idle row
    (16, 40, (16, 16), jnp.float32, 16, 128),   # full rows, tile wider than W
    (7, 24, (7, 2, 0), jnp.float32, 4, 16),     # odd S padded to token tile
    (12, 48, (12, 5), jnp.bfloat16, 8, 32),     # bf16 scan carries
    (6, 8, (0, 0), jnp.float32, 2, 8),          # fully-idle batch
])
def test_rglru_scan_prefill_parity(s, w, ctx_lens, scan_dtype, tt, wt, key):
    """Chunked-prefill RG-LRU kernel vs the associative-scan oracle across
    scan dtypes × token/width tiles × ragged and fully-idle rows."""
    b = len(ctx_lens)
    k1, k2, k3 = jax.random.split(key, 3)
    log_a = -jnp.abs(jax.random.normal(k1, (b, s, w))) * 0.5
    gx = jax.random.normal(k2, (b, s, w))
    h0 = jax.random.normal(k3, (b, w))
    pos = _scan_pos(ctx_lens, s)
    h_k, hl_k = rglru_scan_pallas(log_a, gx, h0, pos, scan_dtype=scan_dtype,
                                  token_tile=tt, width_tile=wt, interpret=True)
    h_r, hl_r = ref.rglru_scan(log_a, gx, h0, pos, scan_dtype=scan_dtype)
    tol = 3e-2 if scan_dtype == jnp.bfloat16 else 1e-5
    _assert_close(h_k, h_r, tol)
    _assert_close(hl_k, hl_r, tol)
    # idle rows keep their carried state bitwise (f32 h_last path)
    for i, c in enumerate(ctx_lens):
        if c == 0:
            np.testing.assert_array_equal(np.asarray(hl_k[i]), np.asarray(h0[i]))
            np.testing.assert_array_equal(np.asarray(hl_r[i]), np.asarray(h0[i]))


def test_rglru_scan_no_positions_matches_masked_all_real(key):
    """pos=None (training path) must equal an all-real position grid."""
    b, s, w = 2, 8, 16
    k1, k2, k3 = jax.random.split(key, 3)
    log_a = -jnp.abs(jax.random.normal(k1, (b, s, w))) * 0.5
    gx = jax.random.normal(k2, (b, s, w))
    h0 = jax.random.normal(k3, (b, w))
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h_n, hl_n = rglru_scan_pallas(log_a, gx, h0, None, interpret=True)
    h_p, hl_p = rglru_scan_pallas(log_a, gx, h0, pos, interpret=True)
    np.testing.assert_allclose(np.asarray(h_n), np.asarray(h_p), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(hl_n), np.asarray(hl_p), rtol=1e-6, atol=1e-6)


def test_rglru_scan_decode_step_parity(key):
    """Fused masked decode step (S == 1): active rows advance, inactive rows
    keep their state bitwise, vs the oracle."""
    b, w = 4, 24
    k1, k2, k3 = jax.random.split(key, 3)
    log_a = -jnp.abs(jax.random.normal(k1, (b, 1, w))) * 0.5
    gx = jax.random.normal(k2, (b, 1, w))
    h0 = jax.random.normal(k3, (b, w))
    pos = jnp.asarray([[5], [-1], [0], [-1]], jnp.int32)
    h_k, hl_k = rglru_scan_pallas(log_a, gx, h0, pos, width_tile=16,
                                  interpret=True)
    h_r, hl_r = ref.rglru_scan(log_a, gx, h0, pos)
    _assert_close(h_k, h_r, 1e-6)
    _assert_close(hl_k, hl_r, 1e-6)
    for i in (1, 3):  # inactive slots: bitwise passthrough
        np.testing.assert_array_equal(np.asarray(hl_k[i]), np.asarray(h0[i]))


@pytest.mark.parametrize("s,h,hd,ctx_lens,chunk,int8", [
    (16, 2, 8, (16, 7, 0), 16, False),    # one exact chunk + ragged + idle
    (20, 2, 8, (20, 3), 16, False),       # ragged tail pads to 2 chunks
    (5, 1, 16, (5, 0), 16, False),        # prompt shorter than one chunk
    (24, 3, 8, (24, 11, 2), 8, False),    # narrow chunk, three slots
    (16, 2, 8, (16, 5, 0), 16, True),     # int8 state round-trip
    (9, 2, 16, (9, 1), 8, True),          # int8 + ragged pad
])
def test_wkv_scan_prefill_parity(s, h, hd, ctx_lens, chunk, int8, key):
    """Chunked wkv prefill kernel vs the masked oracle across chunk widths ×
    ragged/idle rows × f32/int8 state."""
    b = len(ctx_lens)
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, hd)) * 2 - 1) * 0.98 + 0.01
    u = jax.random.normal(ks[4], (h, hd)) * 0.1
    pos = _scan_pos(ctx_lens, s)
    if int8:
        s0f = jax.random.normal(key, (b, h, hd, hd)) * 0.3
        s0, sc0 = ref.quantize_state(s0f)
    else:
        s0 = jax.random.normal(key, (b, h, hd, hd)) * 0.3
        sc0 = None
    y_k, st_k, sc_k = wkv_scan_pallas(r, k, v, w, u, s0, pos, state_scale=sc0,
                                      chunk=chunk, interpret=True)
    y_r, st_r, sc_r = ref.wkv_scan(r, k, v, w, u, s0, pos, state_scale=sc0,
                                   chunk=chunk)
    _assert_close(y_k, y_r, 1e-5)
    if int8:
        # compare dequantized states; quantization boundaries may flip one
        # int8 step where the f32 values straddle a rounding edge
        d_k = np.asarray(st_k, np.float32) * np.asarray(sc_k)[..., None, None]
        d_r = np.asarray(st_r, np.float32) * np.asarray(sc_r)[..., None, None]
        atol = 2.0 * float(np.max(np.asarray(sc_r)))
        np.testing.assert_allclose(d_k, d_r, atol=atol)
        for i, c in enumerate(ctx_lens):
            if c == 0:  # idle rows: int8 payload AND scale bitwise-preserved
                np.testing.assert_array_equal(np.asarray(st_k[i]), np.asarray(s0[i]))
                np.testing.assert_array_equal(np.asarray(sc_k[i]), np.asarray(sc0[i]))
    else:
        assert sc_k is None and sc_r is None
        _assert_close(st_k, st_r, 1e-5)


def test_wkv_scan_decode_step_parity(key):
    """Fused masked decode step (S == 1) vs the sequential oracle, f32 and
    int8 state, with inactive slots bitwise-preserving payload and scale."""
    b, h, hd = 3, 2, 8
    ks = jax.random.split(key, 5)
    shape = (b, 1, h, hd)
    r = jax.random.normal(ks[0], shape)
    k = jax.random.normal(ks[1], shape)
    v = jax.random.normal(ks[2], shape)
    w = jax.nn.sigmoid(jax.random.normal(ks[3], shape)) * 0.98 + 0.01
    u = jax.random.normal(ks[4], (h, hd)) * 0.1
    pos = jnp.asarray([[4], [-1], [0]], jnp.int32)
    s0f = jax.random.normal(key, (b, h, hd, hd)) * 0.3
    y_k, st_k, _ = wkv_scan_pallas(r, k, v, w, u, s0f, pos, interpret=True)
    y_r, st_r, _ = ref.wkv_scan(r, k, v, w, u, s0f, pos)
    _assert_close(y_k, y_r, 1e-6)
    _assert_close(st_k, st_r, 1e-6)

    q0, sc0 = ref.quantize_state(s0f)
    yq, stq, scq = wkv_scan_pallas(r, k, v, w, u, q0, pos, state_scale=sc0,
                                   interpret=True)
    assert stq.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(stq[1]), np.asarray(q0[1]))
    np.testing.assert_array_equal(np.asarray(scq[1]), np.asarray(sc0[1]))


def test_scan_dispatch_backends(key):
    """ref and pallas-interpret agree through dispatch.rglru_scan /
    dispatch.wkv_scan (the policy chain the serve engine pins), and the
    dispatch-layer shape/scale validation raises."""
    b, s, w = 2, 8, 16
    k1, k2, k3 = jax.random.split(key, 3)
    log_a = -jnp.abs(jax.random.normal(k1, (b, s, w))) * 0.5
    gx = jax.random.normal(k2, (b, s, w))
    h0 = jax.random.normal(k3, (b, w))
    pos = _scan_pos((8, 3), s)
    h_ref, hl_ref = dispatch.rglru_scan(log_a, gx, h0, pos, backend="ref")
    h_pl, hl_pl = dispatch.rglru_scan(log_a, gx, h0, pos,
                                      backend="pallas-interpret")
    np.testing.assert_allclose(np.asarray(h_pl), np.asarray(h_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(hl_pl), np.asarray(hl_ref),
                               rtol=1e-5, atol=1e-5)

    h2, hd = 2, 8
    ks = jax.random.split(key, 5)
    shape = (b, s, h2, hd)
    r = jax.random.normal(ks[0], shape)
    kk = jax.random.normal(ks[1], shape)
    v = jax.random.normal(ks[2], shape)
    ww = jax.nn.sigmoid(jax.random.normal(ks[3], shape)) * 0.98 + 0.01
    u = jax.random.normal(ks[4], (h2, hd)) * 0.1
    s0 = jax.random.normal(key, (b, h2, hd, hd)) * 0.3
    y_ref, st_ref, _ = dispatch.wkv_scan(r, kk, v, ww, u, s0, pos, backend="ref")
    y_pl, st_pl, _ = dispatch.wkv_scan(r, kk, v, ww, u, s0, pos,
                                       backend="pallas-interpret")
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_pl), np.asarray(st_ref),
                               rtol=1e-5, atol=1e-5)

    with pytest.raises(ValueError, match="log_a/gx"):
        dispatch.rglru_scan(log_a, gx[:, :-1], h0, backend="ref")
    with pytest.raises(ValueError, match="h0 must be"):
        dispatch.rglru_scan(log_a, gx, h0[:, :-1], backend="ref")
    with pytest.raises(ValueError, match="share one"):
        dispatch.wkv_scan(r, kk[:, :-1], v, ww, u, s0, backend="ref")
    with pytest.raises(ValueError, match="state0 must be"):
        dispatch.wkv_scan(r, kk, v, ww, u, s0[:, :, :-1], backend="ref")
    with pytest.raises(ValueError, match="state_scale"):
        dispatch.wkv_scan(r, kk, v, ww, u, s0.astype(jnp.int8), backend="ref")
    with pytest.raises(ValueError, match="state_scale"):
        dispatch.wkv_scan(r, kk, v, ww, u, s0,
                          state_scale=jnp.ones((b, h2)), backend="ref")


@pytest.mark.parametrize("arch", ["recurrentgemma-2b", "rwkv6-7b"])
def test_recurrent_session_parity_ref_vs_interpret(arch):
    """End-to-end: a full multi-layer recurrent session (griffin / rwkv)
    produces matching prefill AND decode logits under ref and
    pallas-interpret — the exact programs serve.steps jits for the engine."""
    from repro.configs import get_config
    from repro.kernels.dispatch import backend_override
    from repro.models import build_model
    from repro.models.sessions import SessionSpec, make_session

    cfg = get_config(arch, reduced=True).replace(
        compute_dtype="float32", param_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    spec = SessionSpec(slots=2, max_len=32, prefill_chunk=8, block_size=4)
    session = make_session(cfg, spec)
    rng = np.random.default_rng(9)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
    pos = np.full((2, 8), -1, np.int32)
    pos[0, :8] = np.arange(8)
    pos[1, :3] = np.arange(3)  # ragged second row
    pos = jnp.asarray(pos)
    dt = jnp.asarray([[7], [11]], jnp.int32)
    dp = jnp.asarray([8, 3], jnp.int32)
    outs = {}
    for kb in ("ref", "pallas-interpret"):
        state = session.init_state()
        with backend_override(kb):
            plog, state = session.prefill_chunk(params, state, toks, pos)
            dlog, _ = session.decode_step(params, state, dt, dp)
        outs[kb] = (np.asarray(plog), np.asarray(dlog))
    np.testing.assert_allclose(outs["pallas-interpret"][0], outs["ref"][0],
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(outs["pallas-interpret"][1], outs["ref"][1],
                               rtol=2e-4, atol=2e-4)

"""Compression → serving integration (DESIGN.md §11).

``compress_model`` output must flow through ``make_session`` /
``serve.steps.session_step_fns`` / the engine unchanged: TT-core, int4 and
TT-embedding leaves are ordinary traced arguments inside the jitted step
programs, and compression specs ride the *config* (so differently-compressed
engines get distinct step-cache entries, never a stale program).  The fuzz
here is the compressed counterpart of tests/test_serve_fuzz.py: seeded
schedules with preemption, paged + ring backends, ref vs pallas-interpret,
token-identical to the one-request reference.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import QuantConfig, TTDConfig
from repro.configs import get_config
from repro.core.compress import compress_model
from repro.models import build_model
from repro.serve.engine import Engine
from repro.serve.kv_cache import blocks_for

MAX_LEN = 96
_MAX_NEW = 5

_CACHE: dict = {}


def _dense_setup(arch="tinyllama-1.1b"):
    if arch not in _CACHE:
        cfg = get_config(arch, reduced=True).replace(
            compute_dtype="float32", param_dtype="float32",
            ttd=TTDConfig(enabled=False), quant=QuantConfig(enabled=False))
        model = build_model(cfg)
        _CACHE[arch] = (cfg, model, model.init(jax.random.PRNGKey(0)))
    return _CACHE[arch]


def _target_cfg(arch="tinyllama-1.1b", *, int4=False, embed=False,
                kernel_backend=None):
    cfg = get_config(arch, reduced=True).replace(
        compute_dtype="float32", param_dtype="float32")
    if int4:
        cfg = cfg.replace(quant=QuantConfig(enabled=True, bits=4,
                                            group_size=32))
    if embed:
        cfg = cfg.replace(ttd=dataclasses.replace(cfg.ttd, embed=True))
    if kernel_backend is not None:
        cfg = cfg.replace(kernel_backend=kernel_backend)
    return cfg


def _compressed(target):
    """Compressed params for ``target`` (cached per compression spec —
    kernel_backend doesn't change the tree)."""
    key = ("params", target.ttd, target.quant)
    if key not in _CACHE:
        dense_cfg, _, dense_params = _dense_setup(target.name)
        _CACHE[key] = compress_model(dense_params, dense_cfg, target)
    return _CACHE[key]


def _reference(model, params, prompt, max_tokens):
    """Greedy one-request continuation via model.prefill + decode_step."""
    logits, cache = model.prefill(
        params, {"tokens": jnp.asarray([prompt], jnp.int32)},
        cache_dtype=jnp.float32, max_len=MAX_LEN)
    out = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    for _ in range(max_tokens - 1):
        logits, cache = model.decode_step(
            params, cache, {"tokens": jnp.asarray([[out[-1]]], jnp.int32)},
            jnp.int32(pos))
        out.append(int(jnp.argmax(logits[0])))
        pos += 1
    return out


def _schedule(seed):
    rng = np.random.default_rng(3000 + seed)
    reqs = []
    for _ in range(int(rng.integers(3, 6))):
        plen = int(rng.integers(1, 11))
        prompt = [int(t) for t in rng.integers(0, 256, plen)]
        reqs.append((int(rng.integers(0, 5)), prompt,
                     int(rng.integers(1, _MAX_NEW + 1))))
    return sorted(reqs)


def _drive(engine, sched):
    handles, t, pending = [], 0, list(sched)
    while pending or engine.pending():
        while pending and pending[0][0] <= t:
            _, prompt, max_tokens = pending.pop(0)
            handles.append(engine.submit(prompt, max_tokens=max_tokens))
        engine.tick()
        t += 1
        assert t < 2000, "scheduler stalled"
    return handles


# ---------------------------------------------------------------------------
# Satellite: step-fn memoization across compression specs
# ---------------------------------------------------------------------------
def test_step_cache_distinct_compression_specs():
    """Serving the same architecture twice under different compression specs
    must produce two step-cache entries (the spec rides the cfg in the memo
    key), and each engine's tokens must match its own solo reference."""
    from repro.serve.steps import session_step_fns

    prompts = [[1, 2, 3], [7, 5], [2, 2, 9, 4]]
    engines = {}
    for name, target in (("tt", _target_cfg()),
                         ("tt_int4", _target_cfg(int4=True))):
        params = _compressed(target)
        model = build_model(target)
        eng = Engine(model, params, slots=2, max_len=MAX_LEN, prefill_chunk=8)
        for p in prompts:
            eng.submit(p, max_tokens=4)
        got = [h.out_tokens for h in eng.run()]
        want = [_reference(model, params, p, 4) for p in prompts]
        assert got == want, (name, got, want)
        engines[name] = eng

    fns_tt = session_step_fns(engines["tt"].session)
    assert session_step_fns(engines["tt"].session) is fns_tt  # memo hit
    fns_q = session_step_fns(engines["tt_int4"].session)
    assert fns_tt is not fns_q  # distinct specs -> distinct programs
    assert engines["tt"].session.step_key != engines["tt_int4"].session.step_key


def test_cache_leaf_rule_rejects_param_leaves():
    """The cache sharding walk is state-only: a compressed param tree fed to
    it must fail loudly (params go through dist.sharding), not silently
    replicate TT cores / int4 scales."""
    from jax.sharding import Mesh

    from repro.serve.steps import cache_pspecs

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    state_like = {"k": jax.ShapeDtypeStruct((2, 4, 8, 2, 16), jnp.float32)}
    cache_pspecs(state_like, mesh)  # state names pass
    for bad in ({"attn": {"wo": {"cores": [jax.ShapeDtypeStruct((8, 16), jnp.float32)]}}},
                {"wq": {"qweight": jax.ShapeDtypeStruct((64, 32), jnp.int8),
                        "scales": jax.ShapeDtypeStruct((64, 2), jnp.float32)}},
                {"embed": {"table": jax.ShapeDtypeStruct((256, 64), jnp.float32)}}):
        with pytest.raises(ValueError, match="param leaf"):
            cache_pspecs(bad, mesh)


# ---------------------------------------------------------------------------
# Tentpole: compressed serve fuzz (token-identity vs one-request reference)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed,engine_backend", [
    (0, "paged"), (1, "ring"), (2, "paged"), (3, "ring"),
])
def test_compressed_serve_fuzz(seed, engine_backend):
    """TT+int4 tinyllama through fuzzed schedules with tight block pools
    (preemption + recompute re-admission): multi-slot tokens must be
    identical to the solo reference, and identical between the ref and
    pallas-interpret kernel backends."""
    sched = _schedule(seed)
    got = {}
    for kb in ("ref", "pallas-interpret"):
        target = _target_cfg(int4=True, kernel_backend=kb)
        params = _compressed(target)
        model = build_model(target)
        max_seq = max(len(p) for _, p, _ in sched) + _MAX_NEW + 1
        kw = dict(slots=2, max_len=MAX_LEN, block_size=4,
                  prefill_batch=2, prefill_chunk=8, backend=engine_backend)
        if engine_backend == "paged":
            kw["num_blocks"] = blocks_for(max_seq, 4) + 3  # tight: preempts
        eng = Engine(model, params, **kw)
        toks = [h.out_tokens for h in _drive(eng, sched)]
        want = [_reference(model, params, p, m) for _, p, m in sched]
        assert toks == want, (kb, toks, want)
        if eng.manager is not None:
            assert eng.manager.num_free == eng.manager.num_blocks - 1
            assert eng.manager.live_tokens() == 0
        got[kb] = toks
    assert got["ref"] == got["pallas-interpret"]


def test_tt_embed_serve_matches_reference():
    """TT-embedding compression serves through chunked prefill + ragged
    decode and stays token-identical to the solo reference."""
    target = _target_cfg(embed=True)
    params = _compressed(target)
    assert "cores" in params["embed"] and "table" not in params["embed"]
    model = build_model(target)
    sched = _schedule(7)
    for backend in ("paged", "ring"):
        eng = Engine(model, params, slots=2, max_len=MAX_LEN,
                     prefill_chunk=8, backend=backend)
        toks = [h.out_tokens for h in _drive(eng, sched)]
        want = [_reference(model, params, p, m) for _, p, m in sched]
        assert toks == want, (backend, toks, want)


# ---------------------------------------------------------------------------
# TT-embedding parity: oracle vs Pallas kernel vs dense gather
# ---------------------------------------------------------------------------
def test_tt_embedding_parity():
    from repro.core.ttd import TTSpec, cores_to_matrices, tt_svd
    from repro.kernels import dispatch, ref

    V, D = 240, 48
    spec = TTSpec.make(D, V, 10**6, d=3)  # full rank -> exact rows
    rng = np.random.default_rng(0)
    table = rng.standard_normal((V, D)).astype(np.float32)
    cores = [jnp.asarray(m, jnp.float32)
             for m in cores_to_matrices(tt_svd(table, spec), spec)]
    # ragged/padded rows: -1 ids must resolve exactly like the dense path's
    # jnp.take (negative wrap), or padded prefill rows would diverge
    ids = np.array([[0, 5, V - 1, -1, -1],
                    [17, -1, 3, 2, 1],
                    [-1, -1, -1, -1, -1]], np.int32)
    want = jnp.take(jnp.asarray(table), jnp.asarray(ids), axis=0)
    got_ref = ref.tt_embedding(jnp.asarray(ids), cores, spec)
    assert float(jnp.abs(got_ref - want).max()) < 1e-4
    got_pl = dispatch.tt_embed(jnp.asarray(ids), cores, spec,
                               backend="pallas-interpret")
    assert float(jnp.abs(got_pl - got_ref).max()) < 1e-6
    assert dispatch.resolved_backend("embed_lookup") == "pallas-interpret"
    # 1-D and scalar-free shapes route through the same path
    flat = dispatch.tt_embed(jnp.asarray([3, -1, 9], jnp.int32), cores, spec,
                             backend="ref")
    assert flat.shape == (3, D)


def test_embed_lookup_requires_cfg():
    from repro.models.modules import embed_lookup

    with pytest.raises(ValueError, match="ttd.embed"):
        embed_lookup({"cores": []}, jnp.zeros((1,), jnp.int32), jnp.float32)


def test_full_rank_tt_embed_forward_exact(key):
    """Full-rank TT embedding reproduces the dense model's hidden states."""
    dense_cfg, m_d, params_d = _dense_setup()
    target = dense_cfg.replace(ttd=TTDConfig(enabled=True, rank=10**6, d=2,
                                             roles=(), embed=True))
    params_t = compress_model(params_d, dense_cfg, target, svd_method="svd")
    m_t = build_model(target)
    toks = jax.random.randint(key, (2, 12), 0, dense_cfg.vocab_size)
    h_d, _ = m_d.forward(params_d, {"tokens": toks})
    h_t, _ = m_t.forward(params_t, {"tokens": toks})
    assert float(jnp.linalg.norm(h_d - h_t) / jnp.linalg.norm(h_d)) < 1e-4


def test_tied_tt_embedding_unembeds_through_cores(key):
    """Tied configs route logits through the TT unembed (the cores ARE the
    head); the dense head_weight accessor refuses clearly."""
    base = get_config("tinyllama-1.1b", reduced=True).replace(
        compute_dtype="float32", param_dtype="float32", tie_embeddings=True)
    dense_cfg = base.replace(ttd=TTDConfig(enabled=False),
                             quant=QuantConfig(enabled=False))
    target = base.replace(ttd=TTDConfig(enabled=True, rank=10**6, d=2,
                                        roles=(), embed=True))
    m_d = build_model(dense_cfg)
    params_d = m_d.init(key)
    params_t = compress_model(params_d, dense_cfg, target, svd_method="svd")
    m_t = build_model(target)
    toks = jax.random.randint(key, (1, 8), 0, base.vocab_size)
    l_d, _ = m_d.prefill(params_d, {"tokens": toks}, cache_dtype=jnp.float32)
    l_t, _ = m_t.prefill(params_t, {"tokens": toks}, cache_dtype=jnp.float32)
    assert float(jnp.linalg.norm(l_d - l_t) / jnp.linalg.norm(l_d)) < 1e-3
    with pytest.raises(ValueError, match="no dense head weight"):
        m_t.head_weight(params_t)

"""Continuous-batching engine vs direct decode reference."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import get_model
from repro.serve.engine import Engine


def _ref_generate(model, params, prompt, n):
    """Greedy generation via prefill + decode_step directly."""
    toks = jnp.asarray([prompt], jnp.int32)
    logits, cache = model.prefill(params, {"tokens": toks}, cache_dtype=jnp.float32,
                                  max_len=96)
    out = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    for _ in range(n - 1):
        logits, cache = model.decode_step(params, cache,
                                          {"tokens": jnp.asarray([[out[-1]]], jnp.int32)},
                                          jnp.int32(pos))
        out.append(int(jnp.argmax(logits[0])))
        pos += 1
    return out


def test_engine_matches_reference(key):
    cfg = get_config("tinyllama-1.1b", reduced=True).replace(
        compute_dtype="float32", param_dtype="float32")
    model = get_model(cfg)
    params = model.init(key)
    prompt = [3, 1, 4, 1, 5]
    ref = _ref_generate(model, params, prompt, 6)
    eng = Engine(model, params, slots=2, max_len=96)
    req = eng.submit(prompt, max_tokens=6)
    eng.run()
    assert req.out_tokens == ref


def test_engine_sampling_seeded(key):
    """greedy=False honors temperature/top-k with a seeded PRNG: same seed
    reproduces, top_k=1 degenerates to argmax."""
    cfg = get_config("tinyllama-1.1b", reduced=True).replace(
        compute_dtype="float32", param_dtype="float32")
    model = get_model(cfg)
    params = model.init(key)
    prompt = [3, 1, 4, 1, 5]

    def gen(**kw):
        eng = Engine(model, params, slots=2, max_len=96, **kw)
        req = eng.submit(prompt, max_tokens=6)
        eng.run()
        return req.out_tokens

    ref = gen(greedy=True)
    a = gen(greedy=False, temperature=0.8, seed=7)
    b = gen(greedy=False, temperature=0.8, seed=7)
    assert a == b  # seeded: reproducible
    assert gen(greedy=False, top_k=1, temperature=2.0) == ref
    # high-temperature sampling across seeds must eventually diverge from
    # greedy (vocab 256, 6 tokens — astronomically unlikely to all match)
    draws = [gen(greedy=False, temperature=100.0, seed=s) for s in range(4)]
    assert any(d != ref for d in draws)


def test_engine_continuous_batching(key):
    cfg = get_config("tinyllama-1.1b", reduced=True).replace(
        compute_dtype="float32", param_dtype="float32")
    model = get_model(cfg)
    params = model.init(key)
    eng = Engine(model, params, slots=2, max_len=96)
    prompts = [[1, 2, 3], [4, 5, 6, 7], [8, 9], [10, 11, 12]]
    reqs = [eng.submit(p, max_tokens=5) for p in prompts]
    done = eng.run()
    assert len(done) == 4
    for p, r in zip(prompts, reqs):
        assert r.out_tokens == _ref_generate(model, params, p, 5), p

"""Unified session engine vs direct decode reference."""
import time

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve.engine import Engine, PagedEngine  # analyze: allow[deprecated-api] deprecation-pinning test


def _ref_generate(model, params, prompt, n):
    """Greedy generation via prefill + decode_step directly."""
    toks = jnp.asarray([prompt], jnp.int32)
    logits, cache = model.prefill(params, {"tokens": toks}, cache_dtype=jnp.float32,
                                  max_len=96)
    out = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    for _ in range(n - 1):
        logits, cache = model.decode_step(params, cache,
                                          {"tokens": jnp.asarray([[out[-1]]], jnp.int32)},
                                          jnp.int32(pos))
        out.append(int(jnp.argmax(logits[0])))
        pos += 1
    return out


def test_engine_matches_reference(key):
    cfg = get_config("tinyllama-1.1b", reduced=True).replace(
        compute_dtype="float32", param_dtype="float32")
    model = build_model(cfg)
    params = model.init(key)
    prompt = [3, 1, 4, 1, 5]
    ref = _ref_generate(model, params, prompt, 6)
    eng = Engine(model, params, slots=2, max_len=96)
    req = eng.submit(prompt, max_tokens=6)
    eng.run()
    assert req.out_tokens == ref


def test_engine_sampling_seeded(key):
    """greedy=False honors temperature/top-k with a seeded PRNG: same seed
    reproduces, top_k=1 degenerates to argmax."""
    cfg = get_config("tinyllama-1.1b", reduced=True).replace(
        compute_dtype="float32", param_dtype="float32")
    model = build_model(cfg)
    params = model.init(key)
    prompt = [3, 1, 4, 1, 5]

    def gen(**kw):
        eng = Engine(model, params, slots=2, max_len=96, **kw)
        req = eng.submit(prompt, max_tokens=6)
        eng.run()
        return req.out_tokens

    ref = gen(greedy=True)
    a = gen(greedy=False, temperature=0.8, seed=7)
    b = gen(greedy=False, temperature=0.8, seed=7)
    assert a == b  # seeded: reproducible
    assert gen(greedy=False, top_k=1, temperature=2.0) == ref
    # high-temperature sampling across seeds must eventually diverge from
    # greedy (vocab 256, 6 tokens — astronomically unlikely to all match)
    draws = [gen(greedy=False, temperature=100.0, seed=s) for s in range(4)]
    assert any(d != ref for d in draws)


def test_engine_continuous_batching(key):
    cfg = get_config("tinyllama-1.1b", reduced=True).replace(
        compute_dtype="float32", param_dtype="float32")
    model = build_model(cfg)
    params = model.init(key)
    eng = Engine(model, params, slots=2, max_len=96)
    prompts = [[1, 2, 3], [4, 5, 6, 7], [8, 9], [10, 11, 12]]
    reqs = [eng.submit(p, max_tokens=5) for p in prompts]
    done = eng.run()
    assert len(done) == 4
    for p, r in zip(prompts, reqs):
        assert r.out_tokens == _ref_generate(model, params, p, 5), p


def _tiny():
    cfg = get_config("tinyllama-1.1b", reduced=True).replace(
        compute_dtype="float32", param_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


@pytest.mark.parametrize("backend", ["paged", "ring"])
def test_t_first_stamped_after_device_sync(backend, monkeypatch):
    """Regression: first-token latency must be timed after the device
    finishes prefill, not when the async dispatch returns.  We slow down
    ``jax.block_until_ready`` and record when each sync completed; t_first
    must be at or after the first completed sync."""
    model, params = _tiny()
    real_sync = jax.block_until_ready
    sync_done = []

    def slow_sync(x):
        out = real_sync(x)
        time.sleep(0.02)
        # t_first is a perf_counter stamp — compare in the same clock domain
        sync_done.append(time.perf_counter())
        return out

    monkeypatch.setattr(jax, "block_until_ready", slow_sync)
    eng = Engine(model, params, slots=2, max_len=96, block_size=8,
                 backend=backend)
    req = eng.submit([3, 1, 4], max_tokens=3)
    eng.run()
    assert sync_done, "engine never synced before stamping t_first"
    assert req.t_first >= sync_done[0]
    assert req.t_submit < req.t_first <= req.t_done


@pytest.mark.parametrize("cache_dtype,exact", [
    ("float32", True), ("float16", False), ("int8", False),
])
def test_paged_engine_cache_dtypes(cache_dtype, exact):
    """fp16/int8 paged caches serve plausible tokens (exact parity only for
    the f32 cache; lossy caches must still finish every request)."""
    model, params = _tiny()
    prompts = [[1, 2, 3], [4, 5, 6, 7]]
    ref = Engine(model, params, slots=1, max_len=64, block_size=4)
    ref_reqs = [ref.submit(p, max_tokens=5) for p in prompts]
    ref.run()
    eng = Engine(model, params, slots=2, max_len=64, block_size=4,
                 cache_dtype=cache_dtype)
    reqs = [eng.submit(p, max_tokens=5) for p in prompts]
    eng.run()
    for r, rr in zip(reqs, ref_reqs):
        assert r.done and len(r.out_tokens) == 5
        assert all(0 <= t < model.cfg.vocab_size for t in r.out_tokens)
        if exact:
            assert r.out_tokens == rr.out_tokens


def test_submit_validation():
    """Empty prompts and requests that could never fit the pool are rejected
    at submit (not as a mid-run engine crash)."""
    model, params = _tiny()
    eng = Engine(model, params, slots=1, max_len=64, block_size=4,
                 num_blocks=3)  # 2 usable blocks = 8 positions
    with pytest.raises(ValueError):
        eng.submit([], max_tokens=2)
    with pytest.raises(ValueError):
        eng.submit([1, 2, 3], max_tokens=0)
    with pytest.raises(ValueError):  # worst case 10 tokens -> 3 blocks > 2
        eng.submit([1] * 8, max_tokens=2)
    # a request that fits the pool exactly is fine and completes
    req = eng.submit([1, 2, 3, 4], max_tokens=4)  # worst 8 tokens = 2 blocks
    eng.run()
    assert req.done and len(req.out_tokens) == 4


def test_paged_minimal_pool_single_sequence():
    """The smallest admissible pool serves a request end-to-end: admission's
    +1 lookahead and on-demand growth never hit the unreachable-deadlock
    path (regression for admission lacking the lookahead check)."""
    model, params = _tiny()
    eng = Engine(model, params, slots=1, max_len=64, block_size=4,
                 num_blocks=4)  # 3 usable blocks = 12 positions
    ref = Engine(model, params, slots=1, max_len=64, block_size=4)
    r = eng.submit([1, 2, 3, 4, 5, 6, 7, 8], max_tokens=4)  # worst 12 tokens
    rr = ref.submit([1, 2, 3, 4, 5, 6, 7, 8], max_tokens=4)
    eng.run()
    ref.run()
    assert r.done and r.out_tokens == rr.out_tokens
    assert eng.manager.num_free == eng.manager.num_blocks - 1


def test_rejects_overlong_prompt():
    """Every backend rejects prompts that don't fit ``max_len`` instead of
    silently serving them from a cropped state."""
    model, params = _tiny()
    eng = Engine(model, params, slots=1, max_len=16, block_size=4)
    with pytest.raises(ValueError):
        eng.submit(list(range(1, 18)), max_tokens=2)
    req = eng.submit(list(range(1, 12)), max_tokens=3)
    eng.run()
    assert req.done and len(req.out_tokens) == 3


def test_paged_engine_alias_still_serves():
    """The deprecated PagedEngine alias keeps its old constructor surface."""
    model, params = _tiny()
    # analyze: allow[deprecated-api] the alias's own regression test
    eng = PagedEngine(model, params, slots=2, max_len=96, block_size=8,
                      prefill_batch=2, prefill_chunk=8)
    req = eng.submit([3, 1, 4], max_tokens=4)
    eng.run()
    assert req.out_tokens == _ref_generate(model, params, [3, 1, 4], 4)


# ---------------------------------------------------------------------------
# Cancellation, deadlines, admission policy, drained reuse (sync engine)
# ---------------------------------------------------------------------------
def test_deadline_validation_and_expiry():
    model, params = _tiny()
    eng = Engine(model, params, slots=1, max_len=64, block_size=4)
    for bad in (0, -0.5):
        with pytest.raises(ValueError, match="deadline_s"):
            eng.submit([1, 2, 3], max_tokens=2, deadline_s=bad)
    assert not eng.pending()  # rejected before enqueue
    doomed = eng.submit([1, 2, 3], max_tokens=4, deadline_s=1e-9)
    ok = eng.submit([4, 5, 6], max_tokens=4)
    done = eng.run()
    assert doomed.cancelled and doomed.finish_reason == "deadline"
    assert ok.done and not ok.cancelled and len(ok.out_tokens) == 4
    assert {r.rid for r in done} == {doomed.rid, ok.rid}


def test_cancel_active_request_frees_blocks_for_waiter():
    """Cancelling a mid-flight request releases its slot and blocks; emitted
    tokens are kept; a waiting request then serves identically to running
    alone."""
    model, params = _tiny()
    eng = Engine(model, params, slots=1, max_len=64, block_size=4,
                 num_blocks=12, prefill_chunk=8)
    victim = eng.submit([1, 2, 3], max_tokens=30)
    waiter = eng.submit([4, 5, 6], max_tokens=4)
    for _ in range(4):  # admit + a few decode ticks
        eng.tick()
    assert not victim.done and eng.slot_req[0] is victim
    n_before = len(victim.out_tokens)
    assert eng.cancel(victim)
    assert victim.cancelled and victim.finish_reason == "user"
    assert victim.out_tokens == \
        _ref_generate(model, params, [1, 2, 3], n_before)
    assert eng.slot_req[0] is None
    assert eng.manager.num_free == eng.manager.num_blocks - 1
    eng.run()
    assert waiter.out_tokens == _ref_generate(model, params, [4, 5, 6], 4)
    assert eng.cancel(victim) is False  # cancelling a done request: no-op
    assert eng.manager.num_free == eng.manager.num_blocks - 1


def test_cancel_queued_request_never_admits():
    model, params = _tiny()
    eng = Engine(model, params, slots=1, max_len=64, block_size=4)
    active = eng.submit([1, 2, 3], max_tokens=6)
    queued = eng.submit([4, 5, 6], max_tokens=6)
    eng.tick()  # admits only the first (one slot)
    assert eng.cancel(queued)
    eng.run()
    assert queued.cancelled and queued.out_tokens == []
    assert active.done and len(active.out_tokens) == 6


def test_edf_admission_prefers_nearest_deadline():
    from repro.serve.engine import EDFAdmission, FCFSAdmission

    model, params = _tiny()
    eng = Engine(model, params, slots=1, max_len=64, block_size=4,
                 admission=EDFAdmission())
    late = eng.submit([1, 2, 3], max_tokens=2, deadline_s=60.0)
    soon = eng.submit([4, 5, 6], max_tokens=2, deadline_s=30.0)
    free = eng.submit([7, 8, 9], max_tokens=2)  # deadline-free goes last
    assert [r.rid for r in eng.admission.order(list(eng.queue), 0.0)] == \
        [soon.rid, late.rid, free.rid]
    eng.tick()  # one slot: EDF admits the nearest deadline first
    assert eng.slot_req[0] is soon or soon.done
    eng.run()
    assert all(r.done and not r.cancelled for r in (late, soon, free))
    # FCFS is insensitive to deadlines
    assert [r.rid for r in FCFSAdmission().order([late, soon, free], 0.0)] \
        == [late.rid, soon.rid, free.rid]


def test_run_returns_only_new_finishes_after_drain():
    """A drained engine stays usable, and run() never replays the previous
    batch's requests in its return value."""
    model, params = _tiny()
    eng = Engine(model, params, slots=1, max_len=64, block_size=4)
    first = eng.submit([1, 2, 3], max_tokens=3)
    done1 = eng.run()
    assert [r.rid for r in done1] == [first.rid]
    second = eng.submit([4, 5, 6], max_tokens=3)
    done2 = eng.run()
    assert [r.rid for r in done2] == [second.rid]
    assert second.out_tokens == _ref_generate(model, params, [4, 5, 6], 3)
    assert len(eng.finished) == 2  # cumulative history still intact

"""Typed InferenceSession / StateBackend API: capability declarations,
construction errors, state geometry, and the get_model deprecation shim."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import (  # analyze: allow[deprecated-api] deprecation-pinning test
    FAMILY_BACKENDS,
    SessionSpec,
    build_model,
    default_backend,
    get_model,
    make_session,
)

SPEC = SessionSpec(slots=2, max_len=32, prefill_chunk=8, block_size=4)


def _cfg(arch, **kw):
    return get_config(arch, reduced=True).replace(
        compute_dtype="float32", param_dtype="float32", **kw)


def test_capability_matrix_covers_all_families():
    assert set(FAMILY_BACKENDS) == {"dense", "moe", "griffin", "rwkv", "encdec"}
    for fam, backends in FAMILY_BACKENDS.items():
        assert backends, fam


def test_default_backends():
    assert default_backend(_cfg("tinyllama-1.1b")) == "paged"
    assert default_backend(_cfg("mixtral-8x22b")) == "ring"  # SWA
    assert default_backend(_cfg("recurrentgemma-2b")) == "recurrent"
    assert default_backend(_cfg("rwkv6-7b")) == "recurrent"
    assert default_backend(_cfg("whisper-base")) == "encdec"


def test_unsupported_backend_names_family():
    """The old hasattr probe is gone: asking for a backend a family doesn't
    implement raises NotImplementedError naming the family."""
    with pytest.raises(NotImplementedError, match="rwkv"):
        make_session(_cfg("rwkv6-7b"), SPEC, backend="paged")
    with pytest.raises(NotImplementedError, match="griffin"):
        make_session(_cfg("recurrentgemma-2b"), SPEC, backend="ring")
    with pytest.raises(NotImplementedError, match="encdec"):
        make_session(_cfg("whisper-base"), SPEC, backend="paged")
    # SWA cannot go through block pools — the error points at rings
    with pytest.raises(NotImplementedError, match="window"):
        make_session(_cfg("mixtral-8x22b"), SPEC, backend="paged")
    # M-RoPE positions are not position-addressable yet
    with pytest.raises(NotImplementedError, match="mrope"):
        make_session(_cfg("qwen2-vl-7b"), SPEC)


def test_session_state_geometry():
    paged = make_session(_cfg("tinyllama-1.1b"), SPEC)
    state = paged.init_state()
    seg = state["kv"][0]
    nb = SPEC.resolved_num_blocks()
    assert seg["k"].shape[1:3] == (nb, SPEC.block_size)
    assert state["block_tables"].shape == (SPEC.slots, SPEC.table_width())

    ring = make_session(_cfg("tinyllama-1.1b"), SPEC, backend="ring")
    rseg = ring.init_state()["kv"][0]
    assert rseg["k"].shape[1] == SPEC.slots  # per-slot rings
    assert rseg["pos"].shape[1:] == (SPEC.slots, rseg["k"].shape[2])

    # int8 paged pools carry per-(block-slot, head) scale tables
    spec8 = SessionSpec(slots=2, max_len=16, block_size=4, num_blocks=8,
                        cache_dtype="int8")
    seg8 = make_session(_cfg("tinyllama-1.1b"), spec8).init_state()["kv"][0]
    assert seg8["k"].dtype == jnp.int8
    assert seg8["k_scale"].shape == seg8["k"].shape[:-1]

    rec = make_session(_cfg("rwkv6-7b"), SPEC)
    rstate = rec.init_state()
    assert rstate["wkv"].shape[1] == SPEC.slots  # constant-size per slot

    enc = make_session(_cfg("whisper-base"), SPEC)
    estate = enc.init_state()
    cfg = enc.cfg
    assert estate["cross"]["k"].shape == (
        cfg.n_layers, SPEC.slots, cfg.enc_len, cfg.n_heads, cfg.head_dim)


def test_session_uniform_surface_shapes():
    """prefill_chunk / decode_step return (B,C,V) / (B,V) logits for every
    backend, with -1 positions marking idle rows."""
    for arch in ("tinyllama-1.1b", "rwkv6-7b"):
        cfg = _cfg(arch)
        sess = make_session(cfg, SPEC)
        params = build_model(cfg).init(jax.random.PRNGKey(0))
        state = sess.init_state()
        if sess.uses_blocks:
            # slot 0 owns blocks 1,2 (8 positions)
            bt = jnp.zeros((SPEC.slots, SPEC.table_width()), jnp.int32)
            state = sess.with_tables(state, bt.at[0, :2].set(jnp.asarray([1, 2])))
        toks = jnp.asarray([[5, 6, 7, 0, 0, 0, 0, 0], [0] * 8], jnp.int32)
        pos = jnp.asarray([[0, 1, 2, -1, -1, -1, -1, -1], [-1] * 8], jnp.int32)
        logits, state = sess.prefill_chunk(params, state, toks, pos)
        assert logits.shape == (2, 8, cfg.vocab_size)
        dl, state = sess.decode_step(params, state,
                                     jnp.asarray([[9], [0]], jnp.int32),
                                     jnp.asarray([3, -1], jnp.int32))
        assert dl.shape == (2, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(dl[0])))


def test_get_model_deprecated():
    cfg = _cfg("tinyllama-1.1b")
    with pytest.warns(DeprecationWarning, match="build_model"):
        model = get_model(cfg)  # analyze: allow[deprecated-api] asserts the warning itself
    assert model.cfg is cfg
    # the Model protocol no longer carries probe-able paged fields
    assert not hasattr(model, "init_paged_cache")


def test_chunked_prefill_all_empty_rows():
    """Regression: an all-empty/``None`` prompt batch used to crash
    ``chunked_prefill`` with StopIteration (no row ever produced a filler
    logit); it must return a correctly-shaped zero-logits batch instead."""
    import numpy as np

    from repro.serve.steps import chunked_prefill

    cfg = _cfg("tinyllama-1.1b")
    for backend in ("paged", "ring"):
        sess = make_session(cfg, SPEC, backend=backend)
        params = build_model(cfg).init(jax.random.PRNGKey(0))
        state = sess.init_state()
        logits, state2 = chunked_prefill(sess.prefill_chunk, params, state,
                                         [None, []], chunk=SPEC.prefill_chunk)
        assert logits.shape == (SPEC.slots, cfg.vocab_size)
        assert float(jnp.max(jnp.abs(logits))) == 0.0
        if backend == "ring":
            # ring writes at position -1 are dropped outright, so the idle
            # chunk must leave the state bitwise untouched
            jax.tree.map(lambda a, b: np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b)), state, state2)


def test_int8_cache_accepted_behind_scale_tables():
    """Every state backend now carries per-slot scale tables for int8 —
    construction succeeds and the state exposes quantized payloads next to
    f32 scales (the raw-cast corruption that forced the old rejection is
    structurally impossible)."""
    import dataclasses

    spec8 = dataclasses.replace(SPEC, cache_dtype="int8")
    ring = make_session(_cfg("tinyllama-1.1b"), spec8, backend="ring")
    rseg = ring.init_state()["kv"][0]
    assert rseg["k"].dtype == jnp.int8
    assert rseg["k_scale"].shape == rseg["k"].shape[:-1]
    assert rseg["k_scale"].dtype == jnp.float32

    rwkv_s = make_session(_cfg("rwkv6-7b"), spec8).init_state()
    assert rwkv_s["wkv"].dtype == jnp.int8
    assert rwkv_s["wkv_scale"].shape == rwkv_s["wkv"].shape[:3]
    assert rwkv_s["x_tm"].dtype == jnp.float32  # token-shift tails stay float

    grif_s = make_session(_cfg("recurrentgemma-2b"), spec8).init_state()
    recs = [s for s in grif_s["tail"] if "conv" in s]
    recs += [s for s in grif_s.get("groups", {}).values() if "conv" in s]
    assert recs
    for s in recs:
        assert s["conv"].dtype == jnp.int8
        assert s["conv_scale"].shape == s["conv"].shape[:-1]
        assert s["h"].dtype == jnp.float32  # the RG-LRU carry stays f32

    # block-pool backends keep supporting it (per-slot scale tables exist)
    assert make_session(_cfg("tinyllama-1.1b"), spec8, backend="paged")


def test_int8_cache_rejected_without_scale_support(monkeypatch):
    """The hard error survives for any backend outside INT8_SCALED_BACKENDS
    (a resolved backend without scale tables must fail at construction, not
    corrupt tokens deep inside a jitted step)."""
    import dataclasses

    from repro.models import sessions as sess_mod

    monkeypatch.setattr(sess_mod, "INT8_SCALED_BACKENDS", ("paged", "encdec"))
    spec8 = dataclasses.replace(SPEC, cache_dtype="int8")
    with pytest.raises(NotImplementedError, match="int8"):
        make_session(_cfg("tinyllama-1.1b"), spec8, backend="ring")
    with pytest.raises(NotImplementedError, match="int8"):
        make_session(_cfg("rwkv6-7b"), spec8)


def test_paged_engine_alias_warns():
    from repro.models import build_model as _bm  # noqa: F401  (import guard)
    from repro.serve.engine import PagedEngine  # analyze: allow[deprecated-api] deprecation-pinning test

    cfg = _cfg("tinyllama-1.1b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.warns(DeprecationWarning, match="PagedEngine"):
        PagedEngine(model, params, slots=2, max_len=32, block_size=4)  # analyze: allow[deprecated-api] asserts the warning itself

"""BlockManager / block-table packing invariants.

Deterministic unit tests always run; the randomized-op-sequence property
test uses hypothesis when installed (optional-skip like the dist tests).
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: skip only the property-based tests
    from conftest import given, settings, st  # noqa: F401

from repro.serve.kv_cache import BlockManager, blocks_for


def _check_invariants(m: BlockManager):
    """Pool conservation, disjoint ownership, null block never handed out."""
    owned = [b for sid in m.seq_ids() for b in m.table(sid)]
    assert len(owned) == len(set(owned)), "block double-allocated"
    assert 0 not in owned, "null block handed out"
    assert m.num_free + len(owned) == m.num_blocks - 1, "pool leak"
    for sid in m.seq_ids():
        assert len(m.table(sid)) * m.block_size >= m.seq_len(sid)
    assert m.live_tokens() == sum(m.seq_len(s) for s in m.seq_ids())


def test_blocks_for():
    assert blocks_for(0, 4) == 0
    assert blocks_for(1, 4) == 1
    assert blocks_for(4, 4) == 1
    assert blocks_for(5, 4) == 2


def test_no_double_alloc_and_free_returns_all():
    m = BlockManager(8, 4)  # 7 usable
    assert m.allocate(1, 9)   # 3 blocks
    assert m.allocate(2, 8)   # 2 blocks
    _check_invariants(m)
    assert m.num_free == 2
    assert set(m.table(1)).isdisjoint(m.table(2))
    freed = m.free(1)
    assert len(freed) == 3
    assert m.num_free == 5
    _check_invariants(m)
    m.free(2)
    assert m.num_free == 7
    assert m.live_tokens() == 0


def test_allocate_is_atomic_when_short():
    m = BlockManager(4, 2)  # 3 usable
    assert m.allocate(1, 4)  # 2 blocks
    assert not m.allocate(2, 5)  # needs 3 > 1 free: refuse, allocate nothing
    assert 2 not in m.seq_ids()
    assert m.num_free == 1
    _check_invariants(m)


def test_ensure_grows_and_is_atomic():
    m = BlockManager(5, 2)  # 4 usable
    assert m.allocate(1, 2)  # 1 block
    assert m.ensure(1, 3)    # grow to 2 blocks
    assert len(m.table(1)) == 2
    assert m.ensure(1, 3)    # idempotent
    assert len(m.table(1)) == 2
    assert m.allocate(2, 4)  # takes remaining 2
    assert not m.ensure(1, 7)  # needs 2 more, 0 free
    assert len(m.table(1)) == 2
    _check_invariants(m)


def test_double_register_rejected():
    m = BlockManager(4, 2)
    assert m.allocate(1, 2)
    with pytest.raises(ValueError):
        m.allocate(1, 2)


def test_utilization_matches_live_tokens():
    m = BlockManager(16, 4)
    m.allocate(1, 6)   # 2 blocks, 8 slots
    m.allocate(2, 4)   # 1 block, 4 slots
    assert m.live_tokens() == 10
    assert m.allocated_slots() == 12
    assert m.utilization() == pytest.approx(10 / 12)
    m.ensure(1, 7)
    assert m.utilization() == pytest.approx(11 / 12)
    m.free(1)
    assert m.utilization() == pytest.approx(1.0)
    m.free(2)
    assert m.utilization() == 0.0


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 5),
                          st.integers(0, 17)), max_size=40),
       st.integers(3, 12), st.integers(1, 5))
def test_block_manager_random_ops(ops, num_blocks, block_size):
    """Random alloc/ensure/free sequences keep every invariant: no block is
    owned twice, frees return everything, accounting matches live tokens."""
    m = BlockManager(num_blocks, block_size)
    for op, sid, n in ops:
        if op == 0 and sid not in m.seq_ids():
            free_before = m.num_free
            ok = m.allocate(sid, n)
            assert ok == (blocks_for(n, block_size) <= free_before)
        elif op == 1 and sid in m.seq_ids():
            before = len(m.table(sid))
            if not m.ensure(sid, n):
                assert len(m.table(sid)) == before  # atomic
        elif op == 2 and sid in m.seq_ids():
            owned = set(m.table(sid))
            freed = m.free(sid)
            assert set(freed) == owned
        _check_invariants(m)


def test_block_table_packing():
    from repro.serve.kv_cache import pack_block_tables

    m = BlockManager(8, 4)
    assert m.allocate(7, 6)  # 2 blocks
    bt = pack_block_tables(m, [7, None], table_width=4)
    assert bt.shape == (2, 4)
    assert list(bt[0, :2]) == m.table(7)
    assert (bt[0, 2:] == 0).all() and (bt[1] == 0).all()  # null-padded

"""Fixture: an acknowledged sync carrying an inline allow (suppressed)."""
import numpy as np


def decode_step(tokens):
    # analyze: allow[host-sync] fixture: acknowledged pull overlapped with next tick
    return np.asarray(tokens)

"""Fixture: un-annotated device syncs in a model decode body (SYNC001)."""
import jax
import jax.numpy as jnp
import numpy as np


def decode_step(params, cache, tokens):
    logits = jnp.dot(tokens, params)
    jax.block_until_ready(logits)
    tok = float(jnp.argmax(logits))
    host = np.asarray(logits)
    return host, tok, logits[0].item()

"""Fixture: statically-sized tile footprint over the VMEM budget (PAL004)."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 2048


def _k(x_ref, w_ref, o_ref):
    o_ref[...] = x_ref[...] @ w_ref[...]


def big_matmul(x, w):
    return pl.pallas_call(
        _k,
        grid=(2, 2),
        in_specs=[pl.BlockSpec((TILE, TILE), lambda i, j: (i, 0)),
                  pl.BlockSpec((TILE, TILE), lambda i, j: (0, j))],
        out_specs=pl.BlockSpec((TILE, TILE), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((2 * TILE, 2 * TILE),
                                       jnp.float32))(x, w)

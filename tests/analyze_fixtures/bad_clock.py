"""Fixture: wall-clock in duration math (CLK001)."""
import time


def run(step):
    t0 = time.time()
    step()
    return time.time() - t0

"""Fixture: BlockSpec index-map arity != grid rank (PAL002)."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _k(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def tile(x):
    return pl.pallas_call(
        _k,
        grid=(4, 4),
        in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((32, 32), jnp.float32))(x)

"""Fixture: module-level @jax.jit reading mutable module state (JIT003)."""
import jax

_SCALE = {"value": 2.0}


@jax.jit
def scaled(x):
    return x * _SCALE["value"]

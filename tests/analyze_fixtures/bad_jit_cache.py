"""Fixture: jitted closure capture missing from the memo key (JIT001)."""
import jax

_CACHE = {}


def step_fns(session, backend):
    key = (session.step_key,)
    if key not in _CACHE:
        def _decode(x, _s=session):
            return _s.decode(x, backend)
        _CACHE[key] = jax.jit(_decode)
    return _CACHE[key]

"""Fixture: Python-side effects inside a kernel body (PAL003)."""
import random

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _k(x_ref, o_ref):
    print("tracing", x_ref.shape)
    o_ref[...] = x_ref[...] * random.random()


def noisy(x):
    return pl.pallas_call(
        _k,
        grid=(1,),
        in_specs=[pl.BlockSpec(x.shape, lambda i: (0,))],
        out_specs=pl.BlockSpec(x.shape, lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32))(x)

"""Fixture: literal block shape does not divide out_shape (PAL005)."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _k(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def ragged(x):
    return pl.pallas_call(
        _k,
        grid=(4,),
        in_specs=[pl.BlockSpec((8,), lambda i: (i,))],
        out_specs=pl.BlockSpec((8,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((100,), jnp.float32))(x)

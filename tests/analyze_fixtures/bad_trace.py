"""Fixture: emit sites that disagree with EVENT_FIELDS (TRACE001-003)."""


class Loop:
    def __init__(self, obs):
        self.obs = obs

    def tick(self, n):
        self.obs.event("bogus_event", tick=n)
        self.obs.event("decode_tick", tick=n, active=2, surprise=True)
        self.obs.event("finish", rid=1, tick=n)

"""Fixture: a decode body that stays async (no SYNC001)."""
import jax.numpy as jnp


def decode_step(params, cache, tokens):
    logits = jnp.dot(tokens, params)
    return jnp.argmax(logits, axis=-1), logits

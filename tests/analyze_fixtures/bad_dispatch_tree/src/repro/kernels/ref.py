"""Fixture ref.py: deliberately missing most oracles."""


def wkv_scan(r, k, v, w, u, state):
    return r, state

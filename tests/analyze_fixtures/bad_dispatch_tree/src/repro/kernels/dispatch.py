"""Fixture dispatch.py: a half-registered kernel zoo (DISP00x findings)."""


def _record_dispatch(role, backend, out, t0):
    return out


def resolve_backend(explicit=None, *, role=""):
    return explicit or "ref"


def tt_linear(x, cores, spec, backend=None, role="tt"):
    # no resolve_backend (DISP003), no _record_dispatch (DISP002), and the
    # oracle/kernel legs are missing from this tree (DISP004/DISP005)
    return x


def mystery_op(x, backend=None):
    # obs-wired dispatcher the registry does not know (DISP007)
    backend = resolve_backend(backend)
    return _record_dispatch("mystery", backend, x, 0)

"""Fixture: a Pallas kernel nobody routes + a role typo."""
from .dispatch import paged_attention  # noqa: F401


def orphan_pallas(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def use(x):
    return paged_attention(x, role="attn_pagedd")

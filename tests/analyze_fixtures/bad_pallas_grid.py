"""Fixture: pallas_call without an explicit grid (PAL001)."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _k(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2


def double(x):
    return pl.pallas_call(
        _k, out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32))(x)

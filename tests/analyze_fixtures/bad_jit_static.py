"""Fixture: static arg with an unhashable default (JIT002)."""
import jax


def build():
    def _f(x, opts={"beam": 1}):
        return x * opts["beam"]
    return jax.jit(_f, static_argnames=("opts",))

"""Fixture: internal use of deprecated shims (DEP001)."""
from repro.serve import PagedEngine


def make_engine(model, params):
    return PagedEngine(model, params, slots=2, max_len=32)

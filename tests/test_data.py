import numpy as np

from repro.data.pipeline import DataConfig, PackedDocs, SyntheticLM, make_batches


def test_synthetic_deterministic():
    cfg = DataConfig(vocab_size=100, seq_len=32, global_batch=4, seed=7)
    a = SyntheticLM(cfg).batch(5)
    b = SyntheticLM(cfg).batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_synthetic_learnable_structure():
    cfg = DataConfig(vocab_size=100, seq_len=64, global_batch=8, seed=0)
    src = SyntheticLM(cfg, branching=4)
    b = src.batch(0)
    # every target is one of the 4 allowed successors
    nxt = src.next_tokens[b["tokens"]]
    assert np.all((nxt == b["targets"][..., None]).any(-1))


def test_targets_are_shifted_tokens():
    cfg = DataConfig(vocab_size=100, seq_len=32, global_batch=2, seed=1)
    b = SyntheticLM(cfg).batch(3)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_resume_stream():
    cfg = DataConfig(vocab_size=50, seq_len=16, global_batch=2, seed=3)
    full = [b["tokens"] for _, b in zip(range(6), make_batches(cfg))]
    resumed = [b["tokens"] for _, b in zip(range(3), make_batches(cfg, start_step=3))]
    for x, y in zip(full[3:], resumed):
        np.testing.assert_array_equal(x, y)


def test_packed_docs():
    cfg = DataConfig(vocab_size=64, seq_len=48, global_batch=3, seed=2, kind="packed")
    b = PackedDocs(cfg).batch(0)
    assert b["tokens"].shape == (3, 48)
    assert b["loss_mask"].min() == 1.0  # fully packed rows

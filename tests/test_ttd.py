"""TT decomposition math (paper §II, Algorithm 1, Eq. 2) + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: skip only the property-based tests
    from conftest import given, settings, st  # noqa: F401

from repro.core import (
    TTSpec, compression_ratio, cores_to_matrices, factorize,
    matrices_to_cores, tensorize_weight, tt_linear_apply, tt_reconstruct,
    tt_svd, untensorize_weight,
)


def test_factorize_matches_paper():
    # the paper's hand-picked factorizations fall out of balanced factorize
    assert factorize(13696, 4) == (107, 8, 4, 4)
    assert factorize(4096, 4) == (8, 8, 8, 8)
    assert factorize(4096, 2) == (64, 64)


def test_cr_formula_table1():
    # per-layer CRs from paper Table I
    cases = [
        ((16, 8, 8, 4), (4, 8, 8, 16), 4096, 4096, 481.88),
        ((8, 8, 8, 8), (4, 4, 8, 107), 4096, 13696, 1446.44),
        ((107, 8, 4, 4), (8, 8, 8, 8), 13696, 4096, 1446.44),
        ((43, 16, 4, 4), (4, 8, 8, 16), 11008, 4096, 1007.89),
    ]
    for n_modes, m_modes, n, m, paper_cr in cases:
        spec = TTSpec.make(n, m, 16, in_modes=n_modes, out_modes=m_modes)
        assert abs(spec.compression_ratio() - paper_cr) < 0.5


def test_tensorize_roundtrip():
    spec = TTSpec.make(24, 36, 4, d=3, in_modes=(4, 3, 2), out_modes=(3, 3, 4))
    w = np.random.randn(36, 24)
    t = tensorize_weight(w, spec)
    assert t.shape == spec.mode_sizes
    np.testing.assert_allclose(untensorize_weight(t, spec), w)


def test_full_rank_exact():
    spec = TTSpec.make(24, 36, 10**9, d=3, in_modes=(4, 3, 2), out_modes=(3, 3, 4))
    w = np.random.randn(36, 24)
    cores = tt_svd(w, spec, method="svd")
    np.testing.assert_allclose(tt_reconstruct(cores, spec), w, atol=1e-10)


def test_gram_matches_svd():
    spec = TTSpec.make(256, 128, 8, d=4)
    w = np.random.randn(128, 256)
    r_svd = tt_reconstruct(tt_svd(w, spec, method="svd"), spec)
    r_gram = tt_reconstruct(tt_svd(w, spec, method="gram"), spec)
    np.testing.assert_allclose(r_svd, r_gram, atol=1e-6)


def test_truncation_error_decreases_with_rank():
    w = np.random.randn(64, 64)
    errs = []
    for r in (2, 4, 8, 16):
        spec = TTSpec.make(64, 64, r, d=3)
        err = np.linalg.norm(w - tt_reconstruct(tt_svd(w, spec), spec))
        errs.append(err)
    assert errs == sorted(errs, reverse=True)


def test_staged_inference_equals_dense():
    spec = TTSpec.make(48, 60, 6, d=3, in_modes=(4, 4, 3), out_modes=(5, 4, 3))
    w = np.random.randn(60, 48)
    cores = tt_svd(w, spec)
    w_hat = tt_reconstruct(cores, spec)
    params = {"cores": [jnp.asarray(c, jnp.float32) for c in cores_to_matrices(cores, spec)]}
    x = np.random.randn(7, 48).astype(np.float32)
    y = tt_linear_apply(params, jnp.asarray(x), spec)
    np.testing.assert_allclose(np.asarray(y), x @ w_hat.T, rtol=1e-4, atol=1e-4)


def test_layout_roundtrip():
    spec = TTSpec.make(64, 32, 4, d=3)
    cores = tt_svd(np.random.randn(32, 64), spec)
    back = matrices_to_cores(cores_to_matrices(cores, spec), spec)
    for a, b in zip(cores, back):
        np.testing.assert_allclose(a, b)


@settings(max_examples=25, deadline=None)
@given(
    modes=st.lists(st.integers(2, 5), min_size=2, max_size=4),
    out_modes=st.lists(st.integers(2, 5), min_size=2, max_size=4),
    rank=st.integers(1, 8),
    batch=st.integers(1, 5),
)
def test_property_staged_equals_reconstructed(modes, out_modes, rank, batch):
    """For ANY factorization/rank, staged Eq.-4 contraction == dense matmul
    with the reconstructed weight."""
    d = min(len(modes), len(out_modes))
    n_modes, m_modes = tuple(modes[:d]), tuple(out_modes[:d])
    n, m = int(np.prod(n_modes)), int(np.prod(m_modes))
    spec = TTSpec.make(n, m, rank, in_modes=n_modes, out_modes=m_modes)
    w = np.random.randn(m, n)
    cores = tt_svd(w, spec)
    w_hat = tt_reconstruct(cores, spec)
    params = {"cores": [jnp.asarray(c, jnp.float32) for c in cores_to_matrices(cores, spec)]}
    x = np.random.randn(batch, n).astype(np.float32)
    y = tt_linear_apply(params, jnp.asarray(x), spec)
    np.testing.assert_allclose(np.asarray(y), x @ w_hat.T.astype(np.float32),
                               rtol=2e-3, atol=2e-3)


def test_flops_and_intermediate_accounting():
    spec = TTSpec.make(4096, 4096, 16, in_modes=(16, 8, 8, 4), out_modes=(4, 8, 8, 16))
    # TT flops must be far below dense 2·M·N
    assert spec.flops_per_token() < 0.5 * 2 * 4096 * 4096
    assert spec.max_intermediate() >= 4096

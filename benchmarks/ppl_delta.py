"""Accuracy-degradation analogue of the paper's Table I quality rows
("Score Decrease 4.21" on C-EVal / "PPL Increase 2.62" on C4).

Real C-EVal/C4 + pretrained 6-7B weights aren't available in this container,
so we run the same *pipeline* at laptop scale: pretrain a small dense LM on
the synthetic stream, TT-SVD-compress its linears at several ranks (paper
recipe: attn-O + MLP), and report the held-out PPL delta vs rank — the
compression/accuracy trade-off curve the paper's rank-16 point sits on.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import QuantConfig, TrainConfig, TTDConfig
from repro.configs import get_config
from repro.core.compress import compress_model, compression_report
from repro.data.pipeline import DataConfig, make_source
from repro.models import build_model
from repro.train.losses import chunked_cross_entropy
from repro.train.step import build_train_step, init_train_state


def _eval_ppl(model, params, src, steps=8, start=10_000):
    tot, cnt = 0.0, 0.0
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in src.batch(start + i).items()}
        hidden, _ = model.forward(params, b)
        loss, m = chunked_cross_entropy(hidden, model.head_weight(params),
                                        b["targets"], b["loss_mask"])
        tot += float(m["ce"]) * float(m["tokens"])
        cnt += float(m["tokens"])
    return float(np.exp(tot / cnt))


def _finetune(cfg_t, params_t, steps, src, seed=1):
    """Brief post-compression fine-tune of the TT cores (standard TTD
    practice; exercises TT-as-trainable-parameters)."""
    model_t = build_model(cfg_t)
    tc = TrainConfig(global_batch=8, seq_len=64, lr=1e-3, warmup_steps=5,
                     total_steps=steps, optimizer="adamw", remat="none")
    from repro.optim import init_optimizer
    from repro.train.step import TrainState
    state = TrainState(params_t, init_optimizer("adamw", params_t),
                       jnp.zeros((), jnp.int32))
    step = jax.jit(build_train_step(model_t, tc))
    for i in range(steps):
        state, _ = step(state, {k: jnp.asarray(v) for k, v in src.batch(20_000 + i).items()})
    return state.params


def run(report=print, train_steps=120, ranks=(2, 4, 8, 16), ft_steps=60):
    cfg_d = get_config("tinyllama-1.1b", reduced=True).replace(
        compute_dtype="float32", param_dtype="float32",
        ttd=TTDConfig(enabled=False), quant=QuantConfig(enabled=False))
    model_d = build_model(cfg_d)
    tc = TrainConfig(global_batch=8, seq_len=64, lr=3e-3, warmup_steps=10,
                     total_steps=train_steps, optimizer="adamw", remat="none")
    state = init_train_state(model_d, tc, jax.random.PRNGKey(0))
    step = jax.jit(build_train_step(model_d, tc))
    src = make_source(DataConfig(vocab_size=cfg_d.vocab_size, seq_len=64,
                                 global_batch=8, seed=0))
    for i in range(train_steps):
        state, _ = step(state, {k: jnp.asarray(v) for k, v in src.batch(i).items()})

    base_ppl = _eval_ppl(model_d, state.params, src)
    report(f"dense baseline PPL: {base_ppl:.3f}")
    rows = [("dense", 1.0, base_ppl, 0.0)]
    for r in ranks:
        cfg_t = cfg_d.replace(ttd=TTDConfig(enabled=True, rank=r, d=3))
        model_t = build_model(cfg_t)
        params_t = compress_model(state.params, cfg_d, cfg_t, svd_method="svd")
        ppl = _eval_ppl(model_t, params_t, src)
        rep = compression_report(cfg_t)
        line = (f"rank {r:3d}: block CR={rep.block_cr:6.2f}  PPL={ppl:8.3f} "
                f"(+{ppl - base_ppl:.3f})")
        if r >= 8 and ft_steps:
            params_ft = _finetune(cfg_t, params_t, ft_steps, src)
            ppl_ft = _eval_ppl(model_t, params_ft, src)
            line += f"  after {ft_steps}-step finetune: PPL={ppl_ft:8.3f} (+{ppl_ft - base_ppl:.3f})"
            ppl = ppl_ft
        report(line)
        rows.append((f"tt_rank{r}", rep.block_cr, ppl, ppl - base_ppl))
    return rows


if __name__ == "__main__":
    run()

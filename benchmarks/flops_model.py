"""Analytic FLOP accounting per (arch × shape-cell).

Two numbers per cell:

  * ``model_flops``  — the assignment's MODEL_FLOPS: 6·N_active·D (train) or
    2·N_active·D (serve), N_active = parameters touched per token (dense
    non-embedding + top-k experts + head).
  * ``impl_flops``   — what our implementation actually executes, including
    TT staged contractions (8-18× less than dense for Table-I shapes),
    unmasked flash attention, MoE capacity padding / TP-expert waste, full
    rematerialization, and the optimizer.  This is the number the roofline's
    compute term uses (exact where HLO cost_analysis undercounts scan trip
    counts).

All values are GLOBAL (whole-mesh); divide by chips for per-device.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.config import ModelConfig, ShapeCell
from repro.configs import get_config
from repro.models.modules import LinearSpec, linear_param_count


@dataclass
class CellFlops:
    model_flops: float  # "useful" (assignment formula, TT param counts)
    model_flops_dense: float  # dense-equivalent useful flops (6*N_dense*D)
    impl_fwd: float  # implementation forward pass
    impl_total: float  # full step (train: fwd+remat+bwd+loss+opt)
    n_active: float
    n_active_dense: float
    notes: str = ""


def _dense_count(spec: LinearSpec) -> int:
    return spec.n_in * spec.n_out + (spec.n_out if spec.bias else 0)


def _lin(spec: LinearSpec) -> float:
    """fwd flops per token."""
    if spec.kind == "tt":
        return float(spec.tt.flops_per_token())
    return 2.0 * spec.n_in * spec.n_out


def _attn_linears(cfg, specs):
    a = specs.attn_d() if hasattr(specs, "attn_d") else specs
    return sum(_lin(a[k]) for k in ("wq", "wk", "wv", "wo"))


def _block_fwd_per_token(cfg: ModelConfig, ttd_on: bool, ctx: int) -> tuple[float, float]:
    """(impl flops, active params) per token for one block; ctx = attended
    context length (unmasked-flash S for train/prefill, cache len for decode)."""
    from repro.models.transformer import make_block_specs
    specs = make_block_specs(cfg, ttd_on)
    lin = _attn_linears(cfg, specs)
    attn = 4.0 * ctx * cfg.n_heads * cfg.head_dim
    active = sum(linear_param_count(dict(specs.attn)[k]) for k in ("wq", "wk", "wv", "wo"))
    dense_p = sum(_dense_count(dict(specs.attn)[k]) for k in ("wq", "wk", "wv", "wo"))
    if specs.moe is not None:
        e = specs.moe["expert"]
        per_exp = sum(_lin(s) for s in e.values())
        per_exp_p = sum(linear_param_count(s) for s in e.values())
        per_exp_d = sum(_dense_count(s) for s in e.values())
        router = 2.0 * cfg.d_model * cfg.n_experts
        # capacity/TP waste factor
        mesh_model = 16
        if cfg.n_experts % mesh_model == 0 or mesh_model % cfg.n_experts == 0:
            # ep / replicated-expert ep: top-k x capacity padding
            waste = cfg.capacity_factor * 1.1
            experts_run = cfg.experts_per_token * waste
        else:
            experts_run = cfg.n_experts  # TP-expert path runs all experts
        mlp = router + per_exp * experts_run
        active += per_exp_p * cfg.experts_per_token + cfg.d_model * cfg.n_experts
        dense_p += per_exp_d * cfg.experts_per_token + cfg.d_model * cfg.n_experts
    else:
        mlp = sum(_lin(s) for _, s in specs.mlp)
        active += sum(linear_param_count(s) for _, s in specs.mlp)
        dense_p += sum(_dense_count(s) for _, s in specs.mlp)
    return lin + attn + mlp, active, dense_p


def _rwkv_block(cfg: ModelConfig) -> tuple[float, float]:
    from repro.models.rwkv import rwkv_specs
    sp = rwkv_specs(cfg)
    lin = sum(_lin(s) for s in sp["tm"].values()) + sum(_lin(s) for s in sp["cm"].values())
    lora = 2.0 * cfg.d_model * (5 * cfg.rwkv_lora_mix * 2 + cfg.rwkv_lora_decay * 2)
    hd = cfg.rwkv_head_dim
    wkv = 6.0 * cfg.d_model * hd  # state update + readout per token
    active = sum(linear_param_count(s) for s in sp["tm"].values()) + \
        sum(linear_param_count(s) for s in sp["cm"].values())
    dense_p = sum(_dense_count(s) for s in sp["tm"].values()) + \
        sum(_dense_count(s) for s in sp["cm"].values())
    return lin + lora + wkv, active, dense_p


def _griffin_blocks(cfg: ModelConfig, ctx: int) -> tuple[float, float]:
    """Average over the (rec, rec, attn) pattern, per token."""
    from repro.models.griffin import rec_specs, pattern_plan
    from repro.models.transformer import make_block_specs
    rs = rec_specs(cfg, True)
    w = cfg.lru_width or cfg.d_model
    rec = sum(_lin(rs[k]) for k in ("in_x", "in_g", "gate_a", "gate_x", "out"))
    rec += sum(_lin(s) for s in rs["mlp"].values())
    rec += 2.0 * cfg.conv_width * w + 10.0 * w  # conv + RG-LRU elementwise
    rec_p = sum(linear_param_count(rs[k]) for k in ("in_x", "in_g", "gate_a", "gate_x", "out")) \
        + sum(linear_param_count(s) for s in rs["mlp"].values())
    asp = make_block_specs(cfg, True)
    attn = _attn_linears(cfg, asp) + 4.0 * min(ctx, cfg.window or ctx) * cfg.n_heads * cfg.head_dim
    attn += sum(_lin(s) for _, s in asp.mlp)
    attn_p = sum(linear_param_count(dict(asp.attn)[k]) for k in ("wq", "wk", "wv", "wo")) \
        + sum(linear_param_count(s) for _, s in asp.mlp)
    rec_d = sum(_dense_count(rs[k]) for k in ("in_x", "in_g", "gate_a", "gate_x", "out")) \
        + sum(_dense_count(s) for s in rs["mlp"].values())
    attn_d = sum(_dense_count(dict(asp.attn)[k]) for k in ("wq", "wk", "wv", "wo")) \
        + sum(_dense_count(s) for _, s in asp.mlp)
    n_groups, tail = pattern_plan(cfg)
    n_rec = 2 * n_groups + len(tail)
    n_attn = n_groups
    total = (n_rec * rec + n_attn * attn) / cfg.n_layers
    total_p = (n_rec * rec_p + n_attn * attn_p) / cfg.n_layers
    total_d = (n_rec * rec_d + n_attn * attn_d) / cfg.n_layers
    return total, total_p, total_d


def _whisper_fwd(cfg: ModelConfig, b: int, s_dec: int) -> tuple[float, float]:
    from repro.models.whisper import attn_specs
    from repro.models.modules import mlp_specs
    asp, msp = attn_specs(cfg), mlp_specs(cfg, True)
    lin = sum(_lin(asp[k]) for k in ("wq", "wk", "wv", "wo"))
    mlp = sum(_lin(s) for s in msp.values())
    enc_tok = lin + mlp + 4.0 * cfg.enc_len * cfg.n_heads * cfg.head_dim
    dec_tok = 2 * lin + mlp + 4.0 * (s_dec + cfg.enc_len) * cfg.n_heads * cfg.head_dim
    total = b * (cfg.n_enc_layers * cfg.enc_len * enc_tok + cfg.n_layers * s_dec * dec_tok)
    # decoder active params per token: self+cross attn + mlp
    p = cfg.n_layers * (2 * sum(linear_param_count(asp[k]) for k in asp) +
                        sum(linear_param_count(s) for s in msp.values()))
    d = cfg.n_layers * (2 * sum(_dense_count(asp[k]) for k in asp) +
                        sum(_dense_count(s) for s in msp.values()))
    return total, p, d


def cell_flops(arch: str, cell: ShapeCell) -> CellFlops:
    cfg = get_config(arch)
    b, s = cell.global_batch, cell.seq_len
    head = 2.0 * cfg.d_model * cfg.vocab_size  # per token
    notes = []

    if cell.kind == "train":
        tokens, ctx = b * s, s
    elif cell.kind == "prefill":
        tokens, ctx = b * s, s
    else:
        tokens, ctx = b * 1, min(s, cfg.window) if cfg.window else s

    if cfg.family == "encdec":
        s_dec = s if cell.kind != "decode" else 1
        fwd, p_blocks, d_blocks = _whisper_fwd(cfg, b, s_dec)
        fwd += b * s_dec * head
        n_active = p_blocks + cfg.d_model * cfg.vocab_size
        n_dense = d_blocks + cfg.d_model * cfg.vocab_size
        tokens = b * s_dec
    else:
        per_tok = 0.0
        n_active = 0.0
        n_dense = 0.0
        if cfg.family == "rwkv":
            blk, p, dp = _rwkv_block(cfg)
            per_tok, n_active, n_dense = cfg.n_layers * blk, cfg.n_layers * p, cfg.n_layers * dp
        elif cfg.family == "griffin":
            blk, p, dp = _griffin_blocks(cfg, ctx)
            per_tok, n_active, n_dense = cfg.n_layers * blk, cfg.n_layers * p, cfg.n_layers * dp
        else:
            from repro.models.transformer import segment_plan
            for n, ttd_on in segment_plan(cfg):
                blk, p, dp = _block_fwd_per_token(cfg, ttd_on, ctx)
                per_tok += n * blk
                n_active += n * p
                n_dense += n * dp
        per_tok += head
        n_active += cfg.d_model * cfg.vocab_size
        n_dense += cfg.d_model * cfg.vocab_size
        fwd = tokens * per_tok

    if cell.kind == "train":
        # fwd + remat-recompute fwd + backward 2x + optimizer
        n_params = n_active  # proxy; optimizer cost ~10 flops/param
        impl_total = 4.0 * fwd + 10.0 * n_params
        model = 6.0 * n_active * tokens
        model_d = 6.0 * n_dense * tokens
        notes.append("train: impl=4x fwd (full remat) + opt")
    else:
        impl_total = fwd
        model = 2.0 * n_active * tokens
        model_d = 2.0 * n_dense * tokens
    return CellFlops(model_flops=model, model_flops_dense=model_d,
                     impl_fwd=fwd, impl_total=impl_total,
                     n_active=n_active, n_active_dense=n_dense,
                     notes="; ".join(notes))


# ---------------------------------------------------------------------------
# Analytic HBM-traffic and collective-traffic models (per device, per step).
#
# XLA-CPU's "bytes accessed" counts every HLO op's operands (no TPU-style
# fusion) and counts scan bodies once — so it both over-counts elementwise
# chains and under-counts depth.  These analytic models are the primary
# roofline source; coarse but transparent:
#
# HBM bytes (train) ~ 3x param shard (fwd gather + bwd regather + update)
#                   + 3x optimizer state shard (read m,v / write)
#                   + remat carry stack x3 (save, reload, recompute-write)
#                   + per-layer activation working set x L x 4
# HBM bytes (decode) ~ param shard + KV-cache shard + activations
# collectives (train) ~ FSDP gathers + grad reduce-scatter/all-gather
#                   + SP/TP activation reshards per block + EP all_to_all
# ---------------------------------------------------------------------------
CHIPS_DEFAULT = 256
MESH_DATA, MESH_MODEL = 16, 16


def _param_bytes(cfg, serve: bool) -> float:
    """Global parameter bytes under the cell's parameterization."""
    from repro.core.compress import compression_report
    if cfg.family in ("dense", "moe"):
        rep = compression_report(cfg)
        blocks_bits = (rep.n_tt_blocks * rep.block_bits_comp
                       + (rep.n_blocks - rep.n_tt_blocks) * rep.block_bits_dense)
        emb_bits = rep.embed_params * 16
        return (blocks_bits + emb_bits) / 8.0
    # other families: count from eval_shape-free param math (approx: dense)
    import jax
    from repro.models import build_model
    shapes = jax.eval_shape(build_model(cfg).init, jax.random.PRNGKey(0))
    return float(sum(math.prod(x.shape) * (2 if serve or cfg.param_dtype == "bfloat16" else 4)
                     for x in jax.tree.leaves(shapes)))


def cell_traffic(arch: str, cell: ShapeCell, chips: int = CHIPS_DEFAULT):
    """(hbm_bytes_per_device, collective_bytes_per_device) analytic."""
    from repro.launch.dryrun import arch_cell_config
    cfg = arch_cell_config(arch, cell)
    serve = cell.kind != "train"
    b, s = cell.global_batch, cell.seq_len
    d = cfg.d_model
    act_bytes = 2  # bf16 activations
    p_global = _param_bytes(cfg, serve)

    if cell.kind == "train":
        p_dev = p_global / chips  # FSDP x TP sharded
        carries = cfg.n_layers * (b / MESH_DATA) * (s / MESH_MODEL) * d * act_bytes
        # per-layer working set touched ~4x (fwd, remat, bwd dgrad, bwd wgrad)
        work = cfg.n_layers * 4 * (b * s / chips) * d * 8 * act_bytes
        hbm = 3 * p_dev + 3 * 2 * p_dev + 3 * carries + work
        # collectives: FSDP gathers (2x per step over the data axis) + grad RS
        fsdp = 3 * p_global / MESH_MODEL / MESH_DATA * (MESH_DATA - 1)
        # SP/TP reshard per block: fwd 2 hops + bwd 2 hops of (B,S,D)/devices
        act_coll = cfg.n_layers * 4 * (b * s / chips) * d * act_bytes
        coll = fsdp + act_coll
        if cfg.family in ("griffin", "rwkv"):
            # temporal blocks gather the full sequence per device (recurrence
            # needs seq-local data): 2 tensors x (fwd+bwd) x (g-1)/g
            w = cfg.lru_width or d if cfg.family == "griffin" else d
            n_rec = (cfg.n_layers * 2 // 3) if cfg.family == "griffin" else cfg.n_layers
            gather = n_rec * 4 * (b / MESH_DATA) * s * w * act_bytes * (MESH_MODEL - 1) / MESH_MODEL
            coll += gather
            hbm += gather  # the gathered copies are read/written
        if cfg.family == "moe":
            tokens_dev = b * s / chips
            a2a = cfg.n_layers * 3 * tokens_dev * cfg.experts_per_token * \
                cfg.capacity_factor * d * act_bytes
            coll += a2a
            hbm += a2a  # dispatch buffers are materialized
    elif cell.kind == "prefill":
        p_dev = p_global / MESH_MODEL
        work = cfg.n_layers * (b * s / chips) * d * 6 * act_bytes
        hbm = p_dev + work
        coll = cfg.n_layers * 2 * (b * s / chips) * d * act_bytes
    else:  # decode
        p_dev = p_global / MESH_MODEL
        cache_dtype = 2
        if cfg.family == "rwkv":
            cache_dev = cfg.n_layers * (b / MESH_DATA) * d * cfg.rwkv_head_dim * 4 / MESH_MODEL
        elif cfg.family == "griffin":
            win = min(cfg.window or s, s)
            n_attn = cfg.n_layers // 3
            cache_dev = n_attn * 2 * (b / MESH_DATA) * win * cfg.n_kv_heads * cfg.head_dim * cache_dtype / MESH_MODEL \
                + cfg.n_layers * (b / MESH_DATA) * (cfg.lru_width or d) * 4
        else:
            win = min(cfg.window or s, s)
            kv_feat = max(cfg.n_kv_heads * cfg.head_dim / MESH_MODEL, cfg.head_dim / MESH_MODEL)
            layers = cfg.n_layers * (2 if cfg.family == "encdec" else 1)
            cache_dev = layers * 2 * (b / MESH_DATA) * win * kv_feat * cache_dtype
        hbm = p_dev + cache_dev + (b / MESH_DATA) * d * cfg.n_layers * 4 * act_bytes
        coll = cfg.n_layers * 2 * (b / max(MESH_DATA, 1)) * d * act_bytes * 2
        if cfg.family == "moe":  # ep_psum: one psum of (B,D) per layer
            coll += cfg.n_layers * 2 * (b / MESH_DATA) * d * act_bytes
    return hbm, coll

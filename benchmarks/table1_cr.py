"""Reproduce paper Table I: per-layer / per-block / whole-network CRs."""
from __future__ import annotations

from repro.configs import ALL_ARCHS, get_config
from repro.core.compress import compression_report

PAPER = {
    "chatglm3-6b": {"block": 10.72, "network": 1.94,
                    "roles": {"wo": 481.88, "gate": 1446.44, "up": 1446.44,
                              "down": 1446.44}},
    "llama2-7b": {"block": 4.01, "network": 1.60,
                  "roles": {"wo": 481.88, "gate": 1233.82, "up": 1233.82,
                            "down": 1007.89}},
}


def run(report=print):
    rows = []
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        if cfg.family not in ("dense", "moe"):
            continue
        rep = compression_report(cfg)
        paper = PAPER.get(arch, {})
        report(f"== {arch}: block CR={rep.block_cr:.2f}"
               + (f" (paper {paper['block']})" if paper else "")
               + f"  network CR={rep.network_cr:.2f}"
               + (f" (paper {paper['network']})" if paper else "")
               + f"  net+embed={rep.network_cr_with_embed:.3f}"
               + f"  bits-CR={rep.network_cr_bits:.2f}")
        for r in rep.roles:
            p = paper.get("roles", {}).get(r.role)
            report(f"   {r.role:14s} {r.kind:5s} {r.n_in}x{r.n_out:<7d} CR={r.cr:9.2f}"
                   + (f" (paper {p})" if p else ""))
        rows.append((arch, rep.block_cr, rep.network_cr))
    return rows


if __name__ == "__main__":
    run()

"""Reproduce paper Table I: per-layer / per-block / whole-network CRs.

Two bit-CR columns: ``bits-CR`` uses each config's own storage numerics
(``cfg.param_dtype`` baseline — float32 for the Table-I configs, so it
equals the parameter CR when no int4 mixes in), and ``deploy bits-CR`` the
paper's deployment numerics (Wt INT4 for non-TT linears / FP16 baseline,
i.e. ``serve_config_of``'s quant recipe at ``param_bits=16``).
"""
from __future__ import annotations

from repro.config import QuantConfig
from repro.configs import ALL_ARCHS, get_config
from repro.core.compress import compression_report

PAPER = {
    "chatglm3-6b": {"block": 10.72, "network": 1.94,
                    "roles": {"wo": 481.88, "gate": 1446.44, "up": 1446.44,
                              "down": 1446.44}},
    "llama2-7b": {"block": 4.01, "network": 1.60,
                  "roles": {"wo": 481.88, "gate": 1233.82, "up": 1233.82,
                            "down": 1007.89}},
}

# regenerated pins (tests/test_compress.py asserts these): deployment
# bits-CR = TT linears + int4 everything-else vs an FP16 dense baseline
DEPLOY_BITS = {"chatglm3-6b": 2.09, "llama2-7b": 2.25}


def deploy_bits_cr(cfg) -> float:
    dep = cfg.replace(quant=QuantConfig(enabled=True, bits=4, group_size=128))
    return compression_report(dep, param_bits=16).network_cr_bits


def run(report=print):
    rows = []
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        if cfg.family not in ("dense", "moe"):
            continue
        rep = compression_report(cfg)
        paper = PAPER.get(arch, {})
        report(f"== {arch}: block CR={rep.block_cr:.2f}"
               + (f" (paper {paper['block']})" if paper else "")
               + f"  network CR={rep.network_cr:.2f}"
               + (f" (paper {paper['network']})" if paper else "")
               + f"  net+embed={rep.network_cr_with_embed:.3f}"
               + f"  bits-CR={rep.network_cr_bits:.2f}"
               + f"  deploy bits-CR={deploy_bits_cr(cfg):.2f}")
        for r in rep.roles:
            p = paper.get("roles", {}).get(r.role)
            report(f"   {r.role:14s} {r.kind:5s} {r.n_in}x{r.n_out:<7d} CR={r.cr:9.2f}"
                   + (f" (paper {p})" if p else ""))
        rows.append((arch, rep.block_cr, rep.network_cr))
    return rows


if __name__ == "__main__":
    run()

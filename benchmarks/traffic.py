"""Realistic-traffic benchmark: async front-end under seeded open-loop load.

Replays deterministic Poisson and bursty arrival schedules
(``repro.traffic``) against the asyncio serving front-end
(``repro.serve.frontend``) for three model families — dense paged-attention
(tight block pool, so bursts preempt), a recurrent-state family (rwkv), and
a TT+int4-compressed model — and writes one row per (family, scenario) to
``BENCH_traffic.json``: p50/p95/p99 TTFT and inter-token latency from the
obs registry, goodput (SLO-attained tokens/sec), and preemption / client
cancellation / deadline-miss counts.  CPU wall-time on the reduced configs —
a structural comparison of scheduling under load, not TPU performance.

    PYTHONPATH=src python benchmarks/traffic.py
    PYTHONPATH=src python benchmarks/traffic.py --smoke --check-schema
    PYTHONPATH=src python benchmarks/traffic.py --check-schema BENCH_traffic.json
"""
from __future__ import annotations

import argparse
import json
import os
from pathlib import Path

from repro.configs import get_config

try:
    from .compressed_serve import variant_cfgs
except ImportError:  # standalone `python benchmarks/traffic.py`
    from compressed_serve import variant_cfgs

FAMILIES = ("dense/paged", "rwkv", "tt_int4")


def family_setup(family: str):
    """(arch, model, params, engine kwargs) for one benchmark family."""
    import jax

    from repro.models import build_model

    if family == "tt_int4":
        from repro.core.compress import compress_model

        dense_cfg, target = variant_cfgs("tinyllama-1.1b", "tt_int4")
        dense_model = build_model(dense_cfg)
        params = compress_model(dense_model.init(jax.random.PRNGKey(0)),
                                dense_cfg, target)
        return ("tinyllama-1.1b", build_model(target), params,
                dict(slots=2, max_len=96, block_size=8, prefill_batch=2,
                     prefill_chunk=8))
    if family == "rwkv":
        cfg = get_config("rwkv6-7b", reduced=True).replace(
            compute_dtype="float32", param_dtype="float32")
        model = build_model(cfg)
        return ("rwkv6-7b", model, model.init(jax.random.PRNGKey(0)),
                dict(slots=4, max_len=96, prefill_batch=2, prefill_chunk=8))
    assert family == "dense/paged", family
    cfg = get_config("tinyllama-1.1b", reduced=True).replace(
        compute_dtype="float32", param_dtype="float32")
    model = build_model(cfg)
    # deliberately tight block pool: bursty arrivals overcommit it, so the
    # preemption path shows up in the preempts column
    return ("tinyllama-1.1b", model, model.init(jax.random.PRNGKey(0)),
            dict(slots=4, max_len=96, backend="paged", block_size=8,
                 num_blocks=12, prefill_batch=2, prefill_chunk=8))


def scenario_specs(vocab: int, n_requests: int, deadline_s: float | None):
    """The seeded arrival scenarios every family is measured under."""
    from repro.traffic import WorkloadSpec

    common = dict(n_requests=n_requests,
                  prompt_len_buckets=(6, 16, 40),
                  prompt_len_weights=(0.5, 0.3, 0.2),
                  out_tokens_buckets=(4, 12, 24),
                  out_tokens_weights=(0.5, 0.3, 0.2),
                  vocab=vocab, ttft_slo_s=0.35, deadline_s=deadline_s,
                  cancel_prob=0.25, cancel_window_s=(0.005, 0.08))
    return {
        "poisson": WorkloadSpec(arrival="poisson", rate_rps=6.0, seed=7,
                                **common),
        "bursty": WorkloadSpec(arrival="bursty", rate_rps=8.0, burst_size=4,
                               seed=11, **common),
    }


def _warmup(model, params, kwargs) -> None:
    """Compile every program shape untimed (steps memoize per config)."""
    import jax.numpy as jnp

    from repro.serve import steps
    from repro.serve.engine import Engine

    eng = Engine(model, params, obs=False, **kwargs)
    for i, plen in enumerate((5, 20)):  # single- and multi-chunk prefill
        eng.submit([1 + (i + j) % 7 for j in range(plen)], max_tokens=4)
    eng.run()
    # the async pump's device-side argmax is its own jitted program
    steps.greedy_tokens(jnp.zeros((kwargs["slots"], model.cfg.vocab_size),
                                  jnp.float32))


def run(report=print, *, families=FAMILIES, n_requests: int = 12,
        time_scale: float = 1.0, deadline_s: float | None = 20.0,
        out_path: str = "BENCH_traffic.json"):
    from repro.obs import ObsConfig, Observer
    from repro.serve import AsyncEngine
    from repro.serve.engine import Engine
    from repro.traffic import drive, make_workload, traffic_row

    jsonl = os.environ.get("REPRO_OBS_JSONL") or None
    rows = []
    report(f"== traffic: {len(families)} families x 2 arrival scenarios, "
           f"{n_requests} requests each (time_scale={time_scale})")
    for family in families:
        arch, model, params, kwargs = family_setup(family)
        _warmup(model, params, kwargs)
        specs = scenario_specs(model.cfg.vocab_size, n_requests, deadline_s)
        for scenario, spec in specs.items():
            requests = make_workload(spec)
            # fresh per-scenario observer; all scenarios may append to one
            # JSONL (trace seq numbers are process-wide, so the merged log
            # still validates)
            obs = Observer(ObsConfig(jsonl_path=jsonl))
            frontend = AsyncEngine(engine=Engine(model, params, obs=obs,
                                                 **kwargs))
            result = drive(frontend, requests, time_scale=time_scale)
            obs.close()
            row = traffic_row(
                result=result, registry=obs.registry, family=family,
                arch=arch, scenario=scenario, workload=spec.to_dict(),
                ahead_tick_fraction=(frontend.stats["ahead_ticks"]
                                     / max(1, frontend.stats["ticks"])))
            rows.append(row)
            report(f"   {family:12s} {scenario:8s} "
                   f"goodput {row['goodput_tok_per_s']:7.1f} tok/s "
                   f"(of {row['tok_per_s']:7.1f})  "
                   f"ttft p50 {row['ttft_s']['p50']*1e3:7.1f}ms "
                   f"p99 {row['ttft_s']['p99']*1e3:7.1f}ms  "
                   f"preempts {row['preempts']:2d} cancels {row['cancels']:2d}"
                   f" misses {row['n_deadline_missed']:2d}")
    rec = {
        "scenarios": {"names": sorted({r["scenario"] for r in rows}),
                      "n_requests": n_requests, "time_scale": time_scale,
                      "deadline_s": deadline_s},
        "note": "CPU wall-clock on the reduced configs: open-loop seeded "
                "arrivals through the asyncio front-end (dispatch-ahead "
                "double buffering) — scheduling structure under load, not "
                "TPU kernel performance.",
        "rows": rows,
    }
    Path(out_path).write_text(json.dumps(rec, indent=1))
    report(f"wrote {out_path}")
    return rows


# ---------------------------------------------------------------------------
# CI modes
# ---------------------------------------------------------------------------
def smoke(report=print, out_path: str = "BENCH_traffic.json"):
    """Tiny full-matrix run: every family and scenario, 4 requests each.

    No deadlines (CI machines jitter too much for miss counts to be stable)
    and a compressed clock; the output still satisfies the full schema, so
    ``--smoke --check-schema`` validates what it just wrote.
    """
    return run(report=report, n_requests=4, time_scale=0.5, deadline_s=None,
               out_path=out_path)


def check_schema(path, report=print):
    """Validate a BENCH_traffic.json against the acceptance shape.

    Delegates to the shared BENCH schema table (``repro.analyze.bench``) —
    the same validation ``python -m repro.analyze --bench`` runs in CI.
    """
    from repro.analyze.bench import check_file

    errors = check_file("traffic", Path(path))
    assert not errors, "; ".join(errors)
    rows = json.loads(Path(path).read_text())["rows"]
    report(f"schema OK: {path} ({len(rows)} rows, "
           f"{len({r['family'] for r in rows})} families x "
           f"{len({r['scenario'] for r in rows})} scenarios)")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI: tiny full-matrix run (all families/scenarios, "
                         "4 requests)")
    ap.add_argument("--check-schema", nargs="?", const="", metavar="PATH",
                    help="CI: schema-validate a results file (no PATH: "
                         "whatever --out points at; combines with --smoke)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--time-scale", type=float, default=1.0)
    ap.add_argument("--out", default="BENCH_traffic.json")
    args = ap.parse_args(argv)
    if args.smoke:
        smoke(out_path=args.out)
    elif args.check_schema is None:
        run(n_requests=args.requests, time_scale=args.time_scale,
            out_path=args.out)
    if args.check_schema is not None:
        check_schema(args.check_schema or args.out)


if __name__ == "__main__":
    main()

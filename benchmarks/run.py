"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines at the end, per harness
convention.  The roofline section reads whatever dry-run artifacts exist in
experiments/dryrun (run ``python -m repro.launch.dryrun`` first for the full
40-cell table).
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from . import decode_speed, gvsa_latency, kernel_bench, ppl_delta, roofline, table1_cr

    csv: list[tuple[str, float, str]] = []

    print("=" * 72)
    print("Table I - compression ratios (paper: 481.88x / 1446.44x / 10.72x / 1.94x)")
    print("=" * 72)
    t0 = time.perf_counter()
    rows = table1_cr.run()
    csv.append(("table1_cr", (time.perf_counter() - t0) * 1e6,
                f"chatglm_block_cr={next(r[1] for r in rows if r[0]=='chatglm3-6b'):.2f}"))

    print("\n" + "=" * 72)
    print("Tables III/IV + Fig. 8 - GVSA latency model (paper: 1.45x / 1.57x)")
    print("=" * 72)
    t0 = time.perf_counter()
    rows = gvsa_latency.run()
    csv.append(("gvsa_latency", (time.perf_counter() - t0) * 1e6,
                f"first_token_reduction={rows[0][3]:.2f}x/{rows[1][3]:.2f}x"))

    print("\n" + "=" * 72)
    print("Fig. 9a - decode speed vs decoded tokens")
    print("=" * 72)
    t0 = time.perf_counter()
    rows = decode_speed.run()
    csv.append(("decode_speed", (time.perf_counter() - t0) * 1e6,
                f"speedup@2048={rows[3][2]/rows[3][3]:.2f}x"))

    print("\n" + "=" * 72)
    print("Kernel microbench - dense vs TT staged contraction")
    print("=" * 72)
    t0 = time.perf_counter()
    rows = kernel_bench.run()
    csv.append(("kernel_bench", (time.perf_counter() - t0) * 1e6,
                f"tt_speedup={rows[0][1]/rows[0][2]:.2f}x"))

    print("\n" + "=" * 72)
    print("Accuracy analogue - PPL delta vs TT rank (paper: +2.62 PPL at r=16)")
    print("=" * 72)
    t0 = time.perf_counter()
    rows = ppl_delta.run()
    csv.append(("ppl_delta", (time.perf_counter() - t0) * 1e6,
                f"ppl_delta_r16={rows[-1][3]:.3f}"))

    print("\n" + "=" * 72)
    print("Roofline - per (arch x cell), single-pod (see EXPERIMENTS.md)")
    print("=" * 72)
    t0 = time.perf_counter()
    rrows = roofline.run()
    done = [r for r in rrows if not r.skipped]
    csv.append(("roofline", (time.perf_counter() - t0) * 1e6, f"cells={len(done)}"))

    print("\nname,us_per_call,derived")
    for name, us, derived in csv:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()

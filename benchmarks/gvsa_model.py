"""Analytical cycle model of the paper's GVSA accelerator (§III.B, §V.C).

Hardware: T_in=128-wide MAC lanes × T_out=32 PEs (T_n=16 parallel groups),
125 MHz, FP16 activations × INT4 weights.  Single-token (GEMV) workloads —
the first-token/decode regime of Tables III/IV.

Model:  cycles(op) = α · ideal_cycles(op) + β        (fill/drain + control)
  dense linear  ideal = Σ ceil(N/T_in) · ceil(M/(T_in·T_out/T_in)) …
                simplified to MACs / (T_in·T_out) (peak 4096 MAC/cycle)
  TT linear     ideal = Σ_k stage-loop cycles per Fig. 6:
                T_out · ceil((r_{k-1}·n_k)/T_in) · ceil(T_k/T_out) ·
                ceil((m_k·r_k)/T_out)  — the reorder is free (hidden in the
                ping-pong buffer access pattern, §III.C)
  nonlinear     ideal = elems / T_in  (vector unit)

α, β are calibrated per op-class on HALF of the paper's Table III entries
and validated against the held-out half + all of Table IV
(benchmarks/gvsa_latency.py prints measured-vs-model).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.ttd import TTSpec

T_IN, T_OUT, T_N = 128, 32, 16
FREQ_HZ = 125e6
PEAK_MACS = T_IN * T_OUT


@dataclass(frozen=True)
class GVSAParams:
    alpha_lin: float = 1.45  # dense-linear efficiency factor (~69% of peak)
    alpha_tt: float = 1.75  # TT stages: shorter rows -> more fill overhead
    alpha_nl: float = 24.0  # nonlinear vector ops
    beta: float = 180.0  # fixed per-op control/fill cycles


def cycles_to_us(cycles: float) -> float:
    return cycles / FREQ_HZ * 1e6


def dense_linear_cycles(m: int, n: int, tokens: int = 1, p: GVSAParams = GVSAParams()):
    ideal = tokens * m * n / PEAK_MACS
    return p.alpha_lin * ideal + p.beta


def tt_stage_cycles(spec: TTSpec, tokens: int = 1) -> float:
    """Fig. 6 loop structure, summed over stages (reorder cycles = 0)."""
    total = 0.0
    n, m, r = spec.in_modes, spec.out_modes, spec.ranks
    for k in range(spec.d):
        contract = r[k] * n[k]
        out_cols = m[k] * r[k + 1]
        t_dim = tokens * math.prod(n[k + 1:]) * math.prod(m[:k])
        total += T_OUT * math.ceil(contract / T_IN) * math.ceil(t_dim / T_OUT) \
            * math.ceil(out_cols / T_OUT)
    return total


def tt_linear_cycles(spec: TTSpec, tokens: int = 1, p: GVSAParams = GVSAParams()):
    return p.alpha_tt * tt_stage_cycles(spec, tokens) + p.beta


def nonlinear_cycles(elems: int, p: GVSAParams = GVSAParams()):
    return p.alpha_nl * elems / T_IN + p.beta


def attention_cycles(seq: int, n_heads: int, head_dim: int, kv_heads: int,
                     p: GVSAParams = GVSAParams()):
    """Score + PV matvecs against a KV cache of ``seq`` (decode regime)."""
    macs = 2 * seq * n_heads * head_dim
    return p.alpha_lin * macs / PEAK_MACS + p.beta

"""Fig. 9a analogue: decode tokens/s vs number of decoded tokens, with and
without TTD, from the GVSA cycle model (KV cache growth slows attention; the
TTD linears keep their constant advantage)."""
from __future__ import annotations

from repro.configs import get_config

from .gvsa_latency import model_block_ops
from .gvsa_model import GVSAParams, attention_cycles, cycles_to_us


def tokens_per_s(arch: str, n_decoded: int, prompt: int = 64, tt: bool = True):
    cfg = get_config(arch)
    ops_tt, ops_dense = model_block_ops(arch, seq=prompt + n_decoded)
    blk = sum((ops_tt if tt else ops_dense).values())
    n_tt = cfg.n_layers - cfg.ttd.first_tt_block
    per_tok_us = (n_tt * blk + cfg.ttd.first_tt_block * sum(ops_dense.values())) / 1e3 \
        if tt else cfg.n_layers * sum(ops_dense.values()) / 1e3
    return 1e3 / per_tok_us


def run(report=print):
    rows = []
    for arch in ("chatglm3-6b", "llama2-7b"):
        report(f"== {arch}: decode speed (tokens/s), TTD vs baseline")
        for n in (128, 512, 1024, 2048):
            t_tt = tokens_per_s(arch, n, tt=True)
            t_base = tokens_per_s(arch, n, tt=False)
            report(f"   {n:5d} decoded: TTD {t_tt:7.1f} tok/s  baseline {t_base:7.1f}"
                   f"  speedup {t_tt/t_base:4.2f}x")
            rows.append((arch, n, t_tt, t_base))
        # paper peak speeds: 69.7 tok/s (1.45x) / 65.8 tok/s (1.57x) — the
        # absolute number depends on HBM modelling we don't replicate; the
        # ratio is the reproduced quantity.
    return rows


if __name__ == "__main__":
    run()

"""Decode-speed benchmarks: analytic model + real serving engines.

Default mode (Fig. 9a analogue): decode tokens/s vs number of decoded
tokens, with and without TTD, from the GVSA cycle model (KV cache growth
slows attention; the TTD linears keep their constant advantage).

``--serve`` mode: drive the *real* unified session engine
(``repro.serve.engine``, DESIGN.md §7) over the same request mix for
**every model family** — dense (paged + ring backends), moe, griffin,
rwkv, whisper — at several slot counts, reporting wall-clock tokens/sec
and mean first-token latency per family, and writing the rows to
``BENCH_serve.json``.  CPU wall-time on the reduced configs — a structural
comparison (scheduling + dispatch overheads), not TPU performance.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

from repro.configs import get_config


def tokens_per_s(arch: str, n_decoded: int, prompt: int = 64, tt: bool = True):
    # lazy: the GVSA cycle model only exists in package context; the --serve
    # mode below runs standalone without it
    try:
        from .gvsa_latency import model_block_ops
    except ImportError as e:
        raise SystemExit(
            "analytic mode needs package context: run "
            "`python -m benchmarks.decode_speed` (standalone invocation "
            "only supports --serve)") from e

    cfg = get_config(arch)
    ops_tt, ops_dense = model_block_ops(arch, seq=prompt + n_decoded)
    blk = sum((ops_tt if tt else ops_dense).values())
    n_tt = cfg.n_layers - cfg.ttd.first_tt_block
    per_tok_us = (n_tt * blk + cfg.ttd.first_tt_block * sum(ops_dense.values())) / 1e3 \
        if tt else cfg.n_layers * sum(ops_dense.values()) / 1e3
    return 1e3 / per_tok_us


def run(report=print):
    rows = []
    for arch in ("chatglm3-6b", "llama2-7b"):
        report(f"== {arch}: decode speed (tokens/s), TTD vs baseline")
        for n in (128, 512, 1024, 2048):
            t_tt = tokens_per_s(arch, n, tt=True)
            t_base = tokens_per_s(arch, n, tt=False)
            report(f"   {n:5d} decoded: TTD {t_tt:7.1f} tok/s  baseline {t_base:7.1f}"
                   f"  speedup {t_tt/t_base:4.2f}x")
            rows.append((arch, n, t_tt, t_base))
        # paper peak speeds: 69.7 tok/s (1.45x) / 65.8 tok/s (1.57x) — the
        # absolute number depends on HBM modelling we don't replicate; the
        # ratio is the reproduced quantity.
    return rows


# ---------------------------------------------------------------------------
# Real-engine comparison: every family through the unified session engine
# ---------------------------------------------------------------------------
SERVE_FAMILIES = (
    # (label, arch, backend or None for the family default)
    ("dense/paged", "tinyllama-1.1b", "paged"),
    ("dense/ring", "tinyllama-1.1b", "ring"),
    ("moe", "kimi-k2-1t-a32b", None),
    ("griffin", "recurrentgemma-2b", None),
    ("rwkv", "rwkv6-7b", None),
    ("encdec", "whisper-base", None),
)


def _workload(n_requests: int, max_tokens: int):
    """Deterministic mixed-length prompt set (same for every engine)."""
    return [([1 + (i % 7), 2, 3 + i] + list(range(4, 4 + (i * 3) % 9)),
             max_tokens) for i in range(n_requests)]


def _bench_engine(make_engine, workload, ttft_slo_s):
    # shared summary schema with BENCH_traffic.json (repro.traffic.report):
    # percentile rows from the obs registry, goodput from per-request outcomes
    from repro.obs import Observer
    from repro.traffic import goodput_tok_per_s, outcome_of, registry_summary

    # warmup engine runs the *whole workload* untimed so every program shape
    # (chunk grids, ragged decode) compiles before the timed run (step
    # programs are memoized per session type in serve.steps, so the timed
    # engine below hits the trace cache); obs stays off for the warmup
    warm = make_engine(False)
    for p, m in workload:
        warm.submit(p, max_tokens=m)
    warm.run()
    # timed run records into a fresh per-run registry (DESIGN.md §9)
    obs = Observer()
    eng = make_engine(obs)
    reqs = [eng.submit(p, max_tokens=m) for p, m in workload]
    t0 = time.perf_counter()
    done = eng.run()
    wall = time.perf_counter() - t0
    assert len(done) == len(workload)
    toks = sum(len(r.out_tokens) for r in done)
    ftl = sum(r.t_first - r.t_submit for r in reqs) / len(reqs)
    summary = registry_summary(obs.registry)
    assert summary["tokens"] == toks
    outcomes = [outcome_of(r, ttft_slo_s=ttft_slo_s, idx=i)
                for i, r in enumerate(reqs)]
    return {"wall_s": wall, "tok_per_s": toks / wall,
            "goodput_tok_per_s": goodput_tok_per_s(outcomes, wall),
            "ttft_slo_s": ttft_slo_s,
            "n_slo_attained": sum(o.slo_attained for o in outcomes),
            "mean_first_token_s": ftl, **summary}


def run_serve(report=print, *, slot_counts=(2, 4), n_requests=8,
              max_tokens=8, ttft_slo_s=0.5, out_path="BENCH_serve.json"):
    import jax

    from repro.kernels import dispatch
    from repro.models import build_model
    from repro.serve.engine import Engine

    workload = _workload(n_requests, max_tokens)
    max_len = 96
    rows = []
    report(f"== serve: families × slots, {n_requests} requests × {max_tokens} "
           "tokens (CPU wall-clock, reduced configs — structural comparison)")
    for label, arch, backend in SERVE_FAMILIES:
        cfg = get_config(arch, reduced=True).replace(
            compute_dtype="float32", param_dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        # per-family counter reset so attention-free families (rwkv) report
        # null instead of inheriting the previous family's resolution
        dispatch.reset_dispatch_metrics()
        for slots in slot_counts:
            r = _bench_engine(
                lambda obs: Engine(model, params, slots=slots, max_len=max_len,
                                   backend=backend, block_size=8,
                                   prefill_batch=min(slots, 4),
                                   prefill_chunk=8, obs=obs),
                workload, ttft_slo_s)
            # the kernel backends the engine's programs *actually* baked in
            # at trace time (kernels.dispatch records it at resolution), not
            # a re-derivation of the policy chain the benchmark hopes matched;
            # recurrent families additionally report their scan role
            prefill_backend = dispatch.resolved_backend("attn_prefill")
            scan_role = {"griffin": "rglru_scan", "rwkv": "wkv_scan"}.get(label)
            scan_backend = (dispatch.resolved_backend(scan_role)
                            if scan_role else None)
            p95 = r["ttft_s"]["p95"]
            report(f"   {label:12s} slots={slots}: {r['tok_per_s']:7.1f} tok/s "
                   f"goodput {r['goodput_tok_per_s']:7.1f}  "
                   f"ttft mean {r['mean_first_token_s']*1e3:7.1f}ms "
                   f"p95 {p95*1e3:7.1f}ms  prefill={prefill_backend}"
                   + (f"  scan={scan_backend}" if scan_role else ""))
            rows.append({"family": label, "arch": arch, "slots": slots,
                         "prefill_attention_backend": prefill_backend,
                         "recurrent_scan_backend": scan_backend, **r})
    rec = {
        "workload": {"n_requests": n_requests, "max_tokens": max_tokens,
                     "max_len": max_len, "ttft_slo_s": ttft_slo_s},
        "note": "CPU wall-clock on the reduced configs: compares the "
                "families' state-backend structure through one scheduler "
                "(batched chunked prefill + one ragged decode call per "
                "tick), not TPU kernel performance.  Summary rows share "
                "the repro.traffic.report schema with BENCH_traffic.json.",
        "rows": rows,
    }
    Path(out_path).write_text(json.dumps(rec, indent=1))
    report(f"wrote {out_path}")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--serve", action="store_true",
                    help="benchmark the real ring vs paged serving engines")
    ap.add_argument("--slots", type=int, nargs="*", default=None)
    ap.add_argument("--ttft-slo", type=float, default=0.5,
                    help="TTFT SLO (seconds) used for the goodput column")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)
    if args.serve:
        run_serve(slot_counts=tuple(args.slots or (2, 4)),
                  ttft_slo_s=args.ttft_slo, out_path=args.out)
    else:
        run()


if __name__ == "__main__":
    main()

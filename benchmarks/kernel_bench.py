"""Kernel-level microbenchmarks (CPU wall-time, structural comparison).

Compares the per-call cost of: dense matmul vs staged TT contraction (the
pure-JAX path the dry-run lowers) for the paper's layer shapes.  On CPU,
times track FLOPs, so the TT FLOP reduction (8-18x for Table-I shapes) shows
directly; the Pallas kernel's VMEM behaviour can't be timed here (interpret
mode is Python) and is validated for correctness in tests/test_kernels.py.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.tt_linear import init_tt_linear, tt_linear_apply
from repro.core.ttd import TTSpec

SHAPES = [
    ("chatglm_O", 4096, 4096, (16, 8, 8, 4), (4, 8, 8, 16)),
    ("chatglm_mlp", 4096, 13696, (8, 8, 8, 8), (4, 4, 8, 107)),
    ("llama_mlp_dn", 11008, 4096, (43, 16, 4, 4), (4, 8, 8, 16)),
]


def _time(f, *args, iters=5):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run(report=print, batch=64):
    rows = []
    key = jax.random.PRNGKey(0)
    for name, n, m, nm, mm in SHAPES:
        spec = TTSpec.make(n, m, 16, in_modes=nm, out_modes=mm)
        params = init_tt_linear(key, spec, jnp.float32)
        w = jax.random.normal(key, (n, m), jnp.float32)
        x = jax.random.normal(key, (batch, n), jnp.float32)
        f_tt = jax.jit(lambda x: tt_linear_apply(params, x, spec))
        f_dense = jax.jit(lambda x: x @ w)
        us_tt = _time(f_tt, x)
        us_dense = _time(f_dense, x)
        flop_ratio = (2 * n * m) / spec.flops_per_token()
        report(f"{name:14s} B={batch}: dense {us_dense:9.1f}us  tt {us_tt:9.1f}us "
               f"speedup {us_dense/us_tt:5.2f}x (flop ratio {flop_ratio:5.2f}x)")
        rows.append((name, us_dense, us_tt, flop_ratio))
    return rows


if __name__ == "__main__":
    run()

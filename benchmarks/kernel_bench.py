"""Kernel-level microbenchmarks (CPU wall-time, structural comparison).

Two modes:

* default — per-call cost of dense matmul vs staged TT contraction (the
  pure-JAX path the dry-run lowers) for the paper's layer shapes.  On CPU,
  times track FLOPs, so the TT FLOP reduction (8-18x for Table-I shapes)
  shows directly.
* ``--dispatch`` (also implied by ``--smoke``) — per-layer ref vs
  pallas-interpret numbers through ``repro.kernels.dispatch`` for the tt and
  int4 kinds with fused epilogues, written to ``BENCH_kernels.json``.  The
  interpreter executes the exact kernel body on CPU, so this validates the
  production dispatch path end-to-end (and guards it against rot in CI via
  ``--smoke``); real VMEM behaviour needs a TPU.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import quantize_int4
from repro.core.tt_linear import init_tt_linear, tt_linear_apply
from repro.core.ttd import TTSpec
from repro.kernels import dispatch

SHAPES = [
    ("chatglm_O", 4096, 4096, (16, 8, 8, 4), (4, 8, 8, 16)),
    ("chatglm_mlp", 4096, 13696, (8, 8, 8, 8), (4, 4, 8, 107)),
    ("llama_mlp_dn", 11008, 4096, (43, 16, 4, 4), (4, 8, 8, 16)),
]

SMOKE_SHAPES = [
    ("smoke_O", 256, 512, (4, 4, 4, 4), (8, 8, 4, 2)),
]


def _time(f, *args, iters=5):
    jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run(report=print, batch=64):
    rows = []
    key = jax.random.PRNGKey(0)
    for name, n, m, nm, mm in SHAPES:
        spec = TTSpec.make(n, m, 16, in_modes=nm, out_modes=mm)
        params = init_tt_linear(key, spec, jnp.float32)
        w = jax.random.normal(key, (n, m), jnp.float32)
        x = jax.random.normal(key, (batch, n), jnp.float32)
        f_tt = jax.jit(lambda x: tt_linear_apply(params, x, spec))
        f_dense = jax.jit(lambda x: x @ w)
        us_tt = _time(f_tt, x)
        us_dense = _time(f_dense, x)
        flop_ratio = (2 * n * m) / spec.flops_per_token()
        report(f"{name:14s} B={batch}: dense {us_dense:9.1f}us  tt {us_tt:9.1f}us "
               f"speedup {us_dense/us_tt:5.2f}x (flop ratio {flop_ratio:5.2f}x)")
        rows.append((name, us_dense, us_tt, flop_ratio))
    return rows


def _rel_err(a, b):
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    scale = float(jnp.max(jnp.abs(b))) or 1.0
    return float(jnp.max(jnp.abs(a - b))) / scale


def _prefill_attention_rows(*, iters, smoke):
    """Chunked-prefill attention (paged + ring layouts) through
    ``dispatch.prefill_attention``: ref gather oracle vs the streaming
    Pallas kernel under the interpreter."""
    rng = np.random.default_rng(0)
    if smoke:
        b, chunk, ctx, bs, hkv, g, dh, wr, win = 2, 8, 24, 4, 2, 2, 16, 16, 8
    else:
        b, chunk, ctx, bs, hkv, g, dh, wr, win = 4, 32, 256, 16, 4, 4, 64, 160, 128
    h = hkv * g
    q = jnp.asarray(rng.standard_normal((b, chunk, h, dh)), jnp.float32)
    qpos = jnp.broadcast_to(jnp.arange(ctx - chunk, ctx, dtype=jnp.int32), (b, chunk))
    rows = []

    # paged layout: each sequence owns a contiguous run of the shuffled pool
    nb = 1 + b * ((ctx + bs - 1) // bs)
    cache = {"k": jnp.asarray(rng.standard_normal((nb, bs, hkv, dh)), jnp.float32),
             "v": jnp.asarray(rng.standard_normal((nb, bs, hkv, dh)), jnp.float32)}
    perm = rng.permutation(np.arange(1, nb))
    bt = jnp.asarray(perm.reshape(b, -1), jnp.int32)

    def paged(backend):
        f = jax.jit(lambda q: dispatch.prefill_attention(
            q, qpos, cache=cache, block_tables=bt, backend=backend))
        return f, (q,)

    # ring layout (SWA): ring of window + chunk entries, position p at p % wr
    k = jnp.asarray(rng.standard_normal((b, wr, hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, wr, hkv, dh)), jnp.float32)
    kp = np.full((b, wr), -1, np.int32)
    for p in range(max(0, ctx - wr), ctx):
        kp[:, p % wr] = p
    kp = jnp.asarray(kp)

    def ring(backend):
        f = jax.jit(lambda q: dispatch.prefill_attention(
            q, qpos, k=k, v=v, kpos=kp, window=win, backend=backend))
        return f, (q,)

    for name, make in (("prefill_paged", paged), ("prefill_ring_swa", ring)):
        f_ref, args = make("ref")
        f_pl, _ = make("pallas-interpret")
        y_ref, y_pl = f_ref(*args), f_pl(*args)
        rows.append({"name": name, "kind": "prefill_attention",
                     "n_in": ctx, "n_out": chunk, "batch": b,
                     "ref_us": _time(f_ref, *args, iters=iters),
                     "pallas_interpret_us": _time(f_pl, *args, iters=iters),
                     "max_rel_err": _rel_err(y_pl, y_ref)})
    return rows


def _scan_rows(*, iters, smoke):
    """Recurrent-scan kernels (RG-LRU / wkv) through ``dispatch.rglru_scan``
    / ``dispatch.wkv_scan``: jnp oracles vs the fused Pallas kernels under
    the interpreter, on a chunked-prefill-shaped call."""
    rng = np.random.default_rng(1)
    if smoke:
        b, s, w, h, hd = 2, 8, 32, 2, 8
    else:
        b, s, w, h, hd = 4, 32, 256, 4, 32
    rows = []

    log_a = -jnp.abs(jnp.asarray(rng.standard_normal((b, s, w)), jnp.float32)) * 0.5
    gx = jnp.asarray(rng.standard_normal((b, s, w)), jnp.float32)
    h0 = jnp.asarray(rng.standard_normal((b, w)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def rglru(backend):
        f = jax.jit(lambda a, g: dispatch.rglru_scan(a, g, h0, pos,
                                                     backend=backend))
        return f, (log_a, gx)

    r = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    wd = jnp.asarray(1.0 / (1.0 + np.exp(-rng.standard_normal((b, s, h, hd)))),
                     jnp.float32) * 0.98 + 0.01
    u = jnp.asarray(rng.standard_normal((h, hd)), jnp.float32) * 0.1
    s0 = jnp.asarray(rng.standard_normal((b, h, hd, hd)), jnp.float32) * 0.3

    def wkv(backend):
        f = jax.jit(lambda r, k: dispatch.wkv_scan(r, k, v, wd, u, s0, pos,
                                                   backend=backend))
        return f, (r, k)

    for name, make, width in (("rglru_scan", rglru, w), ("wkv_scan", wkv, h * hd)):
        f_ref, args = make("ref")
        f_pl, _ = make("pallas-interpret")
        y_ref, y_pl = f_ref(*args)[0], f_pl(*args)[0]
        rows.append({"name": name, "kind": "recurrent_scan",
                     "n_in": s, "n_out": width, "batch": b,
                     "ref_us": _time(f_ref, *args, iters=iters),
                     "pallas_interpret_us": _time(f_pl, *args, iters=iters),
                     "max_rel_err": _rel_err(y_pl, y_ref)})
    return rows


def run_dispatch(report=print, *, batch=32, iters=3, smoke=False,
                 out_path="BENCH_kernels.json"):
    """Per-layer ref vs pallas-interpret through the dispatch layer."""
    key = jax.random.PRNGKey(0)
    shapes = SMOKE_SHAPES if smoke else SHAPES
    rank = 4 if smoke else 16
    rows = []
    for name, n, m, nm, mm in shapes:
        spec = TTSpec.make(n, m, rank, in_modes=nm, out_modes=mm)
        cores = init_tt_linear(key, spec, jnp.float32)["cores"]
        k1, k2, k3, k4 = jax.random.split(key, 4)
        x = jax.random.normal(k1, (batch, n), jnp.float32)
        sc = jax.random.normal(k2, (m,), jnp.float32)
        bi = jax.random.normal(k3, (m,), jnp.float32)
        res = jax.random.normal(k4, (batch, m), jnp.float32)

        def tt(backend):
            f = jax.jit(lambda x, res: dispatch.tt_linear(
                x, cores, spec, scale=sc, bias=bi, residual=res, backend=backend))
            return f, (x, res)

        f_ref, args = tt("ref")
        f_pl, _ = tt("pallas-interpret")
        y_ref, y_pl = f_ref(*args), f_pl(*args)
        row = {"name": f"{name}_tt_bn_res", "kind": "tt",
               "n_in": n, "n_out": m, "batch": batch,
               "ref_us": _time(f_ref, *args, iters=iters),
               "pallas_interpret_us": _time(f_pl, *args, iters=iters),
               "max_rel_err": _rel_err(y_pl, y_ref)}
        rows.append(row)

        # int4 (w4a16) with bias+residual epilogue for the same layer shape
        group = 64 if smoke else 128
        w = jax.random.normal(k2, (m, n), jnp.float32) / (n ** 0.5)
        q = quantize_int4(w, group)

        def i4(backend):
            f = jax.jit(lambda x, res: dispatch.int4_matmul(
                x, q["qweight"], q["scales"], group=group, bias=bi,
                residual=res, backend=backend))
            return f, (x, res)

        f_ref, args = i4("ref")
        f_pl, _ = i4("pallas-interpret")
        y_ref, y_pl = f_ref(*args), f_pl(*args)
        rows.append({"name": f"{name}_int4_bias_res", "kind": "int4",
                     "n_in": n, "n_out": m, "batch": batch,
                     "ref_us": _time(f_ref, *args, iters=iters),
                     "pallas_interpret_us": _time(f_pl, *args, iters=iters),
                     "max_rel_err": _rel_err(y_pl, y_ref)})

    rows.extend(_prefill_attention_rows(iters=iters, smoke=smoke))
    rows.extend(_scan_rows(iters=iters, smoke=smoke))

    # pallas-interpret timings are Python-interpreter wall-time — useful only
    # as a parity/rot gate.  Label them so e.g. the int4 row's apparent
    # "regression" vs ref isn't read as a kernel problem.
    note = ("pallas-interpret timings are interpreter wall-time "
            "(parity gate only) — NOT representative of TPU performance")
    for r in rows:
        r["timings_representative"] = False
        report(f"{r['name']:24s} B={r['batch']}: ref {r['ref_us']:9.1f}us  "
               f"pallas-interpret {r['pallas_interpret_us']:9.1f}us "
               f"[interpreted; not TPU-representative]  "
               f"max_rel_err {r['max_rel_err']:.2e}")
        if r["max_rel_err"] > 1e-4:
            raise SystemExit(f"dispatch parity failed for {r['name']}: "
                             f"{r['max_rel_err']:.3e}")
    report(f"note: {note}")
    rec = {"mode": "smoke" if smoke else "full", "batch": batch,
           "timings_note": note, "rows": rows}
    Path(out_path).write_text(json.dumps(rec, indent=1))
    report(f"wrote {out_path}")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--dispatch", action="store_true",
                    help="benchmark ref vs pallas-interpret through the dispatch layer")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-shape dispatch parity run (CI gate; implies --dispatch)")
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--out", default="BENCH_kernels.json")
    args = ap.parse_args(argv)
    if args.dispatch or args.smoke:
        run_dispatch(batch=args.batch or (8 if args.smoke else 32),
                     iters=1 if args.smoke else 3, smoke=args.smoke,
                     out_path=args.out)
    else:
        run(batch=args.batch or 64)


if __name__ == "__main__":
    main()

"""Roofline analysis (EXPERIMENTS.md §Roofline).

Per (arch × shape-cell) on the single-pod 16×16 mesh:

    t_compute    = FLOPs / (chips · 197e12)          [TPU v5e bf16 peak]
    t_memory     = HBM bytes / (chips · 819e9)
    t_collective = link bytes / (chips-normalized 50e9 per link)

Sources, in order of trust:
  * FLOPs: analytic model (benchmarks/flops_model.py) — exact; the HLO
    cost_analysis numbers (raw + depth-delta corrected) are cross-checks,
    because XLA counts scan bodies once regardless of trip count
    (demonstrated in EXPERIMENTS.md §Methodology).
  * bytes: depth-delta-corrected HLO "bytes accessed" (per-device).
  * collective bytes: depth-delta-corrected, ring-traffic-weighted per-op
    sums parsed from the post-SPMD HLO (launch/dryrun.py).

MODEL_FLOPS ratio = model_flops / impl_flops — how much compiled compute is
"useful" (catches remat, capacity padding, unmasked-attention waste).
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path

from repro.config import SHAPE_CELLS, shape_cell
from repro.configs import ALL_ARCHS, get_config

from .flops_model import cell_flops, cell_traffic

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
LINK_BW = 50e9  # B/s / link
CHIPS = 256

SEG_COUNTS = {  # how many of each probe-delta unit the full model has
    # family-style plans resolved per arch below
}


@dataclass
class CellRoofline:
    arch: str
    cell: str
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    model_flops: float
    impl_flops: float
    flops_hlo_raw: float
    flops_hlo_corrected: float
    bytes_corrected: float
    coll_corrected: float
    mfu_bound: float
    mfu_dense_equiv: float = 0.0
    skipped: str | None = None


def _probe_extrapolate(arch: str, rec: dict, probes: dict, mb: int):
    """total = base + Σ n_seg · Δ_seg for flops/bytes/coll."""
    cfg = get_config(arch)
    p = probes["probes"] if probes else None

    def field(tag, name):
        return p[tag][name]

    def combine(name, raw_value):
        if p is None:
            return raw_value
        fam = cfg.family
        try:
            if fam == "encdec":
                d_enc = field("e2d1", name) - field("e1d1", name)
                d_dec = field("e1d2", name) - field("e1d1", name)
                base = field("e1d1", name) - d_enc - d_dec
                tot = base + cfg.n_enc_layers * d_enc + cfg.n_layers * d_dec
            elif fam == "griffin":
                d_grp = field("g2", name) - field("g1", name)
                d_rec = field("g1r1", name) - field("g1", name)
                base = field("g1", name) - d_grp
                n_groups = cfg.n_layers // 3
                tail = cfg.n_layers - 3 * n_groups
                tot = base + n_groups * d_grp + tail * d_rec
            elif "d1" in p:  # two-segment transformer
                dd = field("d2", name) - field("d1", name)
                dt = field("t2", name) - field("t1", name)
                base = field("d1", name) - dd
                ft = cfg.ttd.first_tt_block
                tot = base + ft * dd + (cfg.n_layers - ft) * dt
            else:
                dl = field("L2", name) - field("L1", name)
                base = field("L1", name) - dl
                tot = base + cfg.n_layers * dl
            return max(tot, raw_value) * 1.0
        except KeyError:
            return raw_value

    flops_c = combine("flops", rec.get("flops", 0.0)) * mb
    bytes_c = combine("bytes", rec.get("bytes_accessed", 0.0)) * mb
    coll_c = combine("coll", rec.get("collectives", {}).get("total", 0.0)) * mb
    return flops_c, bytes_c, coll_c


def load_cell(dry_dir: Path, arch: str, cell_name: str) -> CellRoofline | None:
    f = dry_dir / f"{arch}_{cell_name}_16x16.json"
    if not f.exists():
        return None
    rec = json.loads(f.read_text())
    if "skipped" in rec:
        return CellRoofline(arch, cell_name, 0, 0, 0, "-", 0, 0, 0, 0, 0, 0, 0,
                            skipped=rec["skipped"])
    pf = dry_dir / f"{arch}_{cell_name}_16x16_probes.json"
    probes = json.loads(pf.read_text()) if pf.exists() else None
    mb = rec.get("microbatches", 1)
    flops_c, bytes_c, coll_c = _probe_extrapolate(arch, rec, probes, mb)

    cf = cell_flops(arch, shape_cell(cell_name))
    impl = cf.impl_total  # global
    hbm_a, coll_a = cell_traffic(arch, shape_cell(cell_name))
    t_comp = impl / (CHIPS * PEAK_FLOPS)
    # analytic traffic is primary; HLO numbers are kept as cross-checks
    t_mem = hbm_a / HBM_BW
    t_coll = coll_a / LINK_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    t_model = cf.model_flops / (CHIPS * PEAK_FLOPS)
    mfu_bound = t_model / max(max(terms.values()), 1e-12)
    t_model_d = cf.model_flops_dense / (CHIPS * PEAK_FLOPS)
    mfu_dense_equiv = t_model_d / max(max(terms.values()), 1e-12)
    return CellRoofline(
        arch=arch, cell=cell_name, t_compute=t_comp, t_memory=t_mem,
        t_collective=t_coll, dominant=dom, model_flops=cf.model_flops,
        impl_flops=impl, flops_hlo_raw=rec.get("flops", 0.0) * CHIPS,
        flops_hlo_corrected=flops_c * CHIPS, bytes_corrected=bytes_c,
        coll_corrected=coll_c, mfu_bound=mfu_bound,
        mfu_dense_equiv=mfu_dense_equiv)


def run(report=print, dry_dir="experiments/dryrun", csv_out="experiments/roofline.csv"):
    dry_dir = Path(dry_dir)
    rows = []
    csv_lines = ["arch,cell,t_compute,t_memory,t_collective,dominant,"
                 "model_flops,impl_flops,hlo_flops_raw,hlo_flops_corrected,"
                 "hlo_bytes_corrected,hlo_coll_corrected,mfu_bound,mfu_dense_equiv,skipped"]
    report(f"{'arch':<18s} {'cell':<12s} {'t_comp':>9s} {'t_mem':>9s} {'t_coll':>9s} "
           f"{'dominant':>10s} {'MF/impl':>8s} {'MFU_bound':>9s} {'MFU_dense':>9s}")
    for arch in ALL_ARCHS:
        for cell in SHAPE_CELLS:
            r = load_cell(dry_dir, arch, cell.name)
            if r is None:
                continue
            if r.skipped:
                report(f"{arch:<18s} {cell.name:<12s} {'SKIP':>9s}  ({r.skipped[:60]})")
                rows.append(r)
                csv_lines.append(f"{r.arch},{r.cell},,,,,,,,,,,,,{r.skipped}")
                continue
            ratio = r.model_flops / max(r.impl_flops, 1)
            report(f"{arch:<18s} {cell.name:<12s} {r.t_compute:9.4f} {r.t_memory:9.4f} "
                   f"{r.t_collective:9.4f} {r.dominant:>10s} {ratio:8.2f} "
                   f"{r.mfu_bound:9.3f} {r.mfu_dense_equiv:9.3f}")
            rows.append(r)
            csv_lines.append(
                f"{r.arch},{r.cell},{r.t_compute:.6f},{r.t_memory:.6f},"
                f"{r.t_collective:.6f},{r.dominant},{r.model_flops:.4e},"
                f"{r.impl_flops:.4e},{r.flops_hlo_raw:.4e},{r.flops_hlo_corrected:.4e},"
                f"{r.bytes_corrected:.4e},{r.coll_corrected:.4e},"
                f"{r.mfu_bound:.4f},{r.mfu_dense_equiv:.4f},")
    if csv_out:
        Path(csv_out).parent.mkdir(parents=True, exist_ok=True)
        Path(csv_out).write_text("\n".join(csv_lines))
    return rows


if __name__ == "__main__":
    run()

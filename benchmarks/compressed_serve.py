"""CR-vs-serving-speed pareto sweep for TT-compressed models (paper §V).

Serves ``compress_model``-ed trees through the real unified engine
(``repro.serve.engine``) for the paper-target configs and reports, per
(config, compression variant) row: the Table-I CR numbers, obs-registry
TTFT percentiles, decoded tokens/sec, and the kernel backend the traced
programs actually baked in — the Fig. 9 / first-token-delay claim as a
measurable pareto front.  Variants: dense baseline, TT linears, TT+int4,
TT+TT-embedding (TensorGPT-style vocab-axis TT).  CPU wall-time on the
reduced configs — a structural comparison, not TPU performance.

    PYTHONPATH=src python benchmarks/compressed_serve.py
    PYTHONPATH=src python benchmarks/compressed_serve.py --smoke
    PYTHONPATH=src python benchmarks/compressed_serve.py \
        --check-schema BENCH_compressed_serve.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

from repro.config import QuantConfig, TTDConfig
from repro.configs import get_config

ARCHS = ("tinyllama-1.1b", "chatglm3-6b", "llama2-7b")
VARIANTS = ("dense", "tt", "tt_int4", "tt_embed")


def variant_cfgs(arch: str, variant: str):
    """(dense source cfg, compression target cfg) for one sweep row."""
    base = get_config(arch, reduced=True).replace(
        compute_dtype="float32", param_dtype="float32")
    dense = base.replace(ttd=TTDConfig(enabled=False),
                         quant=QuantConfig(enabled=False))
    if variant == "dense":
        return dense, dense
    target = base  # reduced configs carry the TT recipe (rank 4, d 3)
    if variant == "tt_int4":
        target = target.replace(quant=QuantConfig(enabled=True, bits=4,
                                                  group_size=32))
    elif variant == "tt_embed":
        target = target.replace(ttd=dataclasses.replace(target.ttd, embed=True))
    return dense, target


def _workload(n_requests: int, max_tokens: int):
    return [([1 + (i % 7), 2, 3 + i] + list(range(4, 4 + (i * 3) % 9)),
             max_tokens) for i in range(n_requests)]


def _pcts(h):
    if h is None or h.count == 0:
        return {"p50": None, "p95": None, "p99": None, "mean": None}
    return {"p50": h.percentile(0.50), "p95": h.percentile(0.95),
            "p99": h.percentile(0.99), "mean": h.mean()}


def _bench_engine(make_engine, workload):
    from repro.obs import Observer

    warm = make_engine(False)  # untimed full-workload warmup (compiles)
    for p, m in workload:
        warm.submit(p, max_tokens=m)
    warm.run()
    obs = Observer()
    eng = make_engine(obs)
    reqs = [eng.submit(p, max_tokens=m) for p, m in workload]
    t0 = time.perf_counter()
    done = eng.run()
    wall = time.perf_counter() - t0
    assert len(done) == len(workload)
    toks = sum(len(r.out_tokens) for r in done)
    reg = obs.registry
    assert reg.get("serve_tokens_total").value == toks
    return {"tokens": toks, "wall_s": wall, "tok_per_s": toks / wall,
            "mean_first_token_s":
                sum(r.t_first - r.t_submit for r in reqs) / len(reqs),
            "ttft_s": _pcts(reg.get("serve_ttft_seconds")),
            "inter_token_s": _pcts(reg.get("serve_inter_token_seconds"))}


def _cr_row(target_cfg):
    from repro.core.compress import compression_report

    rep = compression_report(target_cfg)
    return {"block": rep.block_cr, "network": rep.network_cr,
            "network_with_embed": rep.network_cr_with_embed,
            "bits": rep.network_cr_bits}


def _traced_backends():
    """{role: backend} the programs traced in this row actually baked in."""
    from repro.kernels import dispatch

    return {role: dispatch.resolved_backend(role)
            for role in sorted({r for r, _ in dispatch.dispatch_counts()})}


def run(report=print, *, archs=ARCHS, variants=VARIANTS, n_requests=6,
        max_tokens=6, slots=2, out_path="BENCH_compressed_serve.json"):
    import jax

    from repro.core.compress import compress_model
    from repro.kernels import dispatch
    from repro.models import build_model
    from repro.serve.engine import Engine

    workload = _workload(n_requests, max_tokens)
    max_len = 96
    rows = []
    report(f"== compressed serve: {len(archs)} configs x {len(variants)} "
           f"variants, {n_requests} requests x {max_tokens} tokens")
    for arch in archs:
        dense_cfg, _ = variant_cfgs(arch, "dense")
        dense_model = build_model(dense_cfg)
        dense_params = dense_model.init(jax.random.PRNGKey(0))
        for variant in variants:
            _, target = variant_cfgs(arch, variant)
            params = (dense_params if variant == "dense"
                      else compress_model(dense_params, dense_cfg, target))
            model = build_model(target)
            dispatch.reset_dispatch_metrics()
            r = _bench_engine(
                lambda obs: Engine(model, params, slots=slots, max_len=max_len,
                                   block_size=8, prefill_batch=slots,
                                   prefill_chunk=8, obs=obs),
                workload)
            cr = _cr_row(target)
            backends = _traced_backends()
            p95 = r["ttft_s"]["p95"]
            report(f"   {arch:14s} {variant:8s} CR(net+emb) "
                   f"{cr['network_with_embed']:5.2f}  {r['tok_per_s']:7.1f} "
                   f"tok/s  ttft p50 {r['ttft_s']['p50']*1e3:7.1f}ms "
                   f"p95 {p95*1e3:7.1f}ms  "
                   f"prefill={backends.get('attn_prefill')}")
            rows.append({"arch": arch, "variant": variant, "cr": cr,
                         "backends": backends, **r})
    rec = {
        "workload": {"n_requests": n_requests, "max_tokens": max_tokens,
                     "max_len": max_len, "slots": slots},
        "note": "CPU wall-clock on the reduced configs: the CR-vs-latency "
                "pareto structure of serving compress_model trees through "
                "the unified engine (chunked prefill + ragged decode), not "
                "TPU kernel performance.",
        "rows": rows,
    }
    Path(out_path).write_text(json.dumps(rec, indent=1))
    report(f"wrote {out_path}")
    return rows


# ---------------------------------------------------------------------------
# CI modes
# ---------------------------------------------------------------------------
def smoke(report=print):
    """Compress a tiny config, serve it, assert tokens are well-formed."""
    import jax

    from repro.core.compress import compress_model
    from repro.models import build_model
    from repro.serve.engine import Engine

    dense_cfg, target = variant_cfgs("tinyllama-1.1b", "tt_embed")
    target = target.replace(quant=QuantConfig(enabled=True, bits=4,
                                              group_size=32))
    dense_model = build_model(dense_cfg)
    params = compress_model(dense_model.init(jax.random.PRNGKey(0)),
                            dense_cfg, target)
    eng = Engine(build_model(target), params, slots=2, max_len=64,
                 prefill_chunk=8)
    reqs = [eng.submit([1 + i, 2, 3, 4 + i], max_tokens=5) for i in range(4)]
    done = eng.run()
    assert len(done) == len(reqs)
    for r in done:
        assert len(r.out_tokens) == 5, r.out_tokens
        assert all(isinstance(t, int) and 0 <= t < target.vocab_size
                   for t in r.out_tokens), r.out_tokens
    report(f"smoke OK: {[r.out_tokens for r in done]}")


def check_schema(path, report=print):
    """Validate BENCH_compressed_serve.json against the acceptance shape.

    Delegates to the shared BENCH schema table (``repro.analyze.bench``) —
    the same validation ``python -m repro.analyze --bench`` runs in CI.
    """
    from repro.analyze.bench import check_file

    errors = check_file("compressed_serve", Path(path))
    assert not errors, "; ".join(errors)
    rows = json.loads(Path(path).read_text())["rows"]
    report(f"schema OK: {path} ({len(rows)} rows, "
           f"{len({r['variant'] for r in rows})} variants x "
           f"{len({r['arch'] for r in rows})} configs)")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI: compress + serve one tiny config, assert "
                         "well-formed tokens")
    ap.add_argument("--check-schema", metavar="PATH",
                    help="CI: schema-validate an existing results file")
    ap.add_argument("--out", default="BENCH_compressed_serve.json")
    args = ap.parse_args(argv)
    if args.smoke:
        smoke()
    elif args.check_schema:
        check_schema(args.check_schema)
    else:
        run(out_path=args.out)


if __name__ == "__main__":
    main()

"""Reproduce the paper's Tables III/IV + Fig. 8 (per-op delays on GVSA and
TTD speedups) from the analytical cycle model.

The paper's measured per-op delays are hard-coded below (Tables III/IV);
the model predicts each op from first principles + two calibration
constants, and we report measured vs model plus the three headline ratios:
MLP speedup (paper 3.22×/3.88×), block speedup (2.19×/1.78×), first-token
delay reduction (1.45×/1.57×).
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core.ttd import TTSpec

from .gvsa_model import (GVSAParams, attention_cycles, cycles_to_us,
                         dense_linear_cycles, nonlinear_cycles,
                         tt_linear_cycles)

# paper Table III (ChatGLM3-6B) and Table IV (LLaMA2-7B), per-op us
PAPER_TABLE_III = {
    "LN": 11.39, "Linear-BN(QK)": 51.03, "EMB(Q)": 6.54, "EMB(K)": 6.80,
    "Linear-TRP": 8.24, "Softmax": 26.08, "Linear-BN(V)": 7.47, "Linear": 8.99,
    "TTDLinear-BNRes(attnO)": 29.32, "LN2": 11.63, "TTDLinear-BN(mlp1)": 43.04,
    "ACT": 21.87, "TTDLinear-BNRes(mlp2)": 43.49, "TTDLinear-BNRes(mlp3)": 37.22,
}
PAPER_TABLE_IV = {
    "LN": 12.57, "Linear-BN(QK)": 91.23, "EMB(Q)": 4.82, "EMB(K)": 6.80,
    "Linear-TRP": 47.35, "Softmax": 22.35, "Linear-BN(V)": 51.94, "Linear": 44.13,
    "TTDLinear-BNRes(attnO)": 29.34, "LN2": 11.00, "TTDLinear-BN(mlp1)": 27.03,
    "ACT": 12.43, "TTDLinear-BNRes(mlp2)": 27.74, "TTDLinear-BNRes(mlp3)": 24.73,
}
PAPER_FIRST_TOKEN_MS = {"chatglm3-6b": 14.34, "llama2-7b": 15.20}
PAPER_SPEEDUPS = {  # (mlp, block, first-token)
    "chatglm3-6b": (3.22, 2.19, 1.45),
    "llama2-7b": (3.88, 1.78, 1.57),
}


def _tt_spec(cfg, role):
    ov = dict(cfg.ttd.overrides)[role]
    return TTSpec.make(1, 1, ov.rank, in_modes=ov.in_modes, out_modes=ov.out_modes)


def model_block_ops(arch: str, seq: int = 64, p: GVSAParams = GVSAParams()):
    """Per-op model latencies (us) for one TT block and one dense block."""
    cfg = get_config(arch)
    d, ff = cfg.d_model, cfg.d_ff
    kvd = cfg.kv_dim
    tt_o = _tt_spec(cfg, "attn_o")
    tt_up = _tt_spec(cfg, "mlp_gate")
    tt_dn = _tt_spec(cfg, "mlp_down")

    ops_tt = {
        "LN": nonlinear_cycles(d, p),
        "Linear-BN(QK)": dense_linear_cycles(d + kvd, d, 1, p),
        "EMB(Q)": nonlinear_cycles(d, p),
        "EMB(K)": nonlinear_cycles(kvd, p),
        "Linear-TRP": attention_cycles(seq, cfg.n_heads, cfg.head_dim, cfg.n_kv_heads, p),
        "Softmax": nonlinear_cycles(cfg.n_heads * seq, p) * 2,
        "Linear-BN(V)": dense_linear_cycles(kvd, d, 1, p),
        "Linear": attention_cycles(seq, cfg.n_heads, cfg.head_dim, cfg.n_kv_heads, p),
        "TTDLinear-BNRes(attnO)": tt_linear_cycles(tt_o, 1, p),
        "LN2": nonlinear_cycles(d, p),
        "TTDLinear-BN(mlp1)": tt_linear_cycles(tt_up, 1, p),
        "ACT": nonlinear_cycles(ff, p),
        "TTDLinear-BNRes(mlp2)": tt_linear_cycles(tt_up, 1, p),
        "TTDLinear-BNRes(mlp3)": tt_linear_cycles(tt_dn, 1, p),
    }
    ops_dense = dict(ops_tt)
    ops_dense["TTDLinear-BNRes(attnO)"] = dense_linear_cycles(d, d, 1, p)
    ops_dense["TTDLinear-BN(mlp1)"] = dense_linear_cycles(ff, d, 1, p)
    ops_dense["TTDLinear-BNRes(mlp2)"] = dense_linear_cycles(ff, d, 1, p)
    ops_dense["TTDLinear-BNRes(mlp3)"] = dense_linear_cycles(d, ff, 1, p)
    return ({k: cycles_to_us(v) for k, v in ops_tt.items()},
            {k: cycles_to_us(v) for k, v in ops_dense.items()})


def first_token_ms(arch: str, ops_tt, ops_dense):
    cfg = get_config(arch)
    n_tt = cfg.n_layers - cfg.ttd.first_tt_block
    n_dense = cfg.ttd.first_tt_block
    blk_tt = sum(ops_tt.values())
    blk_dense = sum(ops_dense.values())
    # output layer: LN + vocab projection (dense, int4)
    out_us = cycles_to_us(nonlinear_cycles(cfg.d_model)
                          + dense_linear_cycles(cfg.vocab_size, cfg.d_model))
    with_tt = (n_tt * blk_tt + n_dense * blk_dense) / 1e3 + out_us / 1e3
    without = cfg.n_layers * blk_dense / 1e3 + out_us / 1e3
    return with_tt, without


def run(report=print):
    rows = []
    for arch, paper_tbl in (("chatglm3-6b", PAPER_TABLE_III),
                            ("llama2-7b", PAPER_TABLE_IV)):
        ops_tt, ops_dense = model_block_ops(arch)
        report(f"== {arch}: per-op latency, model vs paper (us)")
        for op, paper_us in paper_tbl.items():
            report(f"  {op:26s} model={ops_tt[op]:8.2f}  paper={paper_us:8.2f}")
        mlp_ops = [k for k in ops_tt if "mlp" in k or k == "ACT" or k == "LN2"]
        mlp_tt = sum(ops_tt[k] for k in mlp_ops)
        mlp_dense = sum(ops_dense[k] for k in mlp_ops)
        blk_tt, blk_dense = sum(ops_tt.values()), sum(ops_dense.values())
        ft_tt, ft_dense = first_token_ms(arch, ops_tt, ops_dense)
        p_mlp, p_blk, p_ft = PAPER_SPEEDUPS[arch]
        report(f"  MLP speedup    model={mlp_dense/mlp_tt:5.2f}x  paper={p_mlp}x")
        report(f"  block speedup  model={blk_dense/blk_tt:5.2f}x  paper={p_blk}x")
        report(f"  first-token    model={ft_dense/ft_tt:5.2f}x  paper={p_ft}x "
               f"(model {ft_tt:.2f}ms vs paper {PAPER_FIRST_TOKEN_MS[arch]}ms)")
        rows.append((arch, mlp_dense / mlp_tt, blk_dense / blk_tt, ft_dense / ft_tt,
                     ft_tt))
    return rows


if __name__ == "__main__":
    run()

"""repro: TT-decomposition LLM compression on a JAX/Pallas stack."""
from . import _compat  # noqa: F401  (installs jax 0.4.x mesh-API shims on import)

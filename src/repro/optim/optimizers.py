"""Optimizers in pure JAX: AdamW and Adafactor.

Adafactor (factored second moments, Shazeer & Stern 2018) is the default for
the trillion-parameter configs: AdamW's 8 bytes/param of state exceeds
512×16 GB for kimi-k2-1t, Adafactor's factored statistics are ~0.01
bytes/param for matrices.  Optimizer state inherits the parameter sharding
(ZeRO: state lives on the shard that owns the parameter slice).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

EPS1 = 1e-30
EPS2 = 1e-3


@dataclass(frozen=True)
class OptState:
    kind: str  # adamw | adafactor
    inner: Any  # pytree of per-param states
    step: jax.Array


def _is_factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] >= 8 and shape[-2] >= 8


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_optimizer(kind: str, params) -> OptState:
    if kind == "adamw":
        inner = {
            "mu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "nu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        }
    elif kind == "adafactor":
        def leaf(p):
            if _is_factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros_like(p, jnp.float32)}
        inner = jax.tree.map(leaf, params)
    else:
        raise ValueError(kind)
    return OptState(kind=kind, inner=inner, step=jnp.zeros((), jnp.int32))


def opt_state_pspecs(kind: str, param_specs, params_shapes) -> Any:
    """Derive optimizer-state PartitionSpecs from parameter specs."""
    if kind == "adamw":
        return OptState(kind=kind,
                        inner={"mu": param_specs, "nu": param_specs},
                        step=P())

    def leaf(spec, p):
        if _is_factored(p.shape):
            return {"vr": P(*spec[:-1]), "vc": P(*(tuple(spec[:-2]) + (spec[-1],)))}
        return {"v": spec}

    inner = jax.tree.map(leaf, param_specs, params_shapes,
                         is_leaf=lambda x: isinstance(x, P))
    return OptState(kind=kind, inner=inner, step=P())


# ---------------------------------------------------------------------------
# update
# ---------------------------------------------------------------------------
def _global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_optimizer(
    state: OptState,
    params,
    grads,
    lr: jax.Array,
    *,
    weight_decay: float = 0.0,
    grad_clip: float = 0.0,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
) -> tuple[Any, OptState, dict]:
    gnorm = _global_norm(grads)
    if grad_clip > 0:
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)
    step = state.step + 1
    sf = step.astype(jnp.float32)

    if state.kind == "adamw":
        bc1 = 1 - b1 ** sf
        bc2 = 1 - b2 ** sf

        def upd(p, g, mu, nu):
            g = g.astype(jnp.float32)
            mu = b1 * mu + (1 - b1) * g
            nu = b2 * nu + (1 - b2) * g * g
            u = (mu / bc1) / (jnp.sqrt(nu / bc2) + eps)
            new_p = p.astype(jnp.float32) - lr * (u + weight_decay * p.astype(jnp.float32))
            return new_p.astype(p.dtype), mu, nu

        out = jax.tree.map(upd, params, grads, state.inner["mu"], state.inner["nu"])
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        new_state = OptState("adamw", {"mu": new_mu, "nu": new_nu}, step)
        return new_params, new_state, {"grad_norm": gnorm}

    # --- adafactor ---
    decay = 1.0 - sf ** -0.8  # \hat{beta}_2t

    def upd(p, g, st):
        g = g.astype(jnp.float32)
        g2 = g * g + EPS1
        if "vr" in st:
            vr = decay * st["vr"] + (1 - decay) * g2.mean(-1)
            vc = decay * st["vc"] + (1 - decay) * g2.mean(-2)
            denom = jnp.maximum(vr.mean(-1, keepdims=True), EPS1)
            v_hat = (vr[..., None] / denom[..., None]) * vc[..., None, :]
            u = g * jax.lax.rsqrt(v_hat + EPS1)
            new_st = {"vr": vr, "vc": vc}
        else:
            v = decay * st["v"] + (1 - decay) * g2
            u = g * jax.lax.rsqrt(v + EPS1)
            new_st = {"v": v}
        # RMS-clip the update (Adafactor d=1)
        rms = jnp.sqrt(jnp.mean(u * u) + EPS1)
        u = u / jnp.maximum(1.0, rms)
        new_p = p.astype(jnp.float32) - lr * (u + weight_decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), new_st

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = treedef.flatten_up_to(state.inner)
    new_p, new_s = [], []
    for p, g, st in zip(flat_p, flat_g, flat_s):
        np_, ns_ = upd(p, g, st)
        new_p.append(np_)
        new_s.append(ns_)
    new_params = jax.tree.unflatten(treedef, new_p)
    new_state = OptState("adafactor", jax.tree.unflatten(treedef, new_s), step)
    return new_params, new_state, {"grad_norm": gnorm}


jax.tree_util.register_pytree_node(
    OptState,
    lambda s: ((s.inner, s.step), s.kind),
    lambda kind, children: OptState(kind, children[0], children[1]),
)

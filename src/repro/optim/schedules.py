"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def schedule(step):
        # warmup counts from 1 so step 0 already trains (lr = lr/warmup)
        step = step.astype(jnp.float32) + 1.0
        warm = lr * jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0)
        t = jnp.clip((step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup_steps, warm, lr * cos)

    return schedule

from .optimizers import (  # noqa: F401
    OptState,
    init_optimizer,
    apply_optimizer,
    opt_state_pspecs,
)
from .schedules import warmup_cosine  # noqa: F401

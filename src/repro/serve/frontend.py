"""Asyncio serving front-end: submit / stream / cancel over the engine.

:class:`AsyncEngine` wraps the unified continuous-batching
:class:`~repro.serve.engine.Engine` behind an asyncio surface (DESIGN.md
§12): ``submit()`` returns a :class:`RequestHandle` immediately, tokens
arrive through ``async for tok in handle.stream()`` as the scheduler emits
them, ``handle.cancel()`` frees the request's slot and blocks mid-flight,
and ``submit(deadline_s=)`` rides the engine's deadline expiry.  One
background *pump* task drives the engine; consumers are ordinary coroutines
on the same event loop.

**Dispatch-ahead double buffering.**  The engine's decode tick is
schedule → dispatch → collect, and jax dispatch is asynchronous: launching
tick *N* returns logits immediately while the device computes.  When every
in-flight slot is guaranteed to survive its emission (greedy sampling, no
eos watch, away from the max_tokens/max_len frontier, pool growth without
preemption — ``Engine._plan_ahead``), the pump samples tick *N*'s tokens
with a **device-side argmax** and dispatches tick *N+1* from that device
array before anything touches the host.  Tick *N*'s tokens are then pulled
to host, bookkeeping runs, and stream consumers get their tokens — all
while the device is busy with tick *N+1*.  When the guarantee fails (a
request near its frontier, a pending cancel, a waiting admission), the pump
falls back to the synchronous collect-then-dispatch order, so emitted
tokens are **bitwise identical** to the synchronous engine either way
(``tests/test_frontend.py`` fuzzes this under Poisson arrivals with random
cancellations).

Invariants the pump maintains (the dispatch-ahead contract):

* at most one tick is in flight at any time (double buffering, not a queue);
* cancellations, deadline expiry, and admissions are applied only while no
  tick is in flight — a cancel arriving mid-flight is applied before the
  *next* dispatch, and collection skips slots whose occupant changed;
* an in-flight ahead tick only ever extends sequences the collect of its
  predecessor cannot finish, so no token is ever emitted for a dead request.

The front-end is drained-reusable: the pump exits when the engine drains
and a later ``submit`` starts a fresh one.
"""
from __future__ import annotations

import asyncio
from typing import AsyncIterator

import numpy as np

from . import steps
from .engine import Engine, Request

_DONE = object()  # stream sentinel


class RequestHandle:
    """One submitted request: stream its tokens, await it, or cancel it."""

    def __init__(self, owner: "AsyncEngine", req: Request):
        self._owner = owner
        self.req = req
        self._queue: asyncio.Queue = asyncio.Queue()
        self._done = asyncio.Event()
        self._n_sent = 0
        self._cancel_requested = False
        self._error: BaseException | None = None

    @property
    def rid(self) -> int:
        return self.req.rid

    @property
    def out_tokens(self) -> list[int]:
        return list(self.req.out_tokens)

    @property
    def done(self) -> bool:
        return self.req.done

    @property
    def cancelled(self) -> bool:
        return self.req.cancelled

    @property
    def finish_reason(self) -> str:
        return self.req.finish_reason

    async def stream(self) -> AsyncIterator[int]:
        """Yield token ids as the scheduler emits them; ends at finish or
        cancellation (check :attr:`cancelled` to distinguish)."""
        while True:
            item = await self._queue.get()
            if item is _DONE:
                return
            if isinstance(item, BaseException):
                raise item
            yield item

    async def wait_done(self) -> None:
        """Wait for finish/cancellation without consuming the stream (the
        traffic runner's cancel timers race this against their delay)."""
        await self._done.wait()

    async def result(self) -> list[int]:
        """Wait for the request to finish; returns all emitted tokens."""
        await self._done.wait()
        if self._error is not None:
            raise self._error
        return list(self.req.out_tokens)

    def cancel(self) -> None:
        """Request cancellation; applied by the pump at the next safe point
        (between in-flight ticks).  Idempotent; a no-op after finish."""
        if self.req.done or self._cancel_requested:
            return
        self._cancel_requested = True
        self._owner._cancel_q.append(self)


class AsyncEngine:
    """Asyncio front-end over the unified serving engine.

    Construct exactly like :class:`~repro.serve.engine.Engine` (model/params
    plus geometry kwargs), or wrap a prebuilt engine with ``engine=``.
    ``submit`` must be called from a running event loop — it lazily starts
    the pump task that drives scheduling.  ``dispatch_ahead=False`` pins the
    pump to the synchronous collect-then-dispatch order (the fuzz suite's
    control arm).
    """

    def __init__(self, model=None, params=None, *, engine: Engine | None = None,
                 dispatch_ahead: bool = True, **engine_kwargs):
        if engine is not None:
            if model is not None or params is not None or engine_kwargs:
                raise ValueError("pass either a prebuilt engine= or "
                                 "model/params + engine kwargs, not both")
            self.engine = engine
        else:
            self.engine = Engine(model, params, **engine_kwargs)
        self.dispatch_ahead = dispatch_ahead
        self.stats = {"ticks": 0, "ahead_ticks": 0}
        self._handles: dict[int, RequestHandle] = {}
        self._cancel_q: list[RequestHandle] = []
        self._pump_task: asyncio.Task | None = None

    # -- public API -----------------------------------------------------------
    def submit(self, prompt: list[int], max_tokens: int = 32,
               eos: int | None = None, enc_frames=None,
               deadline_s: float | None = None) -> RequestHandle:
        """Validate + enqueue a request and (re)start the pump.

        Raises the engine's submit-time ``ValueError``s (empty prompt,
        non-positive ``max_tokens``/``deadline_s``, a request the pool could
        never hold) before any handle exists."""
        loop = asyncio.get_running_loop()  # raises outside an event loop
        req = self.engine.submit(prompt, max_tokens=max_tokens, eos=eos,
                                 enc_frames=enc_frames, deadline_s=deadline_s)
        handle = RequestHandle(self, req)
        self._handles[req.rid] = handle
        if self._pump_task is None or self._pump_task.done():
            # drained-engine reuse: a finished pump is replaced, never left
            # silently stale
            self._pump_task = loop.create_task(self._pump())
        return handle

    async def drain(self) -> None:
        """Wait until every submitted request has finished (or cancelled);
        re-raises a pump failure."""
        while self._pump_task is not None and not self._pump_task.done():
            await asyncio.shield(self._pump_task)

    def close(self) -> None:
        """Abandon the pump (outstanding streams get the cancellation)."""
        if self._pump_task is not None and not self._pump_task.done():
            self._pump_task.cancel()

    # -- pump -----------------------------------------------------------------
    async def _pump(self) -> None:
        eng = self.engine
        in_flight: tuple | None = None  # (plan, logits) — at most one tick
        idle = 0
        try:
            while True:
                if in_flight is None:
                    self._apply_cancels()
                    eng._expire_deadlines()
                    self._deliver()
                    if not eng.pending():
                        break
                    eng._admit()  # batched chunked prefill (device-blocking)
                    self._deliver()  # prefill emitted first tokens
                    await asyncio.sleep(0)
                    plan = eng._decode_schedule()
                    if plan is None:
                        eng._finish_tick()
                        idle += 1
                        if idle > 10_000:
                            raise RuntimeError("async pump stalled: queue "
                                               "blocked with no active slots")
                        continue
                    idle = 0
                    in_flight = (plan, eng._decode_dispatch(plan))
                    # consumers run while the device computes this tick
                    await asyncio.sleep(0)
                    continue
                plan, logits = in_flight
                in_flight = None
                plan2 = None
                if self.dispatch_ahead and not self._cancel_q and \
                        not (eng.queue and None in eng.slot_req) and \
                        not eng._deadline_due():
                    # no pending cancel, no admission waiting on a free slot,
                    # no expired deadline: chain the next tick ahead of
                    # collection
                    plan2 = eng._plan_ahead(plan)
                if plan2 is not None:
                    toks_dev = steps.greedy_tokens(logits)
                    logits2 = eng._decode_dispatch(plan2, device_toks=toks_dev)
                    self.stats["ahead_ticks"] += 1
                    # pull tick N's tokens to host while tick N+1 computes
                    # analyze: allow[host-sync] the acknowledged sync: overlapped with the in-flight tick
                    toks_host = np.asarray(toks_dev)[:, 0]
                    eng._decode_collect(plan, logits, toks_host=toks_host)
                    in_flight = (plan2, logits2)
                else:
                    eng._decode_collect(plan, logits)
                eng._finish_tick()
                self.stats["ticks"] += 1
                self._deliver()
                await asyncio.sleep(0)
        except BaseException as e:
            self._fail(e)
            raise
        finally:
            self._deliver()

    def _apply_cancels(self) -> None:
        q, self._cancel_q = self._cancel_q, []
        for handle in q:
            self.engine.cancel(handle.req, reason="user")

    def _deliver(self) -> None:
        """Push newly emitted tokens (and completions) to consumer queues."""
        finished = []
        for rid, handle in self._handles.items():
            out = handle.req.out_tokens
            while handle._n_sent < len(out):
                handle._queue.put_nowait(out[handle._n_sent])
                handle._n_sent += 1
            if handle.req.done:
                handle._queue.put_nowait(_DONE)
                handle._done.set()
                finished.append(rid)
        for rid in finished:
            del self._handles[rid]

    def _fail(self, error: BaseException) -> None:
        """Propagate a pump failure to every live consumer."""
        for handle in self._handles.values():
            handle._error = error
            handle._queue.put_nowait(error)
            handle._done.set()
        self._handles.clear()

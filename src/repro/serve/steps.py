"""Serving-side sharding rules and config transforms.

Serving parameterization (the paper's deployment path): TTD stays on, all
non-TT linears go INT4 (w4a16), params are TP-sharded over ``model`` only
(no FSDP — decode latency wants weights resident).  KV caches shard batch
over ``data`` and kv-heads / state width over ``model``.
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..config import ModelConfig, QuantConfig


def serve_config_of(cfg: ModelConfig, kernel_backend: str | None = None) -> ModelConfig:
    """Training config -> serving config (int4 weights for non-TT linears).

    ``kernel_backend`` pins the linear dispatch backend for the serve path
    (default: keep the config's policy — "auto" picks Pallas on TPU); see
    ``repro.kernels.dispatch``.
    """
    cfg = cfg.replace(quant=QuantConfig(enabled=True, bits=4, group_size=128),
                      param_dtype="bfloat16")
    if kernel_backend is not None:
        cfg = cfg.replace(kernel_backend=kernel_backend)
    return cfg


def _cache_leaf_rule(path, shape, mesh: Mesh, batch_axes):
    names = []
    for p in path:
        names.append(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))))
    leaf = names[-1]
    nd = len(shape)
    intent = [None] * nd
    if leaf in ("k", "v"):
        # (..., B, W, Hkv, Dh); GQA often has Hkv < |model| — fall back to
        # sharding the head_dim so big caches still spread over TP
        if nd >= 4:
            intent[-4] = batch_axes
            n_model = mesh.shape.get("model", 1)
            if shape[-2] % n_model == 0:
                intent[-2] = "model"
            elif shape[-1] % n_model == 0:
                intent[-1] = "model"
    elif leaf == "wkv":  # (..., B, H, dk, dv)
        if nd >= 4:
            intent[-4] = batch_axes
            intent[-3] = "model"
    elif leaf == "h":  # (..., B, W)
        intent[-2] = batch_axes
        intent[-1] = "model"
    elif leaf == "conv":  # (..., B, cw-1, W)
        if nd >= 3:
            intent[-3] = batch_axes
            intent[-1] = "model"
    elif leaf in ("x_tm", "x_cm"):  # (..., B, 1, D)
        if nd >= 3:
            intent[-3] = batch_axes
    # sanitize
    out = []
    for dim, e in enumerate(intent):
        if e is None:
            out.append(None)
            continue
        axes = e if isinstance(e, tuple) else (e,)
        axes = tuple(a for a in axes if a in mesh.axis_names)
        total = 1
        for a in axes:
            total *= mesh.shape[a]
        if not axes or shape[dim] % total != 0:
            out.append(None)
        else:
            out.append(axes if len(axes) > 1 else axes[0])
    return P(*out)


def cache_pspecs(cache_shapes, mesh: Mesh):
    baxes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    baxes = baxes if len(baxes) > 1 else baxes[0]
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _cache_leaf_rule(path, tuple(leaf.shape), mesh, baxes),
        cache_shapes)


def cache_shardings(cache_shapes, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        cache_pspecs(cache_shapes, mesh),
                        is_leaf=lambda x: isinstance(x, P))

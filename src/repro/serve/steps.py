"""Serving-side step builders, sharding rules, and config transforms.

Jitted program construction for the engine lives here: one
backend-parameterized builder, :func:`session_step_fns`, jits a session's
uniform ``prefill_chunk`` / ``decode_step`` surface (plus the enc-dec
``begin_sequence`` context writer when the backend declares it).  Programs
are memoized per (session type, model config, kernel backend) so every
:class:`~repro.serve.engine.Engine` over the same model shares one trace
cache (the scheduler fuzz suite builds dozens of engines).  The
``chunked_prefill`` driver feeds several waiting prompts through repeated
fixed-width chunk calls of that one program.

Sharding rules (the paper's deployment path): TTD stays on, all non-TT
linears go INT4 (w4a16), params are TP-sharded over ``model`` only (no FSDP
— decode latency wants weights resident).  KV caches shard batch over
``data`` and kv-heads / state width over ``model``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..config import ModelConfig, QuantConfig
from ..kernels.dispatch import backend_override
from ..models.sessions import (  # noqa: F401  (re-exported for callers)
    CACHE_DTYPES,
    InferenceSession,
    canonical_cache_dtype,
)


def serve_config_of(cfg: ModelConfig, kernel_backend: str | None = None) -> ModelConfig:
    """Training config -> serving config (int4 weights for non-TT linears).

    ``kernel_backend`` pins the linear dispatch backend for the serve path
    (default: keep the config's policy — "auto" picks Pallas on TPU); see
    ``repro.kernels.dispatch``.
    """
    cfg = cfg.replace(quant=QuantConfig(enabled=True, bits=4, group_size=128),
                      param_dtype="bfloat16")
    if kernel_backend is not None:
        cfg = cfg.replace(kernel_backend=kernel_backend)
    return cfg


# ---------------------------------------------------------------------------
# Jitted step builders (shared across engine instances).  One path for every
# backend: the session's uniform surface is what gets jitted — there is no
# ring-vs-paged fork here anymore.
# ---------------------------------------------------------------------------
_STEP_CACHE: dict = {}


def session_step_fns(session: InferenceSession, kernel_backend: str | None = None):
    """(prefill_chunk, decode, begin) jitted programs for one session type.

    Memoized on (session type, model config, kernel backend): the device
    step methods are pure given the static config, so engines over the same
    model share one trace cache regardless of their SessionSpec — geometry
    differences only change argument shapes, which jit re-specializes on
    naturally.  Compression rides the config, not the params: two engines
    serving the same architecture under different compression specs
    (TT ranks, int4 groups, TT embed) carry different ``ModelConfig``s and
    therefore get distinct cache entries — TT-core / int4 / embed-core
    leaves are ordinary traced arguments inside each program
    (tests/test_compressed_serve.py pins this).  ``begin`` is ``None``
    unless the backend declares ``needs_encoder_ctx``.  The kernel backend
    resolves at trace time, so the engine's choice (if any) is pinned into
    all programs.
    """
    key = (*session.step_key, kernel_backend)
    if key not in _STEP_CACHE:
        while len(_STEP_CACHE) >= 64:  # bounded like the old lru_cache
            _STEP_CACHE.pop(next(iter(_STEP_CACHE)))
        def _prefill(params, state, tokens, positions, _s=session,
                     _kb=kernel_backend):
            with backend_override(_kb):
                return _s.prefill_chunk(params, state, tokens, positions)

        def _decode(params, state, tokens, positions, _s=session,
                    _kb=kernel_backend):
            with backend_override(_kb):
                return _s.decode_step(params, state, tokens, positions)

        begin = None
        if session.needs_encoder_ctx:
            def begin(params, state, slot, enc_frames, _s=session,
                      _kb=kernel_backend):
                with backend_override(_kb):
                    return _s.begin_sequence(params, state, slot, enc_frames)
            begin = jax.jit(begin)
        _STEP_CACHE[key] = (jax.jit(_prefill), jax.jit(_decode), begin)
    return _STEP_CACHE[key]


@jax.jit
def _greedy_tokens(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]


def greedy_tokens(logits):
    """Device-side greedy sampling for the dispatch-ahead path.

    (slots, V) logits -> (slots, 1) int32 token column, bitwise the per-row
    ``argmax`` the synchronous engine samples on host — the async front-end
    feeds it straight into the next tick's dispatch and pulls it to host
    while that tick computes (DESIGN.md §12).
    """
    return _greedy_tokens(logits)


def chunked_prefill(prefill_chunk_fn, params, state, prompts, *, chunk: int,
                    on_chunk=None):
    """Prefill several prompts through repeated fixed-width chunk calls.

    prompts: list of ``slots`` token lists — row *i* is decode slot *i*;
    ``None``/empty rows are idle slots riding along at position ``-1`` (their
    writes are dropped / routed to the null block by every backend).  Every
    call processes a (slots, chunk) tile, so multiple admitted prompts
    prefill together in ``ceil(longest/chunk)`` jitted calls of one static
    shape.  Returns (last_logits (slots, V) f32 — garbage for idle rows —
    and the updated state).

    ``on_chunk(chunk_index, n_chunks)``, when given, is called after each
    chunk dispatch (the engine's obs layer emits ``prefill_chunk`` trace
    events through it; ``None`` — the default — costs nothing).
    """
    b = len(prompts)
    lens = [len(p) if p else 0 for p in prompts]
    max_l = max(max(lens), 1)
    n_chunks = -(-max_l // chunk)
    toks = np.zeros((b, n_chunks * chunk), np.int32)
    pos = np.full((b, n_chunks * chunk), -1, np.int32)
    for i, p in enumerate(prompts):
        if p:
            toks[i, :len(p)] = p
            pos[i, :len(p)] = np.arange(len(p))
    last = [None] * b
    for c in range(n_chunks):
        sl = slice(c * chunk, (c + 1) * chunk)
        logits, state = prefill_chunk_fn(params, state, jnp.asarray(toks[:, sl]),
                                         jnp.asarray(pos[:, sl]))
        if on_chunk is not None:
            on_chunk(c, n_chunks)
        for i, n in enumerate(lens):
            if n and c * chunk <= n - 1 < (c + 1) * chunk:
                last[i] = logits[i, (n - 1) % chunk]
    # idle rows (including the all-empty batch, whose single chunk ran at
    # position -1 with every write dropped) get a zero-logits row
    zero = jnp.zeros(logits.shape[-1], logits.dtype)
    return jnp.stack([x if x is not None else zero for x in last]), state


_PARAM_LEAF_NAMES = ("w", "table", "cores", "qweight", "scales", "b")


def _cache_leaf_rule(path, shape, mesh: Mesh, batch_axes):
    names = []
    for p in path:
        names.append(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))))
    leaf = names[-1]
    if leaf in _PARAM_LEAF_NAMES or (names and names[-2:-1] == ["cores"]):
        # the cache walk only knows *state* leaves; a compressed param tree
        # (TT cores / int4 qweight+scales / embed table) fed here would get
        # silently replicated — route params through dist.sharding instead
        raise ValueError(
            f"cache sharding rule got param leaf {'/'.join(names)!r}; "
            "session *state* only — shard params via "
            "repro.dist.sharding.param_shardings")
    nd = len(shape)
    intent = [None] * nd
    if leaf in ("k", "v"):
        # (..., B, W, Hkv, Dh); GQA often has Hkv < |model| — fall back to
        # sharding the head_dim so big caches still spread over TP
        if nd >= 4:
            intent[-4] = batch_axes
            n_model = mesh.shape.get("model", 1)
            if shape[-2] % n_model == 0:
                intent[-2] = "model"
            elif shape[-1] % n_model == 0:
                intent[-1] = "model"
    elif leaf in ("k_scale", "v_scale"):  # (..., B, W, Hkv) — rides its pool/ring
        if nd >= 3:
            intent[-3] = batch_axes
    elif leaf == "wkv":  # (..., B, H, dk, dv)
        if nd >= 4:
            intent[-4] = batch_axes
            intent[-3] = "model"
    elif leaf == "wkv_scale":  # (..., B, H)
        if nd >= 2:
            intent[-2] = batch_axes
            intent[-1] = "model"
    elif leaf == "h":  # (..., B, W)
        intent[-2] = batch_axes
        intent[-1] = "model"
    elif leaf == "conv":  # (..., B, cw-1, W)
        if nd >= 3:
            intent[-3] = batch_axes
            intent[-1] = "model"
    elif leaf == "conv_scale":  # (..., B, cw-1)
        if nd >= 2:
            intent[-2] = batch_axes
    elif leaf in ("x_tm", "x_cm"):  # (..., B, 1, D)
        if nd >= 3:
            intent[-3] = batch_axes
    # sanitize
    out = []
    for dim, e in enumerate(intent):
        if e is None:
            out.append(None)
            continue
        axes = e if isinstance(e, tuple) else (e,)
        axes = tuple(a for a in axes if a in mesh.axis_names)
        total = 1
        for a in axes:
            total *= mesh.shape[a]
        if not axes or shape[dim] % total != 0:
            out.append(None)
        else:
            out.append(axes if len(axes) > 1 else axes[0])
    return P(*out)


def cache_pspecs(cache_shapes, mesh: Mesh):
    baxes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    baxes = baxes if len(baxes) > 1 else baxes[0]
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _cache_leaf_rule(path, tuple(leaf.shape), mesh, baxes),
        cache_shapes)


def cache_shardings(cache_shapes, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        cache_pspecs(cache_shapes, mesh),
                        is_leaf=lambda x: isinstance(x, P))

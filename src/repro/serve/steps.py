"""Serving-side step builders, sharding rules, and config transforms.

Jitted program construction for both engine flavors lives here —
``ring_step_fns`` / ``paged_step_fns`` are memoized on the model so every
:class:`~repro.serve.engine.Engine` instance over the same model shares one
trace cache (the scheduler fuzz suite builds dozens of engines), plus the
``chunked_prefill`` driver that feeds several waiting prompts through one
fixed-width jitted chunk program.

Sharding rules (the paper's deployment path): TTD stays on, all non-TT
linears go INT4 (w4a16), params are TP-sharded over ``model`` only (no FSDP
— decode latency wants weights resident).  KV caches shard batch over
``data`` and kv-heads / state width over ``model``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..config import ModelConfig, QuantConfig
from ..kernels.dispatch import backend_override


def serve_config_of(cfg: ModelConfig, kernel_backend: str | None = None) -> ModelConfig:
    """Training config -> serving config (int4 weights for non-TT linears).

    ``kernel_backend`` pins the linear dispatch backend for the serve path
    (default: keep the config's policy — "auto" picks Pallas on TPU); see
    ``repro.kernels.dispatch``.
    """
    cfg = cfg.replace(quant=QuantConfig(enabled=True, bits=4, group_size=128),
                      param_dtype="bfloat16")
    if kernel_backend is not None:
        cfg = cfg.replace(kernel_backend=kernel_backend)
    return cfg


# ---------------------------------------------------------------------------
# Jitted step builders (shared across engine instances)
# ---------------------------------------------------------------------------
CACHE_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
                "float16": jnp.float16, "int8": jnp.int8}


def canonical_cache_dtype(dtype) -> str:
    """Normalize a user-facing cache dtype (str or jnp dtype) to its name."""
    if isinstance(dtype, str):
        if dtype not in CACHE_DTYPES:
            raise ValueError(f"unknown cache dtype {dtype!r}")
        return dtype
    name = jnp.dtype(dtype).name
    if name not in CACHE_DTYPES:
        raise ValueError(f"unknown cache dtype {dtype!r}")
    return name


@functools.lru_cache(maxsize=64)
def ring_step_fns(model, cache_dtype_name: str, max_len: int,
                  kernel_backend: str | None):
    """(prefill, decode) jitted programs for the ring-cache engine.

    The kernel backend resolves at trace time, so the engine's choice (if
    any) is pinned here for both programs.
    """
    cache_dtype = CACHE_DTYPES[cache_dtype_name]

    def _prefill(params, batch):
        with backend_override(kernel_backend):
            return model.prefill(params, batch, cache_dtype=cache_dtype,
                                 max_len=max_len)

    def _decode(params, cache, batch, pos):
        with backend_override(kernel_backend):
            return model.decode_step(params, cache, batch, pos)

    return jax.jit(_prefill), jax.jit(_decode)


@functools.lru_cache(maxsize=64)
def paged_step_fns(model, kernel_backend: str | None):
    """(prefill_chunk, decode) jitted programs for the paged-cache engine.

    Both take the block tables and per-sequence positions as device args, so
    one compiled program serves every schedule state of a given shape.
    """

    def _prefill_chunk(params, caches, tokens, block_tables, positions):
        with backend_override(kernel_backend):
            return model.prefill_paged_chunk(params, caches,
                                             {"tokens": tokens},
                                             block_tables, positions)

    def _decode(params, caches, tokens, block_tables, positions):
        with backend_override(kernel_backend):
            return model.decode_step_paged(params, caches, {"tokens": tokens},
                                           block_tables, positions)

    return jax.jit(_prefill_chunk), jax.jit(_decode)


def chunked_prefill(prefill_chunk_fn, params, caches, prompts, block_tables,
                    *, chunk: int):
    """Prefill several prompts through repeated fixed-width chunk calls.

    prompts: list of B token lists (ragged; empty lists mark dummy rows used
    to pad the batch to a fixed width — their positions are all ``-1`` so
    their K/V lands in the null block).  block_tables: (B, W) int array.
    Every call processes a (B, chunk) tile, so multiple waiting prompts
    prefill together in ``ceil(max_len/chunk)`` jitted calls of one static
    shape.  Returns (last_logits (B, V) f32 — garbage for dummy rows —
    and the updated caches).
    """
    b = len(prompts)
    lens = [len(p) for p in prompts]
    max_l = max(max(lens), 1)
    n_chunks = -(-max_l // chunk)
    toks = np.zeros((b, n_chunks * chunk), np.int32)
    pos = np.full((b, n_chunks * chunk), -1, np.int32)
    for i, p in enumerate(prompts):
        toks[i, :len(p)] = p
        pos[i, :len(p)] = np.arange(len(p))
    bt = jnp.asarray(block_tables, jnp.int32)
    last = [None] * b
    for c in range(n_chunks):
        sl = slice(c * chunk, (c + 1) * chunk)
        logits, caches = prefill_chunk_fn(params, caches,
                                          jnp.asarray(toks[:, sl]), bt,
                                          jnp.asarray(pos[:, sl]))
        for i, n in enumerate(lens):
            if n and c * chunk <= n - 1 < (c + 1) * chunk:
                last[i] = logits[i, (n - 1) % chunk]
    return jnp.stack([x if x is not None else jnp.zeros_like(last[lens.index(max_l)])
                      for x in last]), caches


def _cache_leaf_rule(path, shape, mesh: Mesh, batch_axes):
    names = []
    for p in path:
        names.append(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))))
    leaf = names[-1]
    nd = len(shape)
    intent = [None] * nd
    if leaf in ("k", "v"):
        # (..., B, W, Hkv, Dh); GQA often has Hkv < |model| — fall back to
        # sharding the head_dim so big caches still spread over TP
        if nd >= 4:
            intent[-4] = batch_axes
            n_model = mesh.shape.get("model", 1)
            if shape[-2] % n_model == 0:
                intent[-2] = "model"
            elif shape[-1] % n_model == 0:
                intent[-1] = "model"
    elif leaf == "wkv":  # (..., B, H, dk, dv)
        if nd >= 4:
            intent[-4] = batch_axes
            intent[-3] = "model"
    elif leaf == "h":  # (..., B, W)
        intent[-2] = batch_axes
        intent[-1] = "model"
    elif leaf == "conv":  # (..., B, cw-1, W)
        if nd >= 3:
            intent[-3] = batch_axes
            intent[-1] = "model"
    elif leaf in ("x_tm", "x_cm"):  # (..., B, 1, D)
        if nd >= 3:
            intent[-3] = batch_axes
    # sanitize
    out = []
    for dim, e in enumerate(intent):
        if e is None:
            out.append(None)
            continue
        axes = e if isinstance(e, tuple) else (e,)
        axes = tuple(a for a in axes if a in mesh.axis_names)
        total = 1
        for a in axes:
            total *= mesh.shape[a]
        if not axes or shape[dim] % total != 0:
            out.append(None)
        else:
            out.append(axes if len(axes) > 1 else axes[0])
    return P(*out)


def cache_pspecs(cache_shapes, mesh: Mesh):
    baxes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    baxes = baxes if len(baxes) > 1 else baxes[0]
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _cache_leaf_rule(path, tuple(leaf.shape), mesh, baxes),
        cache_shapes)


def cache_shardings(cache_shapes, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        cache_pspecs(cache_shapes, mesh),
                        is_leaf=lambda x: isinstance(x, P))

from .engine import (  # noqa: F401
    AdmissionPolicy,
    EDFAdmission,
    Engine,
    FCFSAdmission,
    PagedEngine,
    Request,
)
from .frontend import AsyncEngine, RequestHandle  # noqa: F401
from .steps import cache_pspecs, serve_config_of, session_step_fns  # noqa: F401

from .steps import cache_pspecs, serve_config_of  # noqa: F401

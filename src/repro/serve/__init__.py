from .engine import (  # noqa: F401  # analyze: allow[deprecated-api] public shim re-export
    AdmissionPolicy,
    EDFAdmission,
    Engine,
    FCFSAdmission,
    PagedEngine,
    Request,
)
from .frontend import AsyncEngine, RequestHandle  # noqa: F401
from .steps import cache_pspecs, serve_config_of, session_step_fns  # noqa: F401

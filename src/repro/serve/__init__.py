from .engine import Engine, PagedEngine, Request  # noqa: F401
from .steps import cache_pspecs, serve_config_of, session_step_fns  # noqa: F401

"""Paged KV cache: fixed-size blocks + per-sequence block tables.

The serving engine's KV memory is a pool of ``num_blocks`` fixed-size blocks
(vLLM-style PagedAttention, arXiv:2309.06180 — see PAPERS.md); a sequence
owns an *ordered* list of block ids (its block table) covering its token
positions: position ``p`` lives in logical block ``p // block_size`` at slot
``p % block_size``.  Allocation is O(1) from a free list; freeing a finished
sequence returns every block immediately, so memory scales with *live*
tokens rather than ``slots × max_len`` as the ring layout does.

Two layers:

* :class:`BlockManager` — pure-Python bookkeeping (free list, block tables,
  live-token accounting).  No jax imports; property-tested in
  ``tests/test_kv_cache.py``.
* :func:`pack_block_tables` — the host→device block-table packing.  The
  device-side K/V pools themselves ride in the session state pytree
  (``models.sessions`` paged/encdec backends, DESIGN.md §7); the engine owns
  one :class:`BlockManager` per block-pool session.

Block 0 is reserved as the **null block**: it is never allocated, and jitted
steps route padding-token writes (position ``-1``) into it, so fixed-shape
prefill/decode programs never write into a live sequence's memory.
"""
from __future__ import annotations

from typing import Sequence


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Number of blocks covering ``n_tokens`` positions."""
    return max(0, (n_tokens + block_size - 1) // block_size)


class BlockManager:
    """Free-list allocator over ``num_blocks`` blocks of ``block_size`` slots.

    Block 0 is reserved (the null block); ``num_free`` therefore starts at
    ``num_blocks - 1``.  All methods are O(blocks touched).
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is the reserved null block)")
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: list[int] = list(range(num_blocks - 1, 0, -1))  # LIFO pop
        self._tables: dict[int, list[int]] = {}
        self._lens: dict[int, int] = {}

    # -- queries --------------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    def can_allocate(self, n_tokens: int) -> bool:
        return blocks_for(n_tokens, self.block_size) <= self.num_free

    def table(self, seq_id: int) -> list[int]:
        return list(self._tables[seq_id])

    def seq_ids(self) -> list[int]:
        return list(self._tables)

    def seq_len(self, seq_id: int) -> int:
        return self._lens[seq_id]

    def live_tokens(self) -> int:
        """Total live (written) token positions across sequences."""
        return sum(self._lens.values())

    def allocated_slots(self) -> int:
        """Total capacity of blocks currently owned by sequences."""
        return sum(len(t) for t in self._tables.values()) * self.block_size

    def utilization(self) -> float:
        """live tokens / allocated slots (1.0 when every block is full)."""
        slots = self.allocated_slots()
        return self.live_tokens() / slots if slots else 0.0

    # -- mutation -------------------------------------------------------------
    def allocate(self, seq_id: int, n_tokens: int) -> bool:
        """Register ``seq_id`` with blocks covering ``n_tokens`` positions.

        Atomic: returns False (and allocates nothing) when the free list is
        short.  ``seq_id`` must not already be registered.
        """
        if seq_id in self._tables:
            raise ValueError(f"sequence {seq_id} already allocated")
        need = blocks_for(n_tokens, self.block_size)
        if need > self.num_free:
            return False
        self._tables[seq_id] = [self._free.pop() for _ in range(need)]
        self._lens[seq_id] = n_tokens
        return True

    def ensure(self, seq_id: int, n_tokens: int) -> bool:
        """Grow ``seq_id``'s table to cover ``n_tokens`` positions.

        Atomic like :meth:`allocate`; never shrinks.  Returns False when the
        growth doesn't fit (state unchanged).
        """
        table = self._tables[seq_id]
        need = blocks_for(n_tokens, self.block_size) - len(table)
        if need > self.num_free:
            return False
        for _ in range(max(need, 0)):
            table.append(self._free.pop())
        self._lens[seq_id] = max(self._lens[seq_id], n_tokens)
        return True

    def free(self, seq_id: int) -> list[int]:
        """Release all of ``seq_id``'s blocks back to the pool."""
        blocks = self._tables.pop(seq_id)
        self._lens.pop(seq_id)
        self._free.extend(blocks)
        return blocks


def pack_block_tables(manager: BlockManager, seq_ids: Sequence[int | None],
                      table_width: int):
    """(B, table_width) int32 table; ``None`` rows / tail pad with the
    null block 0."""
    import numpy as np  # local: BlockManager itself stays numpy/jax-free

    out = np.zeros((len(seq_ids), table_width), np.int32)
    for i, sid in enumerate(seq_ids):
        if sid is None:
            continue
        t = manager.table(sid)
        out[i, :len(t)] = t
    return out

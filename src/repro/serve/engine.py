"""Continuous-batching serving engine.

A Python scheduler drives two jitted programs (prefill_step, decode_step)
over a fixed decode batch of ``slots``.  Requests join free slots after
prefill; every decode tick advances all active slots one token; finished
sequences (eos or max_tokens) free their slot immediately — classic
continuous batching (vLLM-style at the scheduling level; the KV layout here
is per-slot rings rather than paged blocks).

Single-sequence prefill + slot-wise cache surgery keeps the engine simple
and correct; a production deployment would batch prefills and use the
sharded decode_step from launch/dryrun.py (same model functions).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..config import ModelConfig
from ..kernels.dispatch import backend_override
from ..models.api import Model


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_tokens: int
    eos: int | None = None
    out_tokens: list[int] = field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


class Engine:
    def __init__(self, model: Model, params, *, slots: int = 4, max_len: int = 512,
                 cache_dtype=jnp.float32, greedy: bool = True,
                 temperature: float = 1.0, top_k: int = 0, seed: int = 0,
                 kernel_backend: str | None = None):
        self.model = model
        self.cfg: ModelConfig = model.cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.greedy = greedy
        self.temperature = temperature
        self.top_k = top_k
        self._key = jax.random.PRNGKey(seed)
        self.kernel_backend = kernel_backend  # None -> dispatch policy chain
        self.cache = model.init_cache(slots, max_len, cache_dtype)
        # identify each cache leaf's batch axis structurally (dim sizes like
        # n_layers can collide with the slot count)
        import jax as _jax
        sa = _jax.eval_shape(lambda: model.init_cache(slots, max_len, cache_dtype))
        sb = _jax.eval_shape(lambda: model.init_cache(slots + 1, max_len, cache_dtype))
        self._batch_axis = _jax.tree.map(
            lambda a, b: next((i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                               if x != y), -1), sa, sb)
        self.slot_req: list[Request | None] = [None] * slots
        self.slot_pos = np.zeros(slots, np.int32)  # next position to decode
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        # backend resolves at trace time — pin the engine's choice (if any)
        # for both jitted programs so prefill/decode exercise the same path
        def _prefill_fn(p, b):
            with backend_override(kernel_backend):
                return model.prefill(p, b, cache_dtype=cache_dtype,
                                     max_len=max_len)

        def _decode_fn(p, c, b, pos):
            with backend_override(kernel_backend):
                return model.decode_step(p, c, b, pos)

        self._prefill = jax.jit(_prefill_fn)
        self._decode = jax.jit(_decode_fn)
        self._next_rid = 0

    # -- public API -----------------------------------------------------------
    def submit(self, prompt: list[int], max_tokens: int = 32, eos: int | None = None) -> Request:
        req = Request(self._next_rid, list(prompt), max_tokens, eos, t_submit=time.time())
        self._next_rid += 1
        self.queue.append(req)
        return req

    def run(self, max_ticks: int = 10_000) -> list[Request]:
        ticks = 0
        while (self.queue or any(r is not None for r in self.slot_req)) and ticks < max_ticks:
            self._admit()
            self._decode_tick()
            ticks += 1
        return self.finished

    # -- internals ------------------------------------------------------------
    def _admit(self):
        for s in range(self.slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.pop(0)
                toks = jnp.asarray([req.prompt], jnp.int32)
                logits, cache1 = self._prefill(self.params, {"tokens": toks})
                tok = self._sample(logits[0])
                req.out_tokens.append(tok)
                req.t_first = time.time()
                self._install(s, cache1, len(req.prompt))
                self.slot_req[s] = req
                self.slot_pos[s] = len(req.prompt)

    def _install(self, slot: int, cache1, prompt_len: int):
        """Copy a batch-1 prefill cache into batch slot ``slot``.

        Leaves with a batch dim get slot-surgery (ring dims padded/cropped to
        the engine's max_len); batchless int32 leaves (position rings, shared
        across the batch) merge by elementwise max — valid because decode
        attention masks ``kpos <= qpos`` per query, so a slot lagging behind
        the shared ring frontier never sees future entries.
        """
        def _fit(one, fshape, axis):
            """Pad/crop every dim after ``axis`` to match fshape."""
            pads, slices = [], []
            for d in range(one.ndim):
                target = fshape[d]
                diff = target - one.shape[d]
                pads.append((0, max(diff, 0)))
                slices.append(slice(0, target))
            fill = -1 if one.dtype == jnp.int32 else 0
            return jnp.pad(one, pads, constant_values=fill)[tuple(slices)]

        def upd(full, one, axis):
            fshape = full.shape
            if axis >= 0:
                idx = [slice(None)] * len(fshape)
                idx[axis] = slice(slot, slot + 1)
                tgt = list(fshape)
                tgt[axis] = 1
                return full.at[tuple(idx)].set(_fit(one, tgt, axis))
            if full.dtype == jnp.int32:  # shared position rings
                return jnp.maximum(full, _fit(one, full.shape, 0))
            return full

        self.cache = jax.tree.map(upd, self.cache, cache1, self._batch_axis)

    def _decode_tick(self):
        active = [s for s in range(self.slots) if self.slot_req[s] is not None]
        if not active:
            return
        # all active slots share a tick; position is per-slot via pos rings,
        # we step each active slot one token (batched decode over all slots)
        toks = np.zeros((self.slots, 1), np.int32)
        for s in active:
            toks[s, 0] = self.slot_req[s].out_tokens[-1]
        # engine-level simplification: one decode_step per distinct position
        # group (slots admitted together share positions)
        groups: dict[int, list[int]] = {}
        for s in active:
            groups.setdefault(int(self.slot_pos[s]), []).append(s)
        for pos, slots in groups.items():
            logits, new_cache = self._decode(self.params, self.cache,
                                             {"tokens": jnp.asarray(toks)},
                                             jnp.int32(pos))
            # keep updates only for slots in this group
            mask = np.zeros(self.slots, bool)
            mask[slots] = True

            def sel(new, old, axis):
                if axis >= 0:
                    m = jnp.asarray(mask).reshape(
                        (1,) * axis + (self.slots,) + (1,) * (new.ndim - axis - 1))
                    return jnp.where(m, new, old)
                return new  # shared leaves (pos rings) — same for the group

            self.cache = jax.tree.map(sel, new_cache, self.cache, self._batch_axis)
            for s in slots:
                req = self.slot_req[s]
                tok = self._sample(logits[s])
                req.out_tokens.append(tok)
                self.slot_pos[s] += 1
                if (req.eos is not None and tok == req.eos) or \
                        len(req.out_tokens) >= req.max_tokens or \
                        self.slot_pos[s] >= self.max_len - 1:
                    req.done = True
                    req.t_done = time.time()
                    self.finished.append(req)
                    self.slot_req[s] = None

    def _sample(self, logits) -> int:
        """Greedy argmax, or seeded temperature/top-k sampling."""
        if self.greedy:
            return int(jnp.argmax(logits))
        self._key, sub = jax.random.split(self._key)
        scaled = logits.astype(jnp.float32) / max(self.temperature, 1e-6)
        if self.top_k > 0:
            k = min(self.top_k, scaled.shape[-1])
            kth = jax.lax.top_k(scaled, k)[0][-1]
            scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
        return int(jax.random.categorical(sub, scaled))

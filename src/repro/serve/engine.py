"""Continuous-batching serving engine over the typed session API.

One scheduler serves every model family (DESIGN.md §7): a Python loop
drives the jitted programs built by ``serve.steps.session_step_fns`` from an
:class:`~repro.models.sessions.InferenceSession` — the family-specific state
layout (paged K/V blocks, per-slot rings, recurrent state, encoder context)
is entirely the backend's business.  The scheduler sees one uniform surface:

* ``prefill_chunk(params, state, tokens, positions)`` — rows are decode
  slots; admitted prompts prefill *batched* in fixed-width chunks while idle
  slots ride along at position ``-1``.
* ``decode_step(params, state, tokens, positions)`` — one call per tick
  regardless of position raggedness (per-sequence positions).

Requests join after prefill; every decode tick advances all active slots one
token; finished sequences free their resources immediately — classic
continuous batching.  For block-pool backends (``session.uses_blocks``) the
engine owns a :class:`~repro.serve.kv_cache.BlockManager`: admission is
FCFS while free blocks cover the prompt plus one lookahead token, tables
grow on demand each tick, and block exhaustion preempts the newest-admitted
sequence back to the waiting queue (recompute-style: its blocks are freed;
emitted tokens are kept and re-prefilled with the prompt on re-admission, so
greedy outputs are unchanged).  Constant-state backends never preempt —
their capacity is the slot itself.

Requests can be **cancelled** mid-flight (``Engine.cancel`` — queued or
active; an active occupant releases its slot and blocks through the same
machinery as a preemption, keeping the tokens already emitted) and carry an
optional **deadline** (``submit(deadline_s=)``; ``tick`` cancels expired
requests with a ``deadline_miss`` trace event before admitting).  Admission
order is a pluggable :class:`AdmissionPolicy` (FCFS default, EDF available);
the decode tick itself is decomposed into schedule → dispatch → collect so
the asyncio front-end (``serve.frontend``, DESIGN.md §12) can overlap host
scheduling with device compute via dispatch-ahead double buffering.

First-token latency (``Request.t_first``) is stamped only after
``jax.block_until_ready`` on the prefill logits — timing the dispatch
instead of the computation understates TTFT by the entire prefill on an
async backend.  All timing fields are ``time.perf_counter()`` stamps
(monotonic — a wall-clock step can never corrupt a latency); the only
wall-clock value kept is the informational ``Request.t_submit_wall``.

Observability (DESIGN.md §9): pass ``obs=`` an
:class:`~repro.obs.Observer` / :class:`~repro.obs.ObsConfig` (or set
``REPRO_OBS=1``) and the engine emits structured scheduler events
(admit / prefill_chunk / decode_tick / preempt / finish / pool_sample),
queue-time / TTFT / inter-token latency histograms, and block-pool
utilization gauges.  Disabled (the default), the hot path pays one
``is None`` check per site — no events, no allocation, no device syncs.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..config import ModelConfig
from ..models.sessions import (
    InferenceSession,
    SessionSpec,
    canonical_cache_dtype,
    make_session,
)
from ..obs import resolve_observer
from . import steps
from .kv_cache import BlockManager, blocks_for, pack_block_tables

_NULL_CTX = contextlib.nullcontext()  # reusable no-op span (obs disabled)


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_tokens: int
    eos: int | None = None
    enc_frames: Any = None  # (T_enc, D) encoder frames (enc-dec families)
    deadline_s: float | None = None  # completion budget from submit (seconds)
    out_tokens: list[int] = field(default_factory=list)
    done: bool = False
    cancelled: bool = False
    finish_reason: str = ""  # eos | max_tokens | max_len | user | deadline
    # monotonic (perf_counter) stamps — duration math only ever uses these
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0
    # informational wall-clock submit time (never used in arithmetic)
    t_submit_wall: float = 0.0


@dataclass
class TickPlan:
    """One decode tick's host-side schedule, frozen at dispatch time.

    ``active``/``rids`` pin which request occupied each scheduled slot when
    the tick launched — collection skips a slot whose occupant changed while
    the tick was in flight (a cancellation between dispatch and collect).
    ``toks`` is the host token batch, or ``None`` when the dispatcher is
    handed a device-resident token array instead (the dispatch-ahead path:
    the previous tick's on-device argmax feeds the next tick without a
    host round-trip).
    """

    active: list[int]            # scheduled slot ids
    rids: list[int]              # per-active-slot request id (staleness check)
    positions: np.ndarray        # (slots,) int32; -1 = idle row
    toks: np.ndarray | None      # (slots, 1) int32 host tokens, or None


class AdmissionPolicy:
    """Orders the waiting queue for admission (the policy seam, DESIGN §12).

    ``order`` returns the waiting requests in admission-priority order; the
    engine walks that order and stops at the first request that does not fit
    (head-of-line semantics *within the policy's order*, so a policy
    reorders priorities but cannot starve the pool-capacity invariants).
    """

    name = "policy"

    def order(self, queue: list[Request], now: float) -> list[Request]:
        raise NotImplementedError


class FCFSAdmission(AdmissionPolicy):
    """First-come-first-served: the queue order is the admission order."""

    name = "fcfs"

    def order(self, queue: list[Request], now: float) -> list[Request]:
        return queue


class EDFAdmission(AdmissionPolicy):
    """Earliest-deadline-first: requests with the nearest absolute deadline
    admit first; deadline-free requests follow in FCFS order."""

    name = "edf"

    def order(self, queue: list[Request], now: float) -> list[Request]:
        return sorted(queue, key=lambda r: (
            (0, r.t_submit + r.deadline_s) if r.deadline_s is not None
            else (1, r.t_submit)))


class Engine:
    """Backend-parameterized continuous-batching scheduler.

    ``model`` may be a :class:`~repro.models.api.Model`, a ``ModelConfig``,
    or a prebuilt :class:`~repro.models.sessions.InferenceSession`.
    ``backend=None`` picks the family default (paged for full-attention
    dense/moe, rings for SWA, recurrent state for griffin/rwkv, encoder
    context + paged self-attention for whisper); asking for an unsupported
    backend raises ``NotImplementedError`` naming the family.
    """

    def __init__(self, model, params, *, slots: int | None = None,
                 max_len: int | None = None, backend: str | None = None,
                 block_size: int | None = None, num_blocks: int | None = None,
                 cache_dtype=None, prefill_batch: int = 2,
                 prefill_chunk: int | None = None, greedy: bool = True,
                 temperature: float = 1.0, top_k: int = 0, seed: int = 0,
                 kernel_backend: str | None = None, obs=None,
                 admission: AdmissionPolicy | None = None):
        geometry = dict(slots=slots, max_len=max_len, block_size=block_size,
                        num_blocks=num_blocks, cache_dtype=cache_dtype,
                        prefill_chunk=prefill_chunk, backend=backend)
        if isinstance(model, InferenceSession):
            passed = [k for k, v in geometry.items() if v is not None]
            if passed:
                raise ValueError(
                    "a prebuilt InferenceSession fixes the serving geometry; "
                    f"drop the conflicting kwargs {passed} or pass the "
                    "config/Model instead")
            self.session = model
        else:
            cfg = getattr(model, "cfg", model)
            self.session = make_session(cfg, SessionSpec(
                slots=slots if slots is not None else 4,
                max_len=max_len if max_len is not None else 512,
                prefill_chunk=max(1, prefill_chunk if prefill_chunk is not None else 32),
                block_size=block_size if block_size is not None else 16,
                num_blocks=num_blocks,
                cache_dtype=canonical_cache_dtype(
                    cache_dtype if cache_dtype is not None else "float32")),
                backend=backend)
        self.cfg: ModelConfig = self.session.cfg
        spec = self.session.spec
        self.params = params
        self.slots = spec.slots
        self.max_len = spec.max_len
        self.prefill_batch = max(1, prefill_batch)
        self.prefill_chunk = spec.prefill_chunk
        self.greedy = greedy
        self.temperature = temperature
        self.top_k = top_k
        self._key = jax.random.PRNGKey(seed)
        self.kernel_backend = kernel_backend  # None -> dispatch policy chain

        self.manager: BlockManager | None = None
        if self.session.uses_blocks:
            self.manager = BlockManager(spec.resolved_num_blocks(),
                                        spec.block_size)
        self.state = self.session.init_state()
        self._batch_axis = self._find_batch_axes()
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self.admission = admission if admission is not None else FCFSAdmission()
        self._any_deadline = False  # cheap guard for the per-tick expiry scan
        self._next_rid = 0
        self.slot_req: list[Request | None] = [None] * self.slots
        self.slot_pos = np.zeros(self.slots, np.int32)  # next position to decode
        self._admit_order: list[int] = []  # slots, oldest admission first
        self._prefill, self._decode, self._begin = steps.session_step_fns(
            self.session, kernel_backend)

        # -- observability (obs=None -> env default; False -> force off) ------
        self.obs = resolve_observer(obs)
        self._tick_no = 0
        self._t_last_tok: dict[int, float] = {}  # slot -> last token stamp
        if self.obs is not None:
            reg = self.obs.registry
            self._h_queue = reg.histogram("serve_queue_seconds")
            self._h_ttft = reg.histogram("serve_ttft_seconds")
            self._h_intertok = reg.histogram("serve_inter_token_seconds")
            self._c_tokens = reg.counter("serve_tokens_total")
            self._c_ticks = reg.counter("serve_decode_ticks_total")
            self._c_preempt = reg.counter("serve_preemptions_total")
            self._c_cancel = reg.counter("serve_cancellations_total")
            self._c_deadline = reg.counter("serve_deadline_miss_total")
            self._g_active = reg.gauge("serve_active_slots")
            if self.manager is not None:
                self._g_util = reg.gauge("serve_pool_utilization")
                self._g_free = reg.gauge("serve_pool_free_blocks")
                self._g_live = reg.gauge("serve_pool_live_tokens")

    # -- public API -----------------------------------------------------------
    def submit(self, prompt: list[int], max_tokens: int = 32,
               eos: int | None = None, enc_frames=None,
               deadline_s: float | None = None) -> Request:
        if not prompt:
            raise ValueError("empty prompt")
        if max_tokens < 1:
            raise ValueError("max_tokens must be >= 1")
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError(
                f"deadline_s must be a positive completion budget in seconds "
                f"(got {deadline_s!r} with max_tokens={max_tokens}); omit it "
                "for no deadline")
        if len(prompt) + 1 > self.max_len:
            raise ValueError(f"prompt needs {len(prompt) + 1} positions "
                             f"> max_len {self.max_len}")
        if self.manager is not None:
            # a request must be servable *alone* (worst case: everything
            # else preempted): its total footprint — prompt + generated,
            # capped by the max_len frontier — must fit the whole pool
            worst = min(len(prompt) + max_tokens, self.max_len)
            need = blocks_for(worst, self.manager.block_size)
            if need > self.manager.num_blocks - 1:
                raise ValueError(
                    f"request needs up to {need} blocks but the pool only "
                    f"has {self.manager.num_blocks - 1}")
        req = Request(self._next_rid, list(prompt), max_tokens, eos,
                      enc_frames=enc_frames, deadline_s=deadline_s,
                      t_submit=time.perf_counter(),
                      # analyze: allow[wall-clock] informational submit stamp; never enters duration math
                      t_submit_wall=time.time())
        self._next_rid += 1
        self._any_deadline |= deadline_s is not None
        self.queue.append(req)
        if self.obs is not None:
            self.obs.event("submit", t=req.t_submit, rid=req.rid,
                           prompt_len=len(req.prompt), max_tokens=max_tokens)
        return req

    def pending(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slot_req)

    def tick(self) -> None:
        """One scheduler step: expire deadlines, admit waiting requests
        (batched chunked prefill), then decode one token for every active
        sequence.  Decoding is schedule → dispatch → collect so an async
        front-end can interleave host work between dispatch and collect
        (dispatch-ahead double buffering, DESIGN.md §12)."""
        self._expire_deadlines()
        self._admit()
        plan = self._decode_schedule()
        if plan is not None:
            logits = self._decode_dispatch(plan)
            self._decode_collect(plan, logits)
        self._finish_tick()

    def _finish_tick(self) -> None:
        """Per-tick epilogue shared by ``tick`` and the async pump."""
        if self.obs is not None:
            self._sample_pool()
        self._tick_no += 1

    def cancel(self, req: Request, reason: str = "user") -> bool:
        """Cancel a queued or mid-flight request, freeing its slot/blocks.

        Emitted tokens are kept on the request; an active occupant goes
        through the same slot/block release as a preemption, so the freed
        capacity admits the next waiting request on the following tick.
        Returns ``False`` when the request already finished (cancellation
        raced completion) — callers treat that as a no-op."""
        if req.done:
            return False
        slot = -1
        if not self._remove_from_queue(req):
            for s, r in enumerate(self.slot_req):
                if r is req:
                    slot = s
                    self.slot_req[s] = None
                    self._admit_order.remove(s)
                    self._t_last_tok.pop(s, None)
                    if self.manager is not None:
                        self.manager.free(req.rid)
                    break
            else:
                return False  # not queued, not active: nothing to cancel
        req.cancelled = True
        req.done = True
        req.finish_reason = reason
        req.t_done = time.perf_counter()
        self.finished.append(req)
        if self.obs is not None:
            self._c_cancel.inc()
            self.obs.event("cancel", t=req.t_done, rid=req.rid, slot=slot,
                           tick=self._tick_no, reason=reason)
        return True

    def _remove_from_queue(self, req: Request) -> bool:
        # identity-based: dataclass __eq__ would compare enc_frames arrays
        for i, r in enumerate(self.queue):
            if r is req:
                del self.queue[i]
                return True
        return False

    def _expired_requests(self, now: float) -> list[Request]:
        live = self.queue + [r for r in self.slot_req if r is not None]
        return [r for r in live if r.deadline_s is not None
                and now - r.t_submit > r.deadline_s]

    def _deadline_due(self) -> bool:
        """True when some live request's deadline has already passed (the
        async pump breaks its dispatch-ahead chain to expire it)."""
        return self._any_deadline and \
            bool(self._expired_requests(time.perf_counter()))

    def _expire_deadlines(self) -> int:
        """Cancel every live request whose completion deadline has passed."""
        if not self._any_deadline:
            return 0
        now = time.perf_counter()
        expired = self._expired_requests(now)
        for req in expired:
            if self.obs is not None:
                self._c_deadline.inc()
                self.obs.event("deadline_miss", t=now, rid=req.rid,
                               tick=self._tick_no, deadline_s=req.deadline_s)
            self.cancel(req, reason="deadline")
        return len(expired)

    def _sample_pool(self) -> None:
        """Record pool-utilization gauges + a pool_sample event (obs on)."""
        active = sum(r is not None for r in self.slot_req)
        self._g_active.set(active)
        if self.manager is None:
            return
        if self._tick_no % self.obs.config.pool_sample_every:
            return
        util = self.manager.utilization()
        free = self.manager.num_free
        live = self.manager.live_tokens()
        self._g_util.set(util)
        self._g_free.set(free)
        self._g_live.set(live)
        self.obs.event("pool_sample", tick=self._tick_no, utilization=util,
                       free_blocks=free, live_tokens=live, active_slots=active)

    def run(self, max_ticks: int = 10_000) -> list[Request]:
        """Tick until drained; returns the requests finished by *this* call.

        The engine stays usable after draining: a later ``submit`` + ``run``
        serves normally, and the return value never replays earlier runs'
        requests (``self.finished`` keeps the cumulative history)."""
        start = len(self.finished)
        ticks = 0
        while self.pending() and ticks < max_ticks:
            self.tick()
            ticks += 1
        return self.finished[start:]

    @property
    def num_free_blocks(self) -> int | None:
        return self.manager.num_free if self.manager is not None else None

    # -- shared internals -----------------------------------------------------
    def _sample(self, logits) -> int:
        """Greedy argmax, or seeded temperature/top-k sampling."""
        if self.greedy:
            # analyze: allow[host-sync] legacy per-token path; the batched tick samples on-device
            return int(jnp.argmax(logits))
        self._key, sub = jax.random.split(self._key)
        scaled = logits.astype(jnp.float32) / max(self.temperature, 1e-6)
        if self.top_k > 0:
            k = min(self.top_k, scaled.shape[-1])
            kth = jax.lax.top_k(scaled, k)[0][-1]
            scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
        # analyze: allow[host-sync] seeded sampling emits one host token by contract
        return int(jax.random.categorical(sub, scaled))

    def _emit(self, req: Request, tok: int) -> bool:
        """Record one sampled token; returns True when the request is done."""
        req.out_tokens.append(tok)
        if self.obs is not None:
            self._c_tokens.inc()
        if req.eos is not None and tok == req.eos:
            self._finish(req, "eos")
            return True
        if len(req.out_tokens) >= req.max_tokens:
            self._finish(req, "max_tokens")
            return True
        return False

    def _finish(self, req: Request, reason: str) -> None:
        req.done = True
        req.finish_reason = reason
        req.t_done = time.perf_counter()
        self.finished.append(req)
        if self.obs is not None:
            self.obs.event("finish", t=req.t_done, rid=req.rid,
                           tick=self._tick_no, reason=reason,
                           n_out=len(req.out_tokens))

    def _seq_tokens(self, req: Request) -> list[int]:
        """Tokens a (re-)admitted request must prefill: the prompt plus
        anything already emitted before a preemption."""
        return req.prompt + req.out_tokens

    def _find_batch_axes(self):
        """Identify each state leaf's slot axis structurally (dim sizes like
        n_layers can collide with the slot count)."""
        spec = self.session.spec
        # pin the block-pool size: the default scales with ``slots``, and a
        # pool dim that grows with the probe would masquerade as a slot axis
        bigger = type(self.session)(self.cfg, dataclasses.replace(
            spec, slots=spec.slots + 1, num_blocks=spec.resolved_num_blocks()))
        sa = jax.eval_shape(self.session.init_state)
        sb = jax.eval_shape(bigger.init_state)
        return jax.tree.map(
            lambda a, b: next((i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                               if x != y), -1), sa, sb)

    def _reset_slots(self, slot_ids: list[int]):
        """Clear per-slot state rows before a new occupant prefills (a stale
        ring/recurrent state would otherwise leak into the new sequence).
        Block-pool leaves have no slot axis and are skipped — block ownership
        already isolates sequences there."""
        mask = np.zeros(self.slots, bool)
        mask[slot_ids] = True
        m = jnp.asarray(mask)

        def upd(path, leaf, axis):
            name = str(getattr(path[-1], "key", getattr(path[-1], "idx", "")))
            if axis < 0 or name == "block_tables":
                return leaf
            mb = m.reshape((1,) * axis + (self.slots,) + (1,) * (leaf.ndim - axis - 1))
            fill = -1 if leaf.dtype == jnp.int32 else 0
            return jnp.where(mb, jnp.asarray(fill, leaf.dtype), leaf)

        self.state = jax.tree_util.tree_map_with_path(upd, self.state,
                                                      self._batch_axis)

    def _sync_tables(self, extra: dict[int, int] | None = None):
        """Re-pack per-slot block tables into the state (block backends)."""
        if self.manager is None:
            return
        rids: list[int | None] = [r.rid if r is not None else None
                                  for r in self.slot_req]
        for s, rid in (extra or {}).items():
            rids[s] = rid
        bt = pack_block_tables(self.manager, rids, self.session.spec.table_width())
        self.state = self.session.with_tables(self.state, bt)

    # -- admission ------------------------------------------------------------
    def _admit(self):
        """Policy-ordered admission (FCFS by default): take waiting requests
        while a slot is free and — for block backends — the pool covers their
        prompt plus one lookahead token, then prefill them together in
        fixed-width chunks."""
        free_slots = [s for s in range(self.slots) if self.slot_req[s] is None]
        batch: list[tuple[int, Request]] = []
        reserve = 0  # lookahead blocks promised to earlier batch members
        if self.queue and free_slots:
            order = self.admission.order(list(self.queue), time.perf_counter())
            for req in order:
                if not free_slots or len(batch) >= self.prefill_batch:
                    break
                n_tok = len(self._seq_tokens(req))
                if self.manager is not None:
                    # admission wants the prompt *plus one lookahead token*
                    # free — counting lookahead already reserved by this
                    # batch's earlier members — so a fresh admission doesn't
                    # immediately preempt on its first decode tick
                    bs = self.manager.block_size
                    need = blocks_for(n_tok + 1, bs)
                    if need + reserve > self.manager.num_free or \
                            not self.manager.allocate(req.rid, n_tok):
                        break  # head-of-line blocks: keep the policy order
                    reserve += need - blocks_for(n_tok, bs)
                self._remove_from_queue(req)
                batch.append((free_slots.pop(0), req))
        if not batch:
            return
        if self.obs is not None:
            t_admit = time.perf_counter()
            for s, req in batch:
                self.obs.event("admit", t=t_admit, rid=req.rid, slot=s,
                               tick=self._tick_no,
                               n_tokens=len(self._seq_tokens(req)))
                if not req.t_first:  # first admission, not a preempt replay
                    self._h_queue.observe(t_admit - req.t_submit)
        self._reset_slots([s for s, _ in batch])
        if self.session.needs_encoder_ctx:
            for s, req in batch:
                frames = req.enc_frames
                if frames is None:
                    frames = np.zeros((self.cfg.enc_len, self.cfg.d_model),
                                      np.float32)
                self.state = self._begin(self.params, self.state, jnp.int32(s),
                                         jnp.asarray(frames)[None])
        self._sync_tables(extra={s: req.rid for s, req in batch})
        prompts: list[list[int] | None] = [None] * self.slots
        for s, req in batch:
            prompts[s] = self._seq_tokens(req)
        on_chunk = None
        if self.obs is not None:
            rids = [req.rid for _, req in batch]

            def on_chunk(c, n_chunks):
                self.obs.event("prefill_chunk", tick=self._tick_no, chunk=c,
                               n_chunks=n_chunks, rids=rids)
        with (self.obs.annotate("repro/serve/prefill")
              if self.obs is not None else _NULL_CTX):
            logits, self.state = steps.chunked_prefill(
                self._prefill, self.params, self.state, prompts,
                chunk=self.prefill_chunk, on_chunk=on_chunk)
            # first-token latency: stamp only after the device finishes
            jax.block_until_ready(logits)
        t_ready = time.perf_counter()
        for s, req in batch:
            fresh = not req.t_first
            if fresh:
                req.t_first = t_ready
                if self.obs is not None:
                    self._h_ttft.observe(t_ready - req.t_submit)
                    self.obs.event("first_token", t=t_ready, rid=req.rid,
                                   tick=self._tick_no,
                                   ttft_s=t_ready - req.t_submit)
            self._t_last_tok[s] = t_ready
            tok = self._sample(logits[s])
            if self._emit(req, tok):  # eos on first token / max_tokens=1
                self._t_last_tok.pop(s, None)
                if self.manager is not None:
                    self.manager.free(req.rid)
                continue
            self.slot_req[s] = req
            self.slot_pos[s] = len(prompts[s])
            self._admit_order.append(s)

    # -- decode / preemption --------------------------------------------------
    def _preempt_newest(self) -> int | None:
        """Free the most recently admitted sequence back to the waiting
        queue's head; returns its slot.  Recompute-style: emitted tokens
        ride along and are re-prefilled with the prompt on re-admission."""
        for s in reversed(self._admit_order):
            if self.slot_req[s] is None:
                continue
            req = self.slot_req[s]
            self.manager.free(req.rid)
            self.slot_req[s] = None
            self._admit_order.remove(s)
            self.queue.insert(0, req)
            self._t_last_tok.pop(s, None)
            if self.obs is not None:
                self._c_preempt.inc()
                self.obs.event("preempt", rid=req.rid, slot=s,
                               tick=self._tick_no)
            return s
        return None

    def _decode_schedule(self) -> TickPlan | None:
        """Host-side tick planning: grow block tables (preempting on
        exhaustion), pick the active slots, and build the token/position
        batch.  Returns ``None`` when nothing is active."""
        # block backends: grow each active sequence's table to cover the
        # incoming token, preempting the newest-admitted sequence on block
        # exhaustion (the grower itself, if it is the newest — FCFS favors
        # older requests)
        if self.manager is not None:
            for s in list(self._admit_order):
                req = self.slot_req[s]
                if req is None:
                    continue
                while not self.manager.ensure(req.rid, int(self.slot_pos[s]) + 1):
                    victim = self._preempt_newest()
                    if victim == s:
                        break  # the grower was evicted; retries on re-admission
                    if victim is None:  # unreachable: submit-time capacity check
                        raise RuntimeError(
                            f"block pool too small: sequence {req.rid} alone "
                            f"cannot grow to {int(self.slot_pos[s]) + 1} tokens")
        active = [s for s in range(self.slots) if self.slot_req[s] is not None]
        if not active:
            return None
        if self.obs is not None:
            self._c_ticks.inc()
            self.obs.event("decode_tick", tick=self._tick_no,
                           active=len(active))
        toks = np.zeros((self.slots, 1), np.int32)
        positions = np.full((self.slots,), -1, np.int32)
        for s in active:
            toks[s, 0] = self.slot_req[s].out_tokens[-1]
            positions[s] = self.slot_pos[s]
        return TickPlan(active=active,
                        rids=[self.slot_req[s].rid for s in active],
                        positions=positions, toks=toks)

    def _plan_ahead(self, plan: TickPlan) -> TickPlan | None:
        """Plan the tick *after* an in-flight ``plan`` without its token
        values (dispatch-ahead, DESIGN.md §12).

        Safe only when every in-flight slot is guaranteed to survive its
        emission — greedy sampling (tokens can come from a device-side
        argmax), no eos watch, not at the max_tokens/max_len frontier — and
        the pool can grow one more token per sequence without preempting.
        Returns ``None`` otherwise; the caller falls back to collecting the
        in-flight tick first."""
        if not self.greedy:
            return None  # host-side RNG sampling needs the logits on host
        for i, s in enumerate(plan.active):
            req = self.slot_req[s]
            if req is None or req.rid != plan.rids[i] or req.eos is not None:
                return None
            # after the in-flight emission the request must still be live:
            # not its last max_tokens emission, not at the max_len frontier
            if len(req.out_tokens) + 1 >= req.max_tokens:
                return None
            if int(plan.positions[s]) + 1 >= self.max_len - 1:
                return None
        if self.manager is not None:
            for s in plan.active:
                # position p+1 writes token p+1 -> needs p+2 covered; bail to
                # the synchronous path rather than preempt around an
                # uncollected tick
                if not self.manager.ensure(self.slot_req[s].rid,
                                           int(plan.positions[s]) + 2):
                    return None
        positions = np.full((self.slots,), -1, np.int32)
        for s in plan.active:
            positions[s] = plan.positions[s] + 1
        if self.obs is not None:
            self._c_ticks.inc()
            # the in-flight tick has not collected yet, so _tick_no still
            # names it; the ahead tick is the next one
            self.obs.event("decode_tick", tick=self._tick_no + 1,
                           active=len(plan.active))
        return TickPlan(active=list(plan.active), rids=list(plan.rids),
                        positions=positions, toks=None)

    def _decode_dispatch(self, plan: TickPlan, device_toks=None):
        """Launch the jitted decode step for ``plan`` (async under jax);
        ``device_toks`` (a (slots, 1) int32 device array) substitutes for the
        host token batch on the dispatch-ahead path."""
        self._sync_tables()
        toks = device_toks if device_toks is not None else jnp.asarray(plan.toks)
        with (self.obs.annotate("repro/serve/decode")
              if self.obs is not None else _NULL_CTX):
            logits, self.state = self._decode(self.params, self.state, toks,
                                              jnp.asarray(plan.positions))
        return logits

    def _decode_collect(self, plan: TickPlan, logits, toks_host=None):
        """Sample/record one token per scheduled slot and run the finish
        bookkeeping.  ``toks_host`` (a (slots,) int sequence) skips sampling
        — the dispatch-ahead path already pulled the device argmax.  Slots
        whose occupant changed since dispatch (cancelled mid-flight) are
        skipped; their computed token is discarded."""
        for i, s in enumerate(plan.active):
            req = self.slot_req[s]
            if req is None or req.rid != plan.rids[i]:
                continue  # cancelled while the tick was in flight
            tok = (int(toks_host[s]) if toks_host is not None
                   else self._sample(logits[s]))
            self.slot_pos[s] += 1
            if self.obs is not None:
                # tick-granular inter-token latency: the argmax/device_get in
                # _sample already materialized this tick's logits, so the
                # stamp costs no extra device sync
                now = time.perf_counter()
                last = self._t_last_tok.get(s)
                if last is not None:
                    self._h_intertok.observe(now - last)
                self._t_last_tok[s] = now
            if self._emit(req, tok) or self.slot_pos[s] >= self.max_len - 1:
                if not req.done:  # max_len frontier hit: force-finish
                    self._finish(req, "max_len")
                if self.manager is not None:
                    self.manager.free(req.rid)
                self.slot_req[s] = None
                self._admit_order.remove(s)
                self._t_last_tok.pop(s, None)


class PagedEngine(Engine):
    """Deprecated alias of :class:`Engine`.

    Every family now serves through the unified session scheduler; the old
    ring-cache reference engine is gone and ``PagedEngine`` simply forwards
    to :class:`Engine` (whose default backend for full-attention dense/moe
    is the paged block pool this class used to hard-code).
    """

    def __init__(self, *args, **kwargs):
        import warnings
        warnings.warn("PagedEngine is a deprecated alias; use serve.engine."
                      "Engine", DeprecationWarning, stacklevel=2)
        super().__init__(*args, **kwargs)

"""Continuous-batching serving engines.

A Python scheduler drives jitted programs (see ``serve/steps.py``) over a
fixed decode batch of ``slots``.  Requests join after prefill; every decode
tick advances all active slots one token; finished sequences (eos or
max_tokens) free their resources immediately — classic continuous batching.

Two cache disciplines share the scheduler protocol (``submit`` / ``tick`` /
``run``):

* :class:`Engine` — the per-slot **ring** layout: each slot owns a
  ``max_len`` ring, prefill is single-sequence with host-side cache surgery,
  and decode groups slots by position (the jitted decode takes one shared
  scalar ``pos``).  Simple and correct; kept as the reference
  implementation the fuzz suite checks the paged engine against.
* :class:`PagedEngine` — the **paged** layout (DESIGN.md §6): KV memory is a
  block pool (``serve/kv_cache.py``), admission is block-table-driven
  (admit while free blocks cover the prompt plus one lookahead token),
  waiting prompts prefill *batched* in fixed-width chunks, decode is one
  call per tick regardless of position raggedness (per-sequence positions),
  and block exhaustion preempts the newest sequence back to the waiting
  queue (recompute-style: its blocks are freed; emitted tokens are kept and
  re-prefilled with the prompt on re-admission, so greedy outputs are
  unchanged).

First-token latency (``Request.t_first``) is stamped only after
``jax.block_until_ready`` on the prefill logits — timing the dispatch
instead of the computation understates TTFT by the entire prefill on an
async backend.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..config import ModelConfig
from ..models.api import Model
from . import steps
from .kv_cache import PagedKVCache, blocks_for


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_tokens: int
    eos: int | None = None
    out_tokens: list[int] = field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


class EngineBase:
    """Scheduler protocol + sampling shared by both cache disciplines."""

    def __init__(self, model: Model, params, *, greedy: bool = True,
                 temperature: float = 1.0, top_k: int = 0, seed: int = 0,
                 kernel_backend: str | None = None):
        self.model = model
        self.cfg: ModelConfig = model.cfg
        self.params = params
        self.greedy = greedy
        self.temperature = temperature
        self.top_k = top_k
        self._key = jax.random.PRNGKey(seed)
        self.kernel_backend = kernel_backend  # None -> dispatch policy chain
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self._next_rid = 0

    # -- public API -----------------------------------------------------------
    def submit(self, prompt: list[int], max_tokens: int = 32,
               eos: int | None = None) -> Request:
        if not prompt:
            raise ValueError("empty prompt")
        if max_tokens < 1:
            raise ValueError("max_tokens must be >= 1")
        self._validate(prompt, max_tokens)
        req = Request(self._next_rid, list(prompt), max_tokens, eos,
                      t_submit=time.time())
        self._next_rid += 1
        self.queue.append(req)
        return req

    def _validate(self, prompt: list[int], max_tokens: int) -> None:
        """Subclass hook: reject requests that can never be served."""

    def pending(self) -> bool:
        raise NotImplementedError

    def tick(self) -> None:
        """One scheduler step: admit waiting requests, then decode one token
        for every active sequence."""
        raise NotImplementedError

    def run(self, max_ticks: int = 10_000) -> list[Request]:
        ticks = 0
        while self.pending() and ticks < max_ticks:
            self.tick()
            ticks += 1
        return self.finished

    # -- shared internals -----------------------------------------------------
    def _sample(self, logits) -> int:
        """Greedy argmax, or seeded temperature/top-k sampling."""
        if self.greedy:
            return int(jnp.argmax(logits))
        self._key, sub = jax.random.split(self._key)
        scaled = logits.astype(jnp.float32) / max(self.temperature, 1e-6)
        if self.top_k > 0:
            k = min(self.top_k, scaled.shape[-1])
            kth = jax.lax.top_k(scaled, k)[0][-1]
            scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
        return int(jax.random.categorical(sub, scaled))

    def _emit(self, req: Request, tok: int) -> bool:
        """Record one sampled token; returns True when the request is done."""
        req.out_tokens.append(tok)
        if (req.eos is not None and tok == req.eos) or \
                len(req.out_tokens) >= req.max_tokens:
            req.done = True
            req.t_done = time.time()
            self.finished.append(req)
            return True
        return False


class Engine(EngineBase):
    """Ring-cache engine (single-sequence prefill + slot-wise cache surgery).

    The KV layout is per-slot rings sized ``max_len``; memory is
    ``slots × max_len`` regardless of live tokens.  Kept as the simple
    reference the paged engine is fuzz-tested against.
    """

    def __init__(self, model: Model, params, *, slots: int = 4, max_len: int = 512,
                 cache_dtype=jnp.float32, greedy: bool = True,
                 temperature: float = 1.0, top_k: int = 0, seed: int = 0,
                 kernel_backend: str | None = None):
        super().__init__(model, params, greedy=greedy, temperature=temperature,
                         top_k=top_k, seed=seed, kernel_backend=kernel_backend)
        self.slots = slots
        self.max_len = max_len
        self.cache = model.init_cache(slots, max_len, cache_dtype)
        # identify each cache leaf's batch axis structurally (dim sizes like
        # n_layers can collide with the slot count)
        sa = jax.eval_shape(lambda: model.init_cache(slots, max_len, cache_dtype))
        sb = jax.eval_shape(lambda: model.init_cache(slots + 1, max_len, cache_dtype))
        self._batch_axis = jax.tree.map(
            lambda a, b: next((i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                               if x != y), -1), sa, sb)
        self.slot_req: list[Request | None] = [None] * slots
        self.slot_pos = np.zeros(slots, np.int32)  # next position to decode
        self._prefill, self._decode = steps.ring_step_fns(
            model, steps.canonical_cache_dtype(cache_dtype), max_len,
            kernel_backend)

    def pending(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slot_req)

    def tick(self) -> None:
        self._admit()
        self._decode_tick()

    # -- internals ------------------------------------------------------------
    def _validate(self, prompt: list[int], max_tokens: int) -> None:
        """The ring holds ``max_len`` positions: a longer prompt would be
        silently cropped by the slot surgery — reject it up front (mirrors
        PagedEngine's contract)."""
        if len(prompt) + 1 > self.max_len:
            raise ValueError(f"prompt needs {len(prompt) + 1} positions "
                             f"> max_len {self.max_len}")

    def _admit(self):
        for s in range(self.slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.pop(0)
                toks = jnp.asarray([req.prompt], jnp.int32)
                logits, cache1 = self._prefill(self.params, {"tokens": toks})
                # first-token latency: stamp only after the device finishes
                jax.block_until_ready(logits)
                req.t_first = time.time()
                tok = self._sample(logits[0])
                if self._emit(req, tok):  # eos on first token / max_tokens=1
                    continue
                self._install(s, cache1, len(req.prompt))
                self.slot_req[s] = req
                self.slot_pos[s] = len(req.prompt)

    def _install(self, slot: int, cache1, prompt_len: int):
        """Copy a batch-1 prefill cache into batch slot ``slot``.

        Leaves with a batch dim get slot-surgery (ring dims padded/cropped to
        the engine's max_len); batchless int32 leaves (position rings, shared
        across the batch) merge by elementwise max — valid because decode
        attention masks ``kpos <= qpos`` per query, so a slot lagging behind
        the shared ring frontier never sees future entries.
        """
        def _fit(one, fshape, axis):
            """Pad/crop every dim after ``axis`` to match fshape."""
            pads, slices = [], []
            for d in range(one.ndim):
                target = fshape[d]
                diff = target - one.shape[d]
                pads.append((0, max(diff, 0)))
                slices.append(slice(0, target))
            fill = -1 if one.dtype == jnp.int32 else 0
            return jnp.pad(one, pads, constant_values=fill)[tuple(slices)]

        def upd(full, one, axis):
            fshape = full.shape
            if axis >= 0:
                idx = [slice(None)] * len(fshape)
                idx[axis] = slice(slot, slot + 1)
                tgt = list(fshape)
                tgt[axis] = 1
                return full.at[tuple(idx)].set(_fit(one, tgt, axis))
            if full.dtype == jnp.int32:  # shared position rings
                return jnp.maximum(full, _fit(one, full.shape, 0))
            return full

        self.cache = jax.tree.map(upd, self.cache, cache1, self._batch_axis)

    def _decode_tick(self):
        active = [s for s in range(self.slots) if self.slot_req[s] is not None]
        if not active:
            return
        # all active slots share a tick; position is per-slot via pos rings,
        # we step each active slot one token (batched decode over all slots)
        toks = np.zeros((self.slots, 1), np.int32)
        for s in active:
            toks[s, 0] = self.slot_req[s].out_tokens[-1]
        # engine-level simplification: one decode_step per distinct position
        # group (slots admitted together share positions)
        groups: dict[int, list[int]] = {}
        for s in active:
            groups.setdefault(int(self.slot_pos[s]), []).append(s)
        for pos, slots in groups.items():
            logits, new_cache = self._decode(self.params, self.cache,
                                             {"tokens": jnp.asarray(toks)},
                                             jnp.int32(pos))
            # keep updates only for slots in this group
            mask = np.zeros(self.slots, bool)
            mask[slots] = True

            def sel(new, old, axis):
                if axis >= 0:
                    m = jnp.asarray(mask).reshape(
                        (1,) * axis + (self.slots,) + (1,) * (new.ndim - axis - 1))
                    return jnp.where(m, new, old)
                return new  # shared leaves (pos rings) — same for the group

            self.cache = jax.tree.map(sel, new_cache, self.cache, self._batch_axis)
            for s in slots:
                req = self.slot_req[s]
                tok = self._sample(logits[s])
                self.slot_pos[s] += 1
                if self._emit(req, tok) or self.slot_pos[s] >= self.max_len - 1:
                    if not req.done:  # ring frontier hit: force-finish
                        req.done = True
                        req.t_done = time.time()
                        self.finished.append(req)
                    self.slot_req[s] = None


class PagedEngine(EngineBase):
    """Paged-KV continuous batching: block-table admission, batched chunked
    prefill, single ragged decode call per tick, preempt-to-waiting.

    ``slots`` is the decode batch width; KV memory is ``num_blocks`` blocks
    of ``block_size`` tokens shared by all sequences (defaults to full
    occupancy: every slot can reach ``max_len``).  ``cache_dtype`` may be
    ``"float32" | "bfloat16" | "float16" | "int8"`` (int8 stores
    per-(block-slot, head) scales alongside the values; see
    ``models.transformer.init_paged_cache``).
    """

    def __init__(self, model: Model, params, *, slots: int = 4, max_len: int = 512,
                 block_size: int = 16, num_blocks: int | None = None,
                 cache_dtype="float32", prefill_batch: int = 2,
                 prefill_chunk: int = 32, greedy: bool = True,
                 temperature: float = 1.0, top_k: int = 0, seed: int = 0,
                 kernel_backend: str | None = None):
        super().__init__(model, params, greedy=greedy, temperature=temperature,
                         top_k=top_k, seed=seed, kernel_backend=kernel_backend)
        cfg = model.cfg
        if model.init_paged_cache is None:
            raise ValueError(f"family {cfg.family!r} has no paged-cache path")
        if cfg.window:
            raise NotImplementedError("paged serving assumes full attention "
                                      "(window=0); use the ring engine for SWA")
        if cfg.pos_type not in ("rope", "none"):
            raise NotImplementedError(
                f"paged serving supports pos_type rope|none, not {cfg.pos_type!r}")
        self.slots = slots
        self.max_len = max_len
        self.block_size = block_size
        self.prefill_batch = max(1, prefill_batch)
        self.prefill_chunk = max(1, prefill_chunk)
        if num_blocks is None:
            num_blocks = 1 + slots * blocks_for(max_len, block_size)
        dtype_name = steps.canonical_cache_dtype(cache_dtype)
        self.kv = PagedKVCache(model, num_blocks=num_blocks,
                               block_size=block_size, max_len=max_len,
                               cache_dtype=steps.CACHE_DTYPES[dtype_name])
        self.slot_req: list[Request | None] = [None] * slots
        self.slot_pos = np.zeros(slots, np.int32)  # next position to decode
        self._admit_order: list[int] = []  # slots, oldest admission first
        self._prefill_chunk, self._decode = steps.paged_step_fns(
            model, kernel_backend)

    def pending(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slot_req)

    def tick(self) -> None:
        self._admit()
        self._decode_tick()

    @property
    def num_free_blocks(self) -> int:
        return self.kv.num_free

    # -- internals ------------------------------------------------------------
    def _validate(self, prompt: list[int], max_tokens: int) -> None:
        """A request must be servable *alone* (worst case: everything else
        preempted): its total token footprint — prompt + generated, capped by
        the ``max_len`` frontier — must fit the whole pool.  Rejecting at
        submit keeps mid-run growth failures recoverable by preemption."""
        if len(prompt) + 1 > self.max_len:
            raise ValueError(f"prompt needs {len(prompt) + 1} positions "
                             f"> max_len {self.max_len}")
        worst = min(len(prompt) + max_tokens, self.max_len)
        if blocks_for(worst, self.block_size) > self.kv.num_blocks - 1:
            raise ValueError(
                f"request needs up to {blocks_for(worst, self.block_size)} "
                f"blocks but the pool only has {self.kv.num_blocks - 1}")
    def _seq_tokens(self, req: Request) -> list[int]:
        """Tokens whose K/V a (re-)admitted request must hold: the prompt
        plus anything already emitted before a preemption."""
        return req.prompt + req.out_tokens

    def _admit(self):
        """FCFS admission: take waiting requests while a slot is free and the
        block pool covers their prompt plus one lookahead token, then prefill
        them together in fixed-width chunks (one jitted program)."""
        free_slots = [s for s in range(self.slots) if self.slot_req[s] is None]
        batch: list[tuple[int, Request]] = []
        reserve = 0  # lookahead blocks promised to earlier batch members
        while self.queue and free_slots and len(batch) < self.prefill_batch:
            req = self.queue[0]
            n_tok = len(self._seq_tokens(req))
            # admission wants the prompt *plus one lookahead token* free —
            # counting lookahead already reserved by this batch's earlier
            # members — so a fresh admission doesn't immediately preempt on
            # its first decode tick
            need = blocks_for(n_tok + 1, self.block_size)
            if need + reserve > self.kv.num_free or \
                    not self.kv.manager.allocate(req.rid, n_tok):
                break  # head-of-line blocks: keep FCFS order
            reserve += need - blocks_for(n_tok, self.block_size)
            self.queue.pop(0)
            batch.append((free_slots.pop(0), req))
        if not batch:
            return
        # pad the prompt batch to the fixed prefill width (dummy rows write
        # only to the null block) so the chunk program has one static shape
        prompts = [self._seq_tokens(r) for _, r in batch]
        prompts += [[]] * (self.prefill_batch - len(batch))
        bt = self.kv.block_table([r.rid for _, r in batch]
                                 + [None] * (self.prefill_batch - len(batch)))
        logits, self.kv.data = steps.chunked_prefill(
            self._prefill_chunk, self.params, self.kv.data, prompts, bt,
            chunk=self.prefill_chunk)
        # first-token latency: stamp only after the device finishes
        jax.block_until_ready(logits)
        t_ready = time.time()
        for i, (s, req) in enumerate(batch):
            if not req.t_first:
                req.t_first = t_ready
            tok = self._sample(logits[i])
            if self._emit(req, tok):  # eos on first token / max_tokens=1
                self.kv.manager.free(req.rid)
                continue
            self.slot_req[s] = req
            self.slot_pos[s] = len(prompts[i])
            self._admit_order.append(s)

    def _preempt_newest(self) -> int | None:
        """Free the most recently admitted sequence back to the waiting
        queue's head; returns its slot.  Recompute-style: emitted tokens
        ride along and are re-prefilled with the prompt on re-admission."""
        for s in reversed(self._admit_order):
            if self.slot_req[s] is None:
                continue
            req = self.slot_req[s]
            self.kv.manager.free(req.rid)
            self.slot_req[s] = None
            self._admit_order.remove(s)
            self.queue.insert(0, req)
            return s
        return None

    def _decode_tick(self):
        # grow each active sequence's table to cover the incoming token,
        # preempting the newest-admitted sequence on block exhaustion (the
        # grower itself, if it is the newest — FCFS favors older requests)
        for s in list(self._admit_order):
            req = self.slot_req[s]
            if req is None:
                continue
            while not self.kv.manager.ensure(req.rid, int(self.slot_pos[s]) + 1):
                victim = self._preempt_newest()
                if victim == s:
                    break  # the grower was evicted; it retries after re-admission
                if victim is None:  # unreachable: submit-time capacity check
                    raise RuntimeError(
                        f"paged pool too small: sequence {req.rid} alone "
                        f"cannot grow to {int(self.slot_pos[s]) + 1} tokens")
        active = [s for s in range(self.slots) if self.slot_req[s] is not None]
        if not active:
            return
        toks = np.zeros((self.slots, 1), np.int32)
        positions = np.full((self.slots,), -1, np.int32)
        for s in active:
            toks[s, 0] = self.slot_req[s].out_tokens[-1]
            positions[s] = self.slot_pos[s]
        bt = self.kv.block_table([self.slot_req[s].rid if self.slot_req[s]
                                  else None for s in range(self.slots)])
        logits, self.kv.data = self._decode(
            self.params, self.kv.data, jnp.asarray(toks), jnp.asarray(bt),
            jnp.asarray(positions))
        for s in active:
            req = self.slot_req[s]
            tok = self._sample(logits[s])
            self.slot_pos[s] += 1
            if self._emit(req, tok) or self.slot_pos[s] >= self.max_len - 1:
                if not req.done:  # frontier hit: force-finish
                    req.done = True
                    req.t_done = time.time()
                    self.finished.append(req)
                self.kv.manager.free(req.rid)
                self.slot_req[s] = None
                self._admit_order.remove(s)

"""Mixture-of-Experts with expert parallelism.

Three execution paths, picked statically from shapes/mesh:

- ``ep``     sort-based capacity-limited dispatch with ``all_to_all`` over the
             ``model`` axis inside ``shard_map`` (train/prefill: tokens are
             sharded over data×model, experts over model).  This is the
             production path whose collectives the roofline measures.
- ``ep_psum``every device applies only its *local* experts to all its tokens,
             masked by the router, then ``psum`` over ``model`` — used when
             the local token count can't shard over ``model`` (decode cells).
- ``dense``  every expert applied to every token (tiny smoke tests only; also
             the correctness oracle for the ep paths).

Expert weights may be TT-compressed (paper technique applied to experts —
the dominant parameter mass in MoE archs; cores stay replicated over data,
sharded over model on the expert dim only).

Expert FFNs route through the unified linear dispatch (``apply_mlp`` fuses
the up/gate activation into the projection epilogue); the block residual is
NOT fused here — the gated combine multiplies each expert's output before
the skip connection, so the add happens after combining in the caller.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..config import ModelConfig
from ..dist.api import batch_axes, current_abstract_mesh
from ..dist.collectives import expert_all_to_all
from .modules import LinearSpec, apply_mlp, init_mlp, linear_spec, mlp_specs, stack_init


# ---------------------------------------------------------------------------
# Specs / init
# ---------------------------------------------------------------------------
def moe_specs(cfg: ModelConfig, ttd_block: bool) -> dict[str, Any]:
    e_specs = mlp_specs(cfg, ttd_block, d_in=cfg.d_model, d_ff=cfg.d_ff_expert,
                        prefix="expert")
    return {"router": linear_spec(cfg, "router", cfg.d_model, cfg.n_experts),
            "expert": e_specs}


def init_moe(key, cfg: ModelConfig, specs, param_dtype):
    k_r, k_e = jax.random.split(key)
    from .modules import init_linear

    return {
        "router": init_linear(k_r, specs["router"], jnp.float32),
        "experts": stack_init(
            lambda k: init_mlp(k, specs["expert"], param_dtype), k_e, cfg.n_experts
        ),
    }


# ---------------------------------------------------------------------------
# Routing
# ---------------------------------------------------------------------------
def _route(params, x, specs, cfg: ModelConfig):
    """x: (T, D) -> probs (T,E) f32, gates (T,K), eids (T,K)."""
    from .modules import apply_linear

    logits = apply_linear(params["router"], x, specs["router"], jnp.float32)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, eids = jax.lax.top_k(probs, cfg.experts_per_token)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return probs, gates, eids


def _aux_loss(probs, eids, cfg: ModelConfig, axes):
    """Switch-style load-balance loss, averaged over all token shards."""
    e = cfg.n_experts
    me = probs.mean(0)  # (E,)
    hits = jnp.zeros((e,), jnp.float32).at[eids.reshape(-1)].add(1.0)
    ce = hits / jnp.maximum(hits.sum(), 1.0)
    if axes:
        me = jax.lax.pmean(me, axes)
        ce = jax.lax.pmean(ce, axes)
    return e * jnp.sum(me * ce) * cfg.router_aux_coef


EXPERT_CHUNK = 128  # capacity-dim chunk: bounds expert-FFN live intermediates


def _expert_ffn(expert_params, xb, specs, cfg, compute_dtype):
    """vmapped per-expert MLP: params stacked (E, ...), xb (E, C, D).

    The capacity dim is scanned in checkpointed chunks so the per-expert
    intermediates (TT stage tensors / d_ff activations) stay bounded — the
    XLA-side analogue of the Pallas kernel's block_b."""
    e, c, d = xb.shape

    def ffn(t):
        return jax.vmap(lambda p, u: apply_mlp(p, u, specs["expert"], cfg, compute_dtype))(
            expert_params, t)

    if c <= EXPERT_CHUNK or c % EXPERT_CHUNK != 0:
        return ffn(xb)
    nc = c // EXPERT_CHUNK
    xs = jnp.moveaxis(xb.reshape(e, nc, EXPERT_CHUNK, d), 1, 0)

    @jax.checkpoint
    def body(_, xc):
        return None, ffn(xc)

    _, ys = jax.lax.scan(body, None, xs)
    return jnp.moveaxis(ys, 0, 1).reshape(e, c, ys.shape[-1])


def _excl_cumsum(x):
    c = jnp.cumsum(x)
    return jnp.concatenate([jnp.zeros((1,), x.dtype), c[:-1]])


# ---------------------------------------------------------------------------
# dense path (oracle / tiny tests)
# ---------------------------------------------------------------------------
def _moe_dense(params, x, specs, cfg: ModelConfig, compute_dtype):
    t, d = x.shape
    probs, gates, eids = _route(params, x, specs, cfg)
    combine = jnp.zeros((t, cfg.n_experts), jnp.float32)
    combine = combine.at[jnp.arange(t)[:, None], eids].add(gates)
    ys = _expert_ffn(params["experts"], jnp.broadcast_to(x, (cfg.n_experts, t, d)),
                     specs, cfg, compute_dtype)  # (E, T, D)
    y = jnp.einsum("te,etd->td", combine.astype(compute_dtype), ys)
    return y, _aux_loss(probs, eids, cfg, axes=None)


# ---------------------------------------------------------------------------
# ep_psum path (decode / tokens not shardable over model)
# ---------------------------------------------------------------------------
def _moe_ep_psum(params_local, x, specs, cfg: ModelConfig, compute_dtype, e_l,
                 replicas: int = 1):
    t, d = x.shape
    probs, gates, eids = _route(params_local, x, specs, cfg)
    combine = jnp.zeros((t, cfg.n_experts), jnp.float32)
    combine = combine.at[jnp.arange(t)[:, None], eids].add(gates)
    if replicas > 1:  # each expert computed on `replicas` shards: split gate
        combine = jnp.tile(combine, (1, replicas)) / replicas
    shard = jax.lax.axis_index("model")
    g_local = jax.lax.dynamic_slice(combine, (0, shard * e_l), (t, e_l))
    ys = _expert_ffn(params_local["experts"],
                     jnp.broadcast_to(x, (e_l, t, d)), specs, cfg, compute_dtype)
    y = jnp.einsum("te,etd->td", g_local.astype(compute_dtype), ys)
    y = jax.lax.psum(y, "model")
    aux = _aux_loss(probs, eids, cfg, axes=None)
    return y, aux


# ---------------------------------------------------------------------------
# ep path: sort + all_to_all (train / prefill)
# ---------------------------------------------------------------------------
def _moe_ep(params_local, x, specs, cfg: ModelConfig, compute_dtype, e_l, n_shards,
            aux_axes, replicas: int = 1):
    """``replicas`` > 1: each physical expert is duplicated across
    ``replicas`` shards (expert data parallelism for E < n_shards, e.g.
    mixtral's 8 experts on TP=16).  Routing uses virtual expert ids
    v = e + E·(assignment_index mod replicas) to load-balance the copies;
    weight gradients sync automatically because the copies are produced by
    tiling (whose transpose is a sum)."""
    t, d = x.shape
    k = cfg.experts_per_token
    tk = t * k
    e = cfg.n_experts * replicas

    probs, gates, eids = _route(params_local, x, specs, cfg)

    # --- sort assignments by destination (virtual) expert ---
    flat_e = eids.reshape(tk)
    if replicas > 1:
        flat_e = flat_e + cfg.n_experts * (jnp.arange(tk, dtype=flat_e.dtype) % replicas)
    order = jnp.argsort(flat_e, stable=True)
    fe_s = flat_e[order]
    tok_s = order // k
    gate_s = gates.reshape(tk)[order]

    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    dest = fe_s // e_l  # destination model-shard
    shard_counts = counts.reshape(n_shards, e_l).sum(1)
    pos_in_dest = jnp.arange(tk, dtype=jnp.int32) - _excl_cumsum(shard_counts)[dest]

    cap_send = int(math.ceil(tk / n_shards * cfg.capacity_factor / 8)) * 8
    oob = jnp.where(pos_in_dest < cap_send, pos_in_dest, cap_send)  # OOB -> drop

    send_x = jnp.zeros((n_shards, cap_send, d), compute_dtype)
    send_x = send_x.at[dest, oob].set(x[tok_s].astype(compute_dtype), mode="drop")
    send_eid = jnp.full((n_shards, cap_send), e_l, jnp.int32)  # e_l = invalid
    send_eid = send_eid.at[dest, oob].set(fe_s % e_l, mode="drop")

    # --- exchange over the model axis ---
    recv_x = expert_all_to_all(send_x, "model")
    recv_eid = expert_all_to_all(send_eid[..., None], "model")[..., 0]

    # --- bucket received tokens per local expert ---
    r = n_shards * cap_send
    r_x = recv_x.reshape(r, d)
    r_e = recv_eid.reshape(r)
    order2 = jnp.argsort(r_e, stable=True)  # invalid (e_l) sort last
    e2_s = r_e[order2]
    counts2 = jnp.zeros((e_l,), jnp.int32).at[jnp.where(r_e < e_l, r_e, 0)].add(
        (r_e < e_l).astype(jnp.int32))
    cap_e = int(math.ceil(r / e_l * cfg.capacity_factor / EXPERT_CHUNK)) * EXPERT_CHUNK
    pos2 = jnp.arange(r, dtype=jnp.int32) - _excl_cumsum(counts2)[jnp.where(e2_s < e_l, e2_s, 0)]
    pos2 = jnp.where((e2_s < e_l) & (pos2 < cap_e), pos2, cap_e)  # OOB -> drop
    e2_idx = jnp.where(e2_s < e_l, e2_s, 0)

    buf = jnp.zeros((e_l, cap_e, d), compute_dtype)
    buf = buf.at[e2_idx, pos2].set(r_x[order2], mode="drop")

    h = _expert_ffn(params_local["experts"], buf, specs, cfg, compute_dtype)

    # --- un-bucket, send back, combine ---
    y_sorted = h.at[e2_idx, pos2].get(mode="fill", fill_value=0)  # (R, D)
    y_slots = jnp.zeros((r, d), compute_dtype).at[order2].set(y_sorted)
    back = expert_all_to_all(y_slots.reshape(n_shards, cap_send, d), "model")
    contrib = back.at[dest, oob].get(mode="fill", fill_value=0)  # (TK, D)
    y = jnp.zeros((t, d), jnp.float32)
    y = y.at[tok_s].add(contrib.astype(jnp.float32) * gate_s[:, None])

    aux = _aux_loss(probs, eids, cfg, axes=aux_axes)
    return y.astype(compute_dtype), aux


# ---------------------------------------------------------------------------
# tp path: experts column/row-sharded over `model` (used when the expert
# count doesn't divide the model axis, e.g. mixtral's 8 experts on TP=16).
# All experts run on all tokens (E/topk compute overhead — a hillclimb
# candidate, see EXPERIMENTS.md §Perf); token chunks are scanned to bound
# the live intermediates.
# ---------------------------------------------------------------------------
def _moe_tp(params, x, specs, cfg: ModelConfig, compute_dtype):
    from ..dist.api import BATCH
    from ..dist import constrain

    t, d = x.shape
    probs, gates, eids = _route(params, x, specs, cfg)
    combine = jnp.zeros((t, cfg.n_experts), jnp.float32)
    combine = combine.at[jnp.arange(t)[:, None], eids].add(gates)

    chunk = EXPERT_CHUNK
    if t <= chunk or t % chunk != 0:
        ys = _expert_ffn(params["experts"],
                         jnp.broadcast_to(x, (cfg.n_experts, t, d)),
                         specs, cfg, compute_dtype)
        y = jnp.einsum("te,etd->td", combine.astype(compute_dtype), ys)
        return y, _aux_loss(probs, eids, cfg, axes=None)

    nc = t // chunk
    xs = x.reshape(nc, chunk, d)
    cs = combine.reshape(nc, chunk, cfg.n_experts).astype(compute_dtype)

    @jax.checkpoint
    def body(_, inp):
        xc, cc = inp
        ye = _expert_ffn(params["experts"],
                         jnp.broadcast_to(xc, (cfg.n_experts, chunk, d)),
                         specs, cfg, compute_dtype)
        return None, jnp.einsum("te,etd->td", cc, ye)

    _, ys = jax.lax.scan(body, None, (xs, cs))
    return ys.reshape(t, d), _aux_loss(probs, eids, cfg, axes=None)


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------
def apply_moe(params, x, specs, cfg: ModelConfig, compute_dtype):
    """x: (B, S, D) -> (y, aux_loss).

    Chooses dense (no mesh) / ep (tokens shard over model) / ep_psum /
    tp (expert count below the model-axis size).
    """
    b, s, d = x.shape
    mesh = current_abstract_mesh()
    if mesh is None or "model" not in mesh.axis_names or cfg.moe_impl == "dense":
        y, aux = _moe_dense(params, x.reshape(b * s, d), specs, cfg, compute_dtype)
        return y.reshape(b, s, d), aux

    n_shards = mesh.shape["model"]
    replicas = 1
    if cfg.moe_impl == "tp" or cfg.n_experts % n_shards != 0:
        if cfg.moe_impl != "tp" and n_shards % cfg.n_experts == 0:
            # replicated-expert EP: duplicate each expert across
            # n_shards/E shards (virtual experts), keep the all_to_all path
            replicas = n_shards // cfg.n_experts
        else:
            # TP-expert fallback (pure GSPMD, no island): expert weights
            # shard d_ff over `model`, tokens stay batch-sharded
            from ..dist.api import BATCH
            from ..dist import constrain
            x2 = constrain(x, BATCH, None, None)
            y, aux = _moe_tp(params, x2.reshape(b * s, d), specs, cfg, compute_dtype)
            y = constrain(y.reshape(b, s, d), BATCH, "model", None)
            return y, aux

    e_l = cfg.n_experts * replicas // n_shards
    baxes = batch_axes()
    baxes = baxes if isinstance(baxes, tuple) else (baxes,)
    baxes = tuple(a for a in baxes if a in mesh.axis_names)
    b_shards = math.prod(mesh.shape[a] for a in baxes) if baxes else 1

    batch_ok = bool(baxes) and b % b_shards == 0
    tokens_ok = batch_ok and ((b // b_shards) * s) % n_shards == 0 and s >= n_shards
    spec_in = P(baxes if batch_ok else None,
                "model" if tokens_ok and s % n_shards == 0 else None, None)
    expert_params = params["experts"]
    if replicas > 1:
        # expert data parallelism: tile copies (transpose of tile = sum, so
        # the copies' gradients merge automatically)
        expert_params = jax.tree.map(
            lambda a: jnp.tile(a, (replicas,) + (1,) * (a.ndim - 1)), expert_params)
    expert_spec = jax.tree.map(lambda _: P("model"), expert_params)
    router_spec = jax.tree.map(lambda _: P(), params["router"])
    in_specs = ({"experts": expert_spec, "router": router_spec}, spec_in)
    out_specs = (spec_in, P())

    use_ep = tokens_ok and s % n_shards == 0 and cfg.moe_impl == "ep"

    def island(p_local, x_local):
        bl, sl, _ = x_local.shape
        xt = x_local.reshape(bl * sl, d)
        if use_ep:
            y, aux = _moe_ep(p_local, xt, specs, cfg, compute_dtype, e_l, n_shards,
                             aux_axes=tuple(baxes) + ("model",), replicas=replicas)
        else:
            y, aux = _moe_ep_psum(p_local, xt, specs, cfg, compute_dtype, e_l,
                                  replicas=replicas)
            if baxes:
                aux = jax.lax.pmean(aux, tuple(baxes))
        return y.reshape(bl, sl, d), aux

    y, aux = jax.shard_map(
        island, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
    )({"experts": expert_params, "router": params["router"]}, x)
    return y, aux

from .api import Model, build_model, get_model  # noqa: F401  # analyze: allow[deprecated-api] public shim re-export
from .sessions import (  # noqa: F401
    FAMILY_BACKENDS,
    InferenceSession,
    SessionSpec,
    default_backend,
    make_session,
)

from .api import Model, get_model  # noqa: F401

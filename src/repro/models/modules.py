"""Shared model building blocks.

Everything is functional: ``init_*`` produce param pytrees (plain dicts),
``apply``-style functions consume them.  Layers are stacked along a leading
axis and iterated with ``lax.scan`` (keeps HLO size constant in depth — vital
for 512-device dry-run compiles).

The ``Linear`` abstraction is where the paper's technique plugs in: every
linear role resolves (statically, from ``ModelConfig.ttd``/``.quant``) to
dense | tt (Tensor-Train cores, paper §II) | int4 (weight-only quant,
paper §IV).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..config import ModelConfig
from ..core.quant import quantize_int4
from ..core.tt_linear import init_tt_linear
from ..core.ttd import TTSpec
from ..dist import constrain
from ..kernels import dispatch

# ---------------------------------------------------------------------------
# dtype helpers
# ---------------------------------------------------------------------------
DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}


def dt(name: str):
    return DTYPES[name]


# ---------------------------------------------------------------------------
# Linear: dense | tt | int4
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class LinearSpec:
    kind: str  # dense | tt | int4
    n_in: int
    n_out: int
    bias: bool = False
    tt: TTSpec | None = None
    quant_group: int = 128
    role: str = ""
    backend: str = ""  # ModelConfig.kernel_backend preference ("" -> auto)


def linear_spec(cfg: ModelConfig, role: str, n_in: int, n_out: int, bias: bool = False,
                *, ttd_block: bool = True) -> LinearSpec:
    """Resolve a linear role to its implementation per the paper's recipe.

    ``ttd_block`` is False for blocks outside the TT-compressed range
    (paper: 15/28 resp. 19/32 blocks compressed; the rest quant-only).
    """
    ttd = cfg.ttd
    if ttd.enabled and ttd_block and role in ttd.roles:
        ov = ttd.override_for(role)
        try:
            tt = TTSpec.make(
                n_in,
                n_out,
                ov.rank if ov else ttd.rank,
                d=ttd.d,
                in_modes=ov.in_modes if ov else None,
                out_modes=ov.out_modes if ov else None,
            )
            return LinearSpec("tt", n_in, n_out, bias=bias, tt=tt, role=role,
                              backend=cfg.kernel_backend)
        except ValueError:
            pass  # un-factorizable dim: fall through to dense/int4
    if cfg.quant.enabled and n_in % cfg.quant.group_size == 0:
        return LinearSpec("int4", n_in, n_out, bias=bias,
                          quant_group=cfg.quant.group_size, role=role,
                          backend=cfg.kernel_backend)
    return LinearSpec("dense", n_in, n_out, bias=bias, role=role,
                      backend=cfg.kernel_backend)


def init_linear(key: jax.Array, spec: LinearSpec, param_dtype) -> dict[str, Any]:
    """Initialize one linear layer's params."""
    k_w, k_b = jax.random.split(key)
    out: dict[str, Any] = {}
    if spec.kind == "dense":
        std = 1.0 / math.sqrt(spec.n_in)
        out["w"] = (jax.random.normal(k_w, (spec.n_in, spec.n_out), jnp.float32) * std).astype(param_dtype)
    elif spec.kind == "tt":
        out.update(init_tt_linear(k_w, spec.tt, dtype=param_dtype))
    elif spec.kind == "int4":
        # random int4-quantized weight (serve-path init; real use loads ckpts)
        std = 1.0 / math.sqrt(spec.n_in)
        w = jax.random.normal(k_w, (spec.n_out, spec.n_in), jnp.float32) * std
        out.update(quantize_int4(w, spec.quant_group))
    else:
        raise ValueError(spec.kind)
    if spec.bias:
        out["b"] = jnp.zeros((spec.n_out,), param_dtype)
    return out


def apply_linear(params: dict[str, Any], x: jax.Array, spec: LinearSpec,
                 compute_dtype=jnp.bfloat16, *, scale: jax.Array | None = None,
                 residual: jax.Array | None = None,
                 activation: str | None = None,
                 backend: str | None = None) -> jax.Array:
    """y = act(x W [* scale] + b) [+ residual]; x: (..., n_in) -> (..., n_out).

    All kinds route through ``repro.kernels.dispatch``; the epilogue operands
    ride into the kernel (the paper's TTDLinear-BN(-Res) fusion) instead of
    being applied as separate ops.  ``backend`` overrides the resolved policy
    (see dispatch.resolve_backend).
    """
    x = x.astype(compute_dtype)
    backend = dispatch.resolve_backend(backend, role=spec.role,
                                       preferred=spec.backend)
    bias = params["b"] if spec.bias else None
    if spec.kind == "dense":
        y = dispatch.dense_linear(x, params["w"].astype(compute_dtype),
                                  scale=scale, bias=bias, residual=residual,
                                  activation=activation, backend=backend,
                                  role=spec.role)
    elif spec.kind == "tt":
        y = dispatch.tt_linear(x, params["cores"], spec.tt, scale=scale,
                               bias=bias, residual=residual,
                               activation=activation, backend=backend,
                               role=spec.role)
    elif spec.kind == "int4":
        y = dispatch.int4_matmul(x, params["qweight"], params["scales"],
                                 group=spec.quant_group, scale=scale, bias=bias,
                                 residual=residual, activation=activation,
                                 backend=backend, role=spec.role)
    else:
        raise ValueError(spec.kind)
    return y


def linear_param_count(spec: LinearSpec) -> int:
    n = spec.n_out if spec.bias else 0
    if spec.kind == "tt":
        return n + spec.tt.n_params()
    return n + spec.n_in * spec.n_out


def linear_param_bits(spec: LinearSpec, param_bits: int = 16) -> int:
    """Storage bits (int4 weights count 4 bits + scales)."""
    n = spec.n_out * param_bits if spec.bias else 0
    if spec.kind == "tt":
        return n + spec.tt.n_params() * param_bits
    if spec.kind == "int4":
        groups = spec.n_in // spec.quant_group
        return n + spec.n_in * spec.n_out * 4 + spec.n_out * groups * 16
    return n + spec.n_in * spec.n_out * param_bits


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def init_norm(cfg: ModelConfig, dim: int, param_dtype) -> dict[str, Any]:
    p = {"scale": jnp.ones((dim,), param_dtype)}
    if cfg.norm_type == "layernorm":
        p["bias"] = jnp.zeros((dim,), param_dtype)
    return p


def apply_norm(params, x, cfg: ModelConfig, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary positions (standard, partial, M-RoPE)
# ---------------------------------------------------------------------------
def rope_angles(positions: jax.Array, head_dim: int, theta: float,
                partial: float = 1.0, mrope_sections=None) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables.

    positions: (..., S) int32 — or (3, ..., S) for M-RoPE where the three
    leading planes are (t, h, w) position ids and ``mrope_sections`` splits
    the rotary half-dim between them (Qwen2-VL §M-RoPE).
    Returns cos, sin of shape (..., S, rot_half).
    """
    rot_dim = int(head_dim * partial)
    half = rot_dim // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    if mrope_sections is not None:
        sec = np.asarray(mrope_sections)
        assert sec.sum() == half, (sec, half)
        sec_id = np.repeat(np.arange(len(sec)), sec)  # (half,) -> which plane
        pos = positions.astype(jnp.float32)  # (3, ..., S)
        angle = pos[sec_id, ..., :, None] * 0  # placeholder to get shape
        # gather the right plane per frequency index
        planes = jnp.stack([pos[i] for i in range(len(sec))], axis=-1)  # (...,S,3)
        angle = planes[..., sec_id] * inv_freq  # (..., S, half)
    else:
        angle = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.cos(angle), jnp.sin(angle)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array, partial: float = 1.0) -> jax.Array:
    """x: (B, S, H, Dh); cos/sin: (B, S, half) or (S, half)."""
    dh = x.shape[-1]
    rot = int(dh * partial)
    half = rot // 2
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., :half], xr[..., half:]
    if cos.ndim == 2:  # (S, half) -> broadcast over batch
        c = cos[None, :, None, :]
        s = sin[None, :, None, :]
    else:  # (B, S, half)
        c = cos[:, :, None, :]
        s = sin[:, :, None, :]
    o1 = x1 * c - x2 * s
    o2 = x2 * c + x1 * s
    return jnp.concatenate([o1, o2, xp], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (pure-JAX flash: blocked online softmax; GQA; causal / SWA /
# cross).  The Pallas equivalent would target TPU; this path is what the
# dry-run lowers (see DESIGN.md §2).
# ---------------------------------------------------------------------------
NEG_INF = -1e30


def _block_mask(qpos, kpos, kmask, causal: bool, window: int):
    """(qb, kb) validity mask from absolute positions."""
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        m &= qpos[:, None] - kpos[None, :] < window
    if kmask is not None:
        m &= kmask[None, :]
    return m


def attention_dense(q, k, v, *, qpos, kpos, kmask=None, causal=True, window=0,
                    scale=None):
    """Unblocked attention for small S (decode / tiny smoke shapes).

    q: (B, Sq, H, Dh); k, v: (B, Skv, Hkv, Dh); kmask: (B, Skv) or (Skv,).
    """
    b, sq, h, dh = q.shape
    hkv = k.shape[2]
    g = h // hkv
    scale = scale or (1.0 / math.sqrt(dh))
    qh = q.reshape(b, sq, hkv, g, dh).transpose(0, 2, 3, 1, 4)  # (B,Hkv,G,Sq,Dh)
    kh = k.transpose(0, 2, 1, 3)  # (B,Hkv,Skv,Dh)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qh.astype(jnp.float32), kh.astype(jnp.float32)) * scale
    mask = _block_mask(qpos, kpos, None, causal, window)  # (Sq,Skv)
    mask = mask[None, None, None]
    if kmask is not None:
        km = kmask if kmask.ndim == 2 else kmask[None]
        mask = mask & km[:, None, None, None, :]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    vh = v.transpose(0, 2, 1, 3)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v.dtype), vh)
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dh)


def flash_attention(q, k, v, *, qpos, kpos, kmask=None, causal=True, window=0,
                    q_block=1024, kv_block=1024, scale=None):
    """Blocked online-softmax attention; O(q_block·kv_block) live scores.

    Shapes as in :func:`attention_dense`.  Falls back to the dense path when
    the problem is already small.
    """
    b, sq, h, dh = q.shape
    skv = k.shape[1]
    if sq * skv <= max(q_block * kv_block, 1 << 21):
        return attention_dense(q, k, v, qpos=qpos, kpos=kpos, kmask=kmask,
                               causal=causal, window=window, scale=scale)
    hkv = k.shape[2]
    g = h // hkv
    scale = scale or (1.0 / math.sqrt(dh))

    qb = min(q_block, sq)
    kb = min(kv_block, skv)
    pad_q = (-sq) % qb
    pad_k = (-skv) % kb
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        qpos = jnp.pad(qpos, (0, pad_q), constant_values=-1)
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, (0, pad_k), constant_values=2**30)
        kmask = jnp.pad(kmask, (0, pad_k)) if kmask is not None else \
            jnp.pad(jnp.ones((skv,), bool), (0, pad_k))
    elif kmask is None:
        kmask = jnp.ones((skv,), bool)
    nq, nk = q.shape[1] // qb, k.shape[1] // kb

    qh = q.reshape(b, nq, qb, hkv, g, dh).transpose(1, 0, 3, 4, 2, 5)  # (nq,B,Hkv,G,qb,Dh)
    kh = k.reshape(b, nk, kb, hkv, dh).transpose(1, 0, 3, 2, 4)  # (nk,B,Hkv,kb,Dh)
    vh = v.reshape(b, nk, kb, hkv, dh).transpose(1, 0, 3, 2, 4)
    qpos_b = qpos.reshape(nq, qb)
    kpos_b = kpos.reshape(nk, kb)
    kmask_b = kmask.reshape(nk, kb)

    def q_step(_, q_in):
        qblk, qp = q_in  # (B,Hkv,G,qb,Dh), (qb,)

        # checkpoint: scores are recomputed in backward instead of being
        # stacked per (q-block × kv-block) — keeps live memory O(blocks)
        @jax.checkpoint
        def kv_step(carry, kv_in):
            m, l, acc = carry
            kblk, vblk, kp, km = kv_in
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qblk.astype(jnp.float32),
                           kblk.astype(jnp.float32)) * scale
            mask = _block_mask(qp, kp, km, causal, window)[None, None, None]
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * corr + p.sum(-1)
            pv = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(vblk.dtype), vblk)
            acc_new = acc * corr[..., None].astype(acc.dtype) + pv.astype(acc.dtype)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full(qblk.shape[:-1], NEG_INF, jnp.float32)
        l0 = jnp.zeros(qblk.shape[:-1], jnp.float32)
        a0 = jnp.zeros(qblk.shape, jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kh, vh, kpos_b, kmask_b))
        out = jnp.where(l[..., None] > 0, acc / jnp.maximum(l, 1e-30)[..., None], 0.0)
        return None, out.astype(q.dtype)

    _, o = jax.lax.scan(jax.checkpoint(q_step), None, (qh, qpos_b))  # (nq,B,Hkv,G,qb,Dh)
    o = o.transpose(1, 0, 4, 2, 3, 5).reshape(b, nq * qb, h, dh)
    return o[:, :sq] if pad_q else o


# ---------------------------------------------------------------------------
# Per-slot ring caches (session serving path, DESIGN.md §7).  Positions are
# *per sequence* — (B, S) int32, ``-1`` marking padding rows or empty cache
# entries — so one fixed-shape program serves arbitrarily ragged
# continuous-batching schedules.  The matching ragged attention lives in the
# kernel layer: ``kernels.dispatch.prefill_attention`` (ring layout), with
# ``kernels.ref.ring_attention`` as its oracle.
# ---------------------------------------------------------------------------
def ring_kv_update(cache: dict, k_new, v_new, positions):
    """Scatter fresh K/V into per-slot ring caches at ``pos % ring_width``.

    cache: ``{"k","v": (B, WR, Hkv, Dh), "pos": (B, WR) int32}`` (``-1`` =
    empty slot), plus ``k_scale``/``v_scale`` ``(B, WR, Hkv)`` f32 for the
    int8 ring dtype — each written entry gets a per-(entry, head) amax/127
    scale, mirroring ``paged_kv_update``'s quantized pool write.
    k_new/v_new: (B, S, Hkv, Dh); positions: (B, S) int32
    absolute positions, ``-1`` = padding (the write is dropped, so inactive
    rows never disturb a live ring).  The ring width ``WR`` must cover the
    attention window plus the widest chunk written in one call (the builder
    sizes it as ``window + chunk``) so no still-visible key is evicted by a
    same-call write.
    """
    wr = cache["k"].shape[1]
    valid = positions >= 0
    slot = jnp.where(valid, positions % wr, wr)  # wr is out-of-bounds -> drop
    bidx = jnp.broadcast_to(jnp.arange(positions.shape[0])[:, None], slot.shape)
    out = {"pos": cache["pos"].at[bidx, slot].set(positions, mode="drop")}
    for nm, x in (("k", k_new), ("v", v_new)):
        buf = cache[nm]
        if nm + "_scale" in cache:
            x32 = x.astype(jnp.float32)
            sc = jnp.maximum(jnp.max(jnp.abs(x32), axis=-1), 1e-8) / 127.0
            q = jnp.round(x32 / sc[..., None]).astype(jnp.int8)
            out[nm] = buf.at[bidx, slot].set(q, mode="drop")
            out[nm + "_scale"] = cache[nm + "_scale"].at[bidx, slot].set(
                sc, mode="drop")
        else:
            out[nm] = buf.at[bidx, slot].set(x.astype(buf.dtype), mode="drop")
    return out


# ---------------------------------------------------------------------------
# Paged KV-cache write (serve path; see serve/kv_cache.py for the layout)
# ---------------------------------------------------------------------------
def paged_kv_update(cache: dict, k_new, v_new, block_tables, positions):
    """Scatter one chunk of fresh K/V into paged blocks via the block table.

    cache: ``{"k","v": (NB, BS, Hkv, Dh)}`` (+ ``k_scale``/``v_scale``
    ``(NB, BS, Hkv)`` f32 for the int8 cache dtype — each written token gets
    a per-(block-slot, head) scale, so dequantization is exact up to the
    int8 rounding of the values themselves).
    k_new/v_new: (B, S, Hkv, Dh); block_tables: (B, W) int32;
    positions: (B, S) int32 absolute token positions, ``-1`` = padding
    (routed to the reserved null block 0, never owned by a live sequence).
    """
    b, s, hkv, dh = k_new.shape
    bs = cache["k"].shape[1]
    valid = positions >= 0
    safe = jnp.maximum(positions, 0)
    idx = jnp.clip(safe // bs, 0, block_tables.shape[1] - 1)
    rows = jnp.where(valid, jnp.take_along_axis(block_tables, idx, axis=1), 0)
    slots = jnp.where(valid, safe % bs, 0)
    rf, sf = rows.reshape(-1), slots.reshape(-1)
    out = dict(cache)
    for nm, x in (("k", k_new), ("v", v_new)):
        buf = cache[nm]
        if nm + "_scale" in cache:
            x32 = x.astype(jnp.float32)
            sc = jnp.maximum(jnp.max(jnp.abs(x32), axis=-1), 1e-8) / 127.0
            q = jnp.round(x32 / sc[..., None]).astype(jnp.int8)
            out[nm] = buf.at[rf, sf].set(q.reshape(-1, hkv, dh))
            out[nm + "_scale"] = cache[nm + "_scale"].at[rf, sf].set(
                sc.reshape(-1, hkv))
        else:
            out[nm] = buf.at[rf, sf].set(x.astype(buf.dtype).reshape(-1, hkv, dh))
    return out


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------
def mlp_specs(cfg: ModelConfig, ttd_block: bool, d_in: int | None = None,
              d_ff: int | None = None, prefix: str = "mlp") -> dict[str, LinearSpec]:
    d = d_in or cfg.d_model
    f = d_ff or cfg.d_ff
    if cfg.act == "gelu_mlp":
        return {
            "up": linear_spec(cfg, f"{prefix}_up", d, f, bias=cfg.norm_type == "layernorm", ttd_block=ttd_block),
            "down": linear_spec(cfg, f"{prefix}_down", f, d, bias=cfg.norm_type == "layernorm", ttd_block=ttd_block),
        }
    return {
        "gate": linear_spec(cfg, f"{prefix}_gate", d, f, ttd_block=ttd_block),
        "up": linear_spec(cfg, f"{prefix}_up", d, f, ttd_block=ttd_block),
        "down": linear_spec(cfg, f"{prefix}_down", f, d, ttd_block=ttd_block),
    }


def init_mlp(key, specs: dict[str, LinearSpec], param_dtype):
    keys = jax.random.split(key, len(specs))
    return {nm: init_linear(k, sp, param_dtype) for (nm, sp), k in zip(specs.items(), keys)}


def apply_mlp(params, x, specs: dict[str, LinearSpec], cfg: ModelConfig, compute_dtype,
              residual: jax.Array | None = None):
    # TT layers keep activations token-sharded (weights are replicated cores);
    # dense layers use Megatron column/row TP (d_ff over `model`).
    # The up/gate activation fuses into the projection's epilogue, and
    # ``residual`` (the block's skip connection) into the down projection's —
    # the paper's TTDLinear-Res fusion at the MLP-down call site.
    from ..dist.api import BATCH
    tt_down = specs["down"].kind == "tt"
    h_spec = (BATCH, "model", None) if tt_down else (None, None, "model")
    if "gate" in specs:
        act = "silu" if cfg.act == "swiglu" else "gelu"
        g = apply_linear(params["gate"], x, specs["gate"], compute_dtype,
                         activation=act)
        u = apply_linear(params["up"], x, specs["up"], compute_dtype)
        h = g * u
        h = constrain(h, *h_spec)
        return apply_linear(params["down"], h, specs["down"], compute_dtype,
                            residual=residual)
    h = apply_linear(params["up"], x, specs["up"], compute_dtype, activation="gelu")
    h = constrain(h, *h_spec)
    return apply_linear(params["down"], h, specs["down"], compute_dtype,
                        residual=residual)


# ---------------------------------------------------------------------------
# Embedding / unembedding.  Vocab is sharded over `model`; GSPMD turns the
# masked formulation below into local-gather + AllReduce instead of
# all-gathering the table (important for 163k×7168 tables).
# ---------------------------------------------------------------------------
def embed_spec(cfg: ModelConfig) -> LinearSpec | None:
    """TT spec for the embedding table, or ``None`` for the dense gather.

    TensorGPT-style vocab-axis TT: the (V, D) table is the TT's (M, N)
    weight directly (M = V, N = D), so ``out_modes`` factor the vocab and
    ``in_modes`` the model dim, and a row gather becomes the digit-indexed
    core contraction in ``kernels.dispatch.tt_embed``.
    """
    ttd = cfg.ttd
    if not (ttd.enabled and ttd.embed):
        return None
    try:
        tt = TTSpec.make(cfg.d_model, cfg.vocab_size,
                         ttd.embed_rank or ttd.rank, d=ttd.embed_d or ttd.d)
    except ValueError:
        return None  # un-factorizable vocab/width: stay dense
    return LinearSpec("tt", cfg.d_model, cfg.vocab_size, tt=tt,
                      role="embed_lookup", backend=cfg.kernel_backend)


def init_embed(key, cfg: ModelConfig, param_dtype):
    sp = embed_spec(cfg)
    if sp is not None:
        return init_tt_linear(key, sp.tt, dtype=param_dtype)
    std = 1.0 / math.sqrt(cfg.d_model)
    p = {"table": (jax.random.normal(key, (cfg.vocab_size, cfg.d_model), jnp.float32) * std).astype(param_dtype)}
    return p


def embed_lookup(params, ids, compute_dtype, cfg: ModelConfig | None = None):
    if "cores" in params:
        sp = embed_spec(cfg) if cfg is not None else None
        if sp is None:
            raise ValueError(
                "params carry a TT-compressed embedding but the config does "
                "not declare one (cfg.ttd.embed) — pass the cfg the tree was "
                "compressed for")
        backend = dispatch.resolve_backend(None, role=sp.role,
                                           preferred=sp.backend)
        rows = dispatch.tt_embed(ids, params["cores"], sp.tt, backend=backend)
        return rows.astype(compute_dtype)
    table = params["table"]
    out = jnp.take(table, ids, axis=0).astype(compute_dtype)
    return out


def unembed(x, table, compute_dtype):
    """x: (..., D) -> logits (..., V)  (tied path uses embed table)."""
    return jax.lax.dot_general(
        x.astype(compute_dtype), table.astype(compute_dtype),
        (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


# ---------------------------------------------------------------------------
# Stacking / scan helpers
# ---------------------------------------------------------------------------
def stack_init(init_fn, key, n: int):
    """vmap an init function over ``n`` layer keys -> stacked params."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def remat_wrap(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)  # "full": save nothing

"""RWKV6 "Finch" — attention-free LM with data-dependent decay
(arXiv:2404.05892).  The paper's TTD technique applies to its linear
projections (channel-mix K/V and time-mix output are the big ones).

Time-mix recurrence per head (state S ∈ R^{dk×dv}):

    S_t = diag(w_t) S_{t-1} + k_tᵀ v_t
    y_t = r_t (S_{t-1} + diag(u) k_tᵀ v_t)

with per-token per-channel decay w_t = exp(-exp(w0 + tanh(x_w W_d1) W_d2))
and token-shift ddlerp mixing (LoRA-modulated).  The recurrence itself runs
through ``kernels.dispatch.wkv_scan`` (ref | pallas-interpret | pallas):
train/prefill take the chunked-parallel wkv form (16-token chunks of batched
matmuls, S/16 scan steps — MXU work instead of a latency-bound length-S
loop; exact vs the sequential oracle in ``kernels/ref.py``); decode is the
fused one-step update.  The serving path optionally keeps the wkv state in
int8 with per-(slot, head) scale tables fused into the kernel.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..config import ModelConfig
from ..dist import constrain
from ..dist.api import BATCH
from ..kernels import dispatch
from ..kernels.ref import WKV_CHUNK, WKV_LOG_DECAY_FLOOR  # noqa: F401 (re-export)
from .modules import (
    apply_linear, apply_norm, dt, embed_lookup, init_embed, init_linear,
    init_norm, linear_spec, remat_wrap, stack_init, unembed,
)

MIX_COMPONENTS = ("w", "k", "v", "r", "g")


# ---------------------------------------------------------------------------
# Specs / init
# ---------------------------------------------------------------------------
def rwkv_specs(cfg: ModelConfig, ttd_block: bool = True):
    d = cfg.d_model
    return {
        "tm": {
            "r": linear_spec(cfg, "tm_r", d, d, ttd_block=ttd_block),
            "k": linear_spec(cfg, "tm_k", d, d, ttd_block=ttd_block),
            "v": linear_spec(cfg, "tm_v", d, d, ttd_block=ttd_block),
            "g": linear_spec(cfg, "tm_g", d, d, ttd_block=ttd_block),
            "o": linear_spec(cfg, "tm_out", d, d, ttd_block=ttd_block),
        },
        "cm": {
            "k": linear_spec(cfg, "cm_key", d, cfg.d_ff, ttd_block=ttd_block),
            "v": linear_spec(cfg, "cm_value", cfg.d_ff, d, ttd_block=ttd_block),
            "r": linear_spec(cfg, "cm_r", d, d, ttd_block=ttd_block),
        },
    }


def init_rwkv_block(key, cfg: ModelConfig, specs, param_dtype):
    d = cfg.d_model
    h = d // cfg.rwkv_head_dim
    ks = jax.random.split(key, 16)
    tm = {nm: init_linear(k, sp, param_dtype) for (nm, sp), k in zip(specs["tm"].items(), ks[:5])}
    cm = {nm: init_linear(k, sp, param_dtype) for (nm, sp), k in zip(specs["cm"].items(), ks[5:8])}
    lm, ld = cfg.rwkv_lora_mix, cfg.rwkv_lora_decay
    p = {
        "ln1": init_norm(cfg, d, param_dtype),
        "ln2": init_norm(cfg, d, param_dtype),
        "tm": tm,
        "cm": cm,
        "mu_base": jnp.full((d,), 0.5, param_dtype),
        "mu": jnp.full((5, d), 0.5, param_dtype),
        "mix_w1": (jax.random.normal(ks[8], (d, 5 * lm), jnp.float32) * 0.01).astype(param_dtype),
        "mix_w2": (jax.random.normal(ks[9], (5, lm, d), jnp.float32) * 0.01).astype(param_dtype),
        "decay_w0": jnp.full((d,), -3.0, param_dtype),
        "decay_w1": (jax.random.normal(ks[10], (d, ld), jnp.float32) * 0.01).astype(param_dtype),
        "decay_w2": (jax.random.normal(ks[11], (ld, d), jnp.float32) * 0.01).astype(param_dtype),
        "bonus_u": (jax.random.normal(ks[12], (d,), jnp.float32) * 0.1).astype(param_dtype),
        "ln_x": {"scale": jnp.ones((d,), param_dtype), "bias": jnp.zeros((d,), param_dtype)},
        "mu_cm_k": jnp.full((d,), 0.5, param_dtype),
        "mu_cm_r": jnp.full((d,), 0.5, param_dtype),
    }
    return p


def init_lm(key, cfg: ModelConfig):
    param_dtype = dt(cfg.param_dtype)
    k_e, k_b, k_h = jax.random.split(key, 3)
    specs = rwkv_specs(cfg)
    params = {
        "embed": init_embed(k_e, cfg, param_dtype),
        "blocks": stack_init(lambda k: init_rwkv_block(k, cfg, specs, param_dtype), k_b, cfg.n_layers),
        "final_norm": init_norm(cfg, cfg.d_model, param_dtype),
    }
    if not cfg.tie_embeddings:
        std = 1.0 / math.sqrt(cfg.d_model)
        params["head"] = {"w": (jax.random.normal(k_h, (cfg.d_model, cfg.vocab_size), jnp.float32) * std).astype(param_dtype)}
    return params


# ---------------------------------------------------------------------------
# Token shift + ddlerp
# ---------------------------------------------------------------------------
def _ddlerp(p, x, x_prev, compute_dtype):
    """Returns dict comp -> mixed input (B,S,D), and xx = x_prev - x."""
    xx = x_prev - x
    base = x + xx * p["mu_base"].astype(compute_dtype)
    lm = p["mix_w1"].shape[1] // 5
    a = jnp.tanh(jax.lax.dot_general(base, p["mix_w1"].astype(compute_dtype),
                                     (((2,), (0,)), ((), ()))))
    a = a.reshape(*a.shape[:-1], 5, lm)  # (B,S,5,lm)
    off = jnp.einsum("bscl,cld->cbsd", a, p["mix_w2"].astype(compute_dtype))
    mixed = {}
    for i, c in enumerate(MIX_COMPONENTS):
        mu_c = p["mu"][i].astype(compute_dtype) + off[i]
        mixed[c] = x + xx * mu_c
    return mixed


def _decay(p, x_w, compute_dtype):
    """Per-token per-channel decay w_t ∈ (0,1): exp(-exp(·))."""
    dd = jnp.tanh(x_w.astype(jnp.float32) @ p["decay_w1"].astype(jnp.float32)) @ \
        p["decay_w2"].astype(jnp.float32)
    log_w = -jnp.exp(jnp.clip(p["decay_w0"].astype(jnp.float32) + dd, -20.0, 8.0))
    return jnp.exp(log_w)  # (B,S,D) in (0,1)


def _group_norm(p, y, n_heads, eps=1e-5):
    """Per-head LayerNorm on (B,S,H,hd) flattened back to (B,S,D)."""
    b, s, h, hd = y.shape
    yf = y.astype(jnp.float32)
    mu = yf.mean(-1, keepdims=True)
    var = yf.var(-1, keepdims=True)
    yn = (yf - mu) * jax.lax.rsqrt(var + eps)
    yn = yn.reshape(b, s, h * hd)
    return yn * p["ln_x"]["scale"].astype(jnp.float32) + p["ln_x"]["bias"].astype(jnp.float32)


# ---------------------------------------------------------------------------
# Time mix.  The sequential and chunked-parallel wkv forms live in
# ``kernels/ref.py`` (``wkv_scan_sequential`` / ``wkv_chunked``) as the
# oracles behind ``dispatch.wkv_scan``; the fused Pallas kernel is
# ``kernels/scan_wkv.py``.
# ---------------------------------------------------------------------------
def _last_real(x_prev, x, mask):
    """Last *real* token of the chunk per row (padding is tail-only); rows
    with no real tokens keep ``x_prev``.  x_prev: (B,1,D); x: (B,S,D);
    mask: (B,S) bool."""
    full = jnp.concatenate([x_prev, x], axis=1)
    n_real = mask.sum(axis=1).astype(jnp.int32)
    return jnp.take_along_axis(full, n_real[:, None, None], axis=1)


def time_mix(p, specs, cfg: ModelConfig, x, x_prev, state0, compute_dtype,
             residual=None, positions=None, state_scale=None):
    """x: (B,S,D); x_prev: (B,1,D) last token of previous chunk (zeros at t=0);
    state0: (B,H,hd,hd) f32 — or int8 with per-(slot, head) ``state_scale``.
    Returns (y, last_x, new_state, new_scale-or-None).  ``residual`` (the
    block skip) fuses into the out-projection's epilogue (TTDLinear-Res).

    ``positions`` (B,S) marks padding steps ``-1`` (serving's ragged chunked
    prefill): ``dispatch.wkv_scan`` gives a padded step decay 1 and k = 0, so
    the wkv state passes through untouched, and the token-shift state keeps
    the last *real* token.  Real steps are bitwise identical to the unmasked
    (``positions=None``) path.

    The wkv recurrence scans over time, so the seq dim must be LOCAL during
    the scan; r/k/v/w are resharded seq→heads around it (batch-only
    intermediate hop, same pattern as the RG-LRU block — scanning over a
    model-sharded seq dim otherwise gathers every operand per step,
    EXPERIMENTS.md §Perf)."""
    b, s, d = x.shape
    h, hd = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    mask = None if positions is None else positions >= 0
    shifted = jnp.concatenate([x_prev, x[:, :-1]], axis=1)
    mixed = _ddlerp(p, x, shifted, compute_dtype)
    r = apply_linear(p["tm"]["r"], mixed["r"], specs["tm"]["r"], compute_dtype)
    k = apply_linear(p["tm"]["k"], mixed["k"], specs["tm"]["k"], compute_dtype)
    v = apply_linear(p["tm"]["v"], mixed["v"], specs["tm"]["v"], compute_dtype)
    g = jax.nn.silu(apply_linear(p["tm"]["g"], mixed["g"], specs["tm"]["g"], compute_dtype).astype(jnp.float32))
    w = _decay(p, mixed["w"], compute_dtype)

    def to_heads(t):
        t = constrain(t, BATCH, None, None)  # hop 1: gather seq
        t = t.reshape(b, s, h, hd)
        return constrain(t, BATCH, None, "model", None)  # hop 2: shard heads

    u = p["bonus_u"].astype(jnp.float32).reshape(h, hd)
    y, state, new_scale = dispatch.wkv_scan(
        to_heads(r), to_heads(k), to_heads(v), to_heads(w), u, state0,
        positions, state_scale=state_scale)
    y = constrain(y, BATCH, None, "model", None)
    y = _group_norm(p, y, h)  # per-head LN: local under head sharding
    y = y.astype(compute_dtype)
    y = constrain(y, BATCH, None, None)  # reverse hops for the TT out-proj
    y = constrain(y, BATCH, "model", None)
    y = y * g.astype(compute_dtype)  # gate is token-sharded; multiply after hop
    y = apply_linear(p["tm"]["o"], y, specs["tm"]["o"], compute_dtype,
                     residual=residual)
    last_x = x[:, -1:] if mask is None else _last_real(x_prev, x, mask)
    return y, last_x, state, new_scale


def channel_mix(p, specs, cfg: ModelConfig, x, x_prev, compute_dtype, mask=None):
    # relu² rides the key projection's fused epilogue; the residual can't
    # fuse into cm_value because the r-gate multiplies its output first.
    shifted = jnp.concatenate([x_prev, x[:, :-1]], axis=1)
    xx = shifted - x
    xk = x + xx * p["mu_cm_k"].astype(compute_dtype)
    xr = x + xx * p["mu_cm_r"].astype(compute_dtype)
    k = apply_linear(p["cm"]["k"], xk, specs["cm"]["k"], compute_dtype,
                     activation="relu2")
    if specs["cm"]["v"].kind == "tt":
        k = constrain(k, BATCH, "model", None)
    else:
        k = constrain(k, BATCH, None, "model")
    kv = apply_linear(p["cm"]["v"], k, specs["cm"]["v"], compute_dtype)
    rgate = jax.nn.sigmoid(apply_linear(p["cm"]["r"], xr, specs["cm"]["r"], compute_dtype).astype(jnp.float32))
    last_x = x[:, -1:] if mask is None else _last_real(x_prev, x, mask)
    return (rgate * kv.astype(jnp.float32)).astype(compute_dtype), last_x


# ---------------------------------------------------------------------------
# Blocks / model
# ---------------------------------------------------------------------------
def apply_block(p, specs, cfg: ModelConfig, x, state, compute_dtype,
                positions=None):
    """state: {"wkv": (B,H,hd,hd), "x_tm": (B,1,D), "x_cm": (B,1,D)} plus
    ``"wkv_scale"`` (B,H) f32 when the wkv state is int8."""
    mask = None if positions is None else positions >= 0
    h = apply_norm(p["ln1"], x, cfg)
    y, last_tm, wkv, wkv_scale = time_mix(
        p, specs, cfg, h, state["x_tm"], state["wkv"], compute_dtype,
        residual=x, positions=positions, state_scale=state.get("wkv_scale"))
    x = constrain(y.astype(x.dtype), BATCH, None, None)
    h = apply_norm(p["ln2"], x, cfg)
    y, last_cm = channel_mix(p, specs, cfg, h, state["x_cm"], compute_dtype,
                             mask=mask)
    x = x + y.astype(x.dtype)
    x = constrain(x, BATCH, None, None)
    new_state = {"wkv": wkv, "x_tm": last_tm, "x_cm": last_cm}
    if wkv_scale is not None:
        new_state["wkv_scale"] = wkv_scale
    return x, new_state


def init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    h, hd = cfg.d_model // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    if jnp.dtype(dtype) == jnp.int8:  # scale-table wkv state (serving only)
        return {
            "wkv": jnp.zeros((cfg.n_layers, batch, h, hd, hd), jnp.int8),
            "wkv_scale": jnp.full((cfg.n_layers, batch, h), 1e-8 / 127.0,
                                  jnp.float32),
            "x_tm": jnp.zeros((cfg.n_layers, batch, 1, cfg.d_model), jnp.float32),
            "x_cm": jnp.zeros((cfg.n_layers, batch, 1, cfg.d_model), jnp.float32),
        }
    return {
        "wkv": jnp.zeros((cfg.n_layers, batch, h, hd, hd), jnp.float32),
        "x_tm": jnp.zeros((cfg.n_layers, batch, 1, cfg.d_model), dtype),
        "x_cm": jnp.zeros((cfg.n_layers, batch, 1, cfg.d_model), dtype),
    }


def forward(params, cfg: ModelConfig, tokens, positions=None, *, remat="none",
            state=None, return_state=False, masked=False):
    """``masked=True`` turns ``positions`` into the serving liveness mask
    (``-1`` = padding step); training callers pass positions for RoPE-style
    uniformity but the recurrence treats every step as real."""
    compute_dtype = dt(cfg.compute_dtype)
    b, s = tokens.shape
    x = embed_lookup(params["embed"], tokens, compute_dtype)
    x = constrain(x, BATCH, None, None)
    specs = rwkv_specs(cfg)
    if state is None:
        state = init_state(cfg, b, compute_dtype)
    pos = positions if masked else None

    def body(carry, xs):
        layer_params, layer_state = xs
        y, new_state = apply_block(layer_params, specs, cfg, carry, layer_state,
                                   compute_dtype, positions=pos)
        return y, new_state

    f = remat_wrap(body, remat)
    x, new_state = jax.lax.scan(lambda c, p_: f(c, p_), x, (params["blocks"], state))
    x = apply_norm(params["final_norm"], x, cfg)
    if return_state:
        return x, new_state
    return x, jnp.zeros((), jnp.float32)


def head_weight(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["embed"]["table"].T
    return params["head"]["w"]


def init_cache(cfg: ModelConfig, batch: int, max_len: int, cache_dtype=jnp.bfloat16):
    del max_len  # O(1) state — the whole point for long_500k
    return init_state(cfg, batch, cache_dtype)


def decode_step(params, cfg: ModelConfig, state, tokens, pos, positions=None):
    """One-token decode: state is O(1) in sequence length."""
    del pos, positions
    x, new_state = forward(params, cfg, tokens, state=jax.tree.map(
        lambda a: a.astype(jnp.float32) if a.dtype != jnp.int32 else a, state),
        return_state=True)
    logits = unembed(x[:, -1:], head_weight(params, cfg).T, dt(cfg.compute_dtype))[:, 0]
    new_state = jax.tree.map(lambda a, b: a.astype(b.dtype), new_state, state)
    return logits, new_state


def prefill(params, cfg: ModelConfig, tokens, positions=None, cache_dtype=jnp.bfloat16,
            max_len=None):
    x, new_state = forward(params, cfg, tokens, return_state=True)
    logits = unembed(x[:, -1:], head_weight(params, cfg).T, dt(cfg.compute_dtype))[:, 0]
    ref = init_state(cfg, tokens.shape[0], cache_dtype)
    return logits, jax.tree.map(lambda a, b: a.astype(b.dtype), new_state, ref)


# ---------------------------------------------------------------------------
# Session serving path (DESIGN.md §7).  RWKV is attention-free: positions
# only carry the ragged-batch liveness convention (-1 = padding/inactive),
# which maps onto the masked wkv/token-shift updates above.  One function
# serves batched chunked prefill (S = chunk) and ragged decode (S = 1).
# ---------------------------------------------------------------------------
def init_session_state(cfg: ModelConfig, batch: int, cache_dtype=jnp.float32):
    return init_state(cfg, batch, cache_dtype)


def prefill_session_chunk(params, cfg: ModelConfig, state, tokens, positions):
    """tokens: (B,C); positions: (B,C), ``-1`` = padding.  Returns logits
    (B,C,V) f32 and the updated state.  int8 wkv state (+"wkv_scale") passes
    through to the scan kernel untouched; float leaves compute in f32."""
    st = jax.tree.map(
        lambda a: a.astype(jnp.float32)
        if jnp.issubdtype(a.dtype, jnp.floating) else a, state)
    x, new_state = forward(params, cfg, tokens, positions, state=st,
                           return_state=True, masked=True)
    logits = unembed(x, head_weight(params, cfg).T, dt(cfg.compute_dtype))
    new_state = jax.tree.map(lambda a, b: a.astype(b.dtype), new_state, state)
    return logits, new_state


def decode_session_step(params, cfg: ModelConfig, state, tokens, positions):
    """tokens: (B,1); positions: (B,), ``-1`` = inactive row."""
    logits, new_state = prefill_session_chunk(params, cfg, state, tokens,
                                              positions[:, None])
    return logits[:, 0], new_state


def specs_tree(cfg: ModelConfig):
    sp = rwkv_specs(cfg)
    block = {k: None for k in ("ln1", "ln2", "mu_base", "mu", "mix_w1", "mix_w2",
                               "decay_w0", "decay_w1", "decay_w2", "bonus_u",
                               "ln_x", "mu_cm_k", "mu_cm_r")}
    block["tm"] = dict(sp["tm"])
    block["cm"] = dict(sp["cm"])
    tree = {"embed": None, "blocks": block, "final_norm": None}
    if not cfg.tie_embeddings:
        tree["head"] = None
    return tree

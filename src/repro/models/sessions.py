"""Typed InferenceSession / StateBackend serving API (DESIGN.md §7).

Every model family serves through the same two-piece contract:

* A **state backend** — a pytree of decode state plus the pure step
  functions over it.  Three concrete layouts:

  - ``paged``     — shared K/V block pools + per-slot block tables
                    (attention families, full attention).
  - ``ring``      — per-slot K/V rings of ``window + chunk`` entries
                    (sliding-window attention; also valid for full
                    attention at ``max_len`` ring width).
  - ``recurrent`` — constant-size recurrent state (griffin: RG-LRU h/conv
                    + windowed attention rings; rwkv: wkv/token-shift).
  - ``encdec``    — paged decoder self-attention + per-slot encoder
                    cross-attention context (whisper).

* An :class:`InferenceSession` handle exposing the uniform surface the
  engine consumes::

      init_state()                                     -> state pytree
      prefill_chunk(params, state, tokens, positions)  -> (logits (B,C,V), state)
      decode_step(params, state, tokens, positions)    -> (logits (B,V),  state)

  ``tokens``/``positions`` follow one convention everywhere: rows are decode
  slots, positions are per-sequence absolute token indices, and ``-1`` marks
  padding/inactive rows, so a single fixed-shape program covers every
  schedule state (ragged batches, mixed prefill progress, idle slots).

Capabilities are **declared**, not probed: :data:`FAMILY_BACKENDS` is the
family × backend matrix, and :func:`make_session` raises a
``NotImplementedError`` naming the family when an unsupported backend is
requested (replacing the old ``hasattr(mod, "init_paged_cache")`` sniffing).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..config import ModelConfig
from . import griffin, rwkv, transformer, whisper

CACHE_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
                "float16": jnp.float16, "int8": jnp.int8}


def canonical_cache_dtype(dtype) -> str:
    """Normalize a user-facing cache dtype (str or jnp dtype) to its name."""
    if isinstance(dtype, str):
        if dtype not in CACHE_DTYPES:
            raise ValueError(f"unknown cache dtype {dtype!r}")
        return dtype
    name = jnp.dtype(dtype).name
    if name not in CACHE_DTYPES:
        raise ValueError(f"unknown cache dtype {dtype!r}")
    return name


@dataclass(frozen=True)
class SessionSpec:
    """Static geometry of one serving session.

    ``slots`` is the decode-batch width (prefill rows are slots too — an
    admitted request prefills *in its slot*, idle rows ride along at
    position ``-1``).  ``num_blocks`` defaults to full occupancy plus the
    reserved null block for block-pool backends.
    """
    slots: int
    max_len: int
    prefill_chunk: int = 32
    block_size: int = 16
    num_blocks: int | None = None
    cache_dtype: str = "float32"

    def resolved_num_blocks(self) -> int:
        from ..serve.kv_cache import blocks_for
        if self.num_blocks is not None:
            return self.num_blocks
        return 1 + self.slots * blocks_for(self.max_len, self.block_size)

    def table_width(self) -> int:
        from ..serve.kv_cache import blocks_for
        return blocks_for(self.max_len, self.block_size)


class InferenceSession:
    """Base session: cfg + spec + the uniform step surface.

    Device-side methods (``init_state`` / ``prefill_chunk`` / ``decode_step``
    / ``begin_sequence``) are pure functions of their arguments given the
    static ``cfg`` — ``serve.steps.session_step_fns`` jits them once per
    (session type, cfg, kernel backend) and reuses the trace across engines.
    Host-side capacity accounting (block tables) lives in the engine, which
    owns a ``BlockManager`` whenever :attr:`uses_blocks` is set.
    """
    backend = "?"
    #: block-pool capacity accounting applies (paged KV memory)
    uses_blocks = False
    #: requests carry encoder context written at admission (enc-dec)
    needs_encoder_ctx = False

    def __init__(self, cfg: ModelConfig, spec: SessionSpec):
        self.cfg = cfg
        self.spec = spec

    @property
    def step_key(self):
        return (type(self), self.cfg)

    def _dtype(self):
        return CACHE_DTYPES[canonical_cache_dtype(self.spec.cache_dtype)]

    # -- device-side ----------------------------------------------------------
    def init_state(self):
        raise NotImplementedError

    def prefill_chunk(self, params, state, tokens, positions):
        """tokens (B,C), positions (B,C) -> (logits (B,C,V) f32, state)."""
        raise NotImplementedError

    def decode_step(self, params, state, tokens, positions):
        """tokens (B,1), positions (B,) -> (logits (B,V) f32, state)."""
        raise NotImplementedError

    def begin_sequence(self, params, state, slot, enc_frames):
        """Write per-request context (enc-dec only) into ``state`` at ``slot``."""
        raise NotImplementedError(
            f"family {self.cfg.family!r} has no per-request context")

    # -- host-side ------------------------------------------------------------
    def with_tables(self, state, block_tables):
        """Swap the host-packed block tables into the state pytree."""
        return state


class PagedKVSession(InferenceSession):
    """Shared K/V block pools + block tables (dense/moe, full attention)."""
    backend = "paged"
    uses_blocks = True

    def init_state(self):
        sp = self.spec
        return {
            "kv": transformer.init_paged_cache(
                self.cfg, sp.resolved_num_blocks(), sp.block_size, self._dtype()),
            "block_tables": jnp.zeros((sp.slots, sp.table_width()), jnp.int32),
        }

    def prefill_chunk(self, params, state, tokens, positions):
        logits, kv = transformer.prefill_paged_chunk(
            params, self.cfg, state["kv"], tokens, state["block_tables"], positions)
        return logits, dict(state, kv=kv)

    def decode_step(self, params, state, tokens, positions):
        logits, kv = transformer.decode_step_paged(
            params, self.cfg, state["kv"], tokens, state["block_tables"], positions)
        return logits, dict(state, kv=kv)

    def with_tables(self, state, block_tables):
        return dict(state, block_tables=jnp.asarray(block_tables, jnp.int32))


class RingKVSession(InferenceSession):
    """Per-slot K/V rings (dense/moe; the sliding-window backend)."""
    backend = "ring"

    def init_state(self):
        sp = self.spec
        return {"kv": transformer.init_ring_cache(
            self.cfg, sp.slots, sp.max_len, sp.prefill_chunk, self._dtype())}

    def prefill_chunk(self, params, state, tokens, positions):
        logits, kv = transformer.prefill_ring_chunk(
            params, self.cfg, state["kv"], tokens, positions)
        return logits, {"kv": kv}

    def decode_step(self, params, state, tokens, positions):
        logits, kv = transformer.decode_step_ring(
            params, self.cfg, state["kv"], tokens, positions)
        return logits, {"kv": kv}


class GriffinSession(InferenceSession):
    """Constant-size recurrent state: RG-LRU h + conv tails + windowed
    attention rings (griffin / recurrentgemma)."""
    backend = "recurrent"

    def init_state(self):
        sp = self.spec
        return griffin.init_session_state(self.cfg, sp.slots, sp.max_len,
                                          sp.prefill_chunk, self._dtype())

    def prefill_chunk(self, params, state, tokens, positions):
        return griffin.prefill_session_chunk(params, self.cfg, state, tokens,
                                             positions)

    def decode_step(self, params, state, tokens, positions):
        return griffin.decode_session_step(params, self.cfg, state, tokens,
                                           positions)


class RwkvSession(InferenceSession):
    """Constant-size recurrent state: wkv matrices + token-shift tails."""
    backend = "recurrent"

    def init_state(self):
        return rwkv.init_session_state(self.cfg, self.spec.slots, self._dtype())

    def prefill_chunk(self, params, state, tokens, positions):
        return rwkv.prefill_session_chunk(params, self.cfg, state, tokens,
                                          positions)

    def decode_step(self, params, state, tokens, positions):
        return rwkv.decode_session_step(params, self.cfg, state, tokens,
                                        positions)


class EncDecSession(InferenceSession):
    """Paged decoder self-attention + per-slot encoder context (whisper)."""
    backend = "encdec"
    uses_blocks = True
    needs_encoder_ctx = True

    def init_state(self):
        sp = self.spec
        state = whisper.init_session_state(
            self.cfg, sp.slots, sp.resolved_num_blocks(), sp.block_size,
            self._dtype())
        state["block_tables"] = jnp.zeros((sp.slots, sp.table_width()), jnp.int32)
        return state

    def prefill_chunk(self, params, state, tokens, positions):
        logits, new = whisper.prefill_session_chunk(
            params, self.cfg, {"self": state["self"], "cross": state["cross"]},
            tokens, state["block_tables"], positions)
        return logits, dict(new, block_tables=state["block_tables"])

    def decode_step(self, params, state, tokens, positions):
        logits, new = whisper.decode_session_step(
            params, self.cfg, {"self": state["self"], "cross": state["cross"]},
            tokens, state["block_tables"], positions)
        return logits, dict(new, block_tables=state["block_tables"])

    def begin_sequence(self, params, state, slot, enc_frames):
        ctx = whisper.encode_ctx(params, self.cfg, enc_frames)  # (L,1,T,H,Dh)
        cross = {
            "k": state["cross"]["k"].at[:, slot].set(ctx["k"][:, 0]),
            "v": state["cross"]["v"].at[:, slot].set(ctx["v"][:, 0]),
        }
        return dict(state, cross=cross)

    def with_tables(self, state, block_tables):
        return dict(state, block_tables=jnp.asarray(block_tables, jnp.int32))


# ---------------------------------------------------------------------------
# Capability matrix (explicit — replaces hasattr probing) + constructor
# ---------------------------------------------------------------------------
FAMILY_BACKENDS: dict[str, tuple[str, ...]] = {
    "dense": ("paged", "ring"),
    "moe": ("paged", "ring"),
    "griffin": ("recurrent",),
    "rwkv": ("recurrent",),
    "encdec": ("encdec",),
}

#: backends whose state carries per-slot scale tables, making
#: ``cache_dtype='int8'`` lossless up to the payload's own rounding: paged
#: pools and per-slot rings quantize each written K/V entry, the recurrent
#: backends quantize the wkv/conv state through the scan kernels'
#: fused scale-table load/store (the RG-LRU carry ``h`` stays f32).
INT8_SCALED_BACKENDS = ("paged", "ring", "recurrent", "encdec")

_SESSION_TYPES: dict[tuple[str, str], type[InferenceSession]] = {
    ("dense", "paged"): PagedKVSession,
    ("moe", "paged"): PagedKVSession,
    ("dense", "ring"): RingKVSession,
    ("moe", "ring"): RingKVSession,
    ("griffin", "recurrent"): GriffinSession,
    ("rwkv", "recurrent"): RwkvSession,
    ("encdec", "encdec"): EncDecSession,
}


def default_backend(cfg: ModelConfig) -> str:
    """The family's preferred backend: block pools for full attention,
    rings for sliding windows, recurrent/encdec state otherwise."""
    if cfg.family in ("dense", "moe"):
        return "ring" if cfg.window else "paged"
    if cfg.family in ("griffin", "rwkv"):
        return "recurrent"
    if cfg.family == "encdec":
        return "encdec"
    raise ValueError(f"unknown family {cfg.family!r}")


def make_session(cfg_or_model, spec: SessionSpec | None = None, *,
                 backend: str | None = None, **spec_kw) -> InferenceSession:
    """Build the typed session for a config (or Model).

    ``backend=None`` picks :func:`default_backend`.  Unsupported
    combinations raise ``NotImplementedError`` naming the family, so an
    engine asking for the wrong layout fails loudly at construction instead
    of deep inside a jitted step.
    """
    cfg: ModelConfig = getattr(cfg_or_model, "cfg", cfg_or_model)
    if spec is None:
        spec = SessionSpec(**spec_kw)
    allowed = FAMILY_BACKENDS.get(cfg.family)
    if allowed is None:
        raise ValueError(f"unknown family {cfg.family!r}")
    backend = backend or default_backend(cfg)
    if backend not in allowed:
        raise NotImplementedError(
            f"family {cfg.family!r} ({cfg.name}) has no {backend!r} state "
            f"backend; available: {', '.join(allowed)}")
    if backend == "paged" and cfg.window:
        raise NotImplementedError(
            f"family {cfg.family!r} ({cfg.name}) uses sliding-window "
            f"attention (window={cfg.window}); the paged backend assumes "
            "full attention — use the 'ring' backend")
    if backend in ("paged", "ring") and cfg.pos_type not in ("rope", "none"):
        raise NotImplementedError(
            f"family {cfg.family!r} ({cfg.name}) has pos_type "
            f"{cfg.pos_type!r}; the {backend!r} backend supports rope|none")
    if canonical_cache_dtype(spec.cache_dtype) == "int8" \
            and backend not in INT8_SCALED_BACKENDS:
        raise NotImplementedError(
            f"cache_dtype 'int8' needs per-slot scale tables; the "
            f"{backend!r} backend stores its state unscaled (a raw int8 "
            "cast would corrupt outputs) — use a float cache dtype")
    return _SESSION_TYPES[cfg.family, backend](cfg, spec)

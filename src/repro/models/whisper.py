"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The conv/mel frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings (B, T_enc, D) to the encoder.  ``seq_len`` of
the assigned shape cells is the **decoder** length (DESIGN.md §5); learned
decoder positions are extended to ``max_seq_len`` (beyond paper scale, by
assignment).  LayerNorm + biased linears + plain GELU MLP, per the paper.
TTD applies to attn-O and MLP linears of both stacks.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from ..config import ModelConfig
from ..dist import constrain
from ..dist.api import BATCH
from ..kernels import dispatch
from .modules import (
    apply_linear, apply_mlp, apply_norm, attention_dense, dt, embed_lookup,
    flash_attention, init_embed, init_linear, init_mlp, init_norm, linear_spec,
    mlp_specs, paged_kv_update, remat_wrap, stack_init, unembed,
)
from .transformer import _ring_from_prefill


# ---------------------------------------------------------------------------
# Specs / init
# ---------------------------------------------------------------------------
def attn_specs(cfg: ModelConfig, ttd_block: bool = True):
    d, qd, kd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    return {
        "wq": linear_spec(cfg, "attn_q", d, qd, bias=True, ttd_block=ttd_block),
        "wk": linear_spec(cfg, "attn_k", d, kd, bias=False, ttd_block=ttd_block),
        "wv": linear_spec(cfg, "attn_v", d, kd, bias=True, ttd_block=ttd_block),
        "wo": linear_spec(cfg, "attn_o", qd, d, bias=True, ttd_block=ttd_block),
    }


def _init_attn(key, specs, param_dtype):
    ks = jax.random.split(key, 4)
    return {nm: init_linear(k, sp, param_dtype) for (nm, sp), k in zip(specs.items(), ks)}


def init_enc_block(key, cfg, aspecs, mspecs, param_dtype):
    ks = jax.random.split(key, 3)
    return {
        "ln1": init_norm(cfg, cfg.d_model, param_dtype),
        "attn": _init_attn(ks[0], aspecs, param_dtype),
        "ln2": init_norm(cfg, cfg.d_model, param_dtype),
        "mlp": init_mlp(ks[1], mspecs, param_dtype),
    }


def init_dec_block(key, cfg, aspecs, mspecs, param_dtype):
    ks = jax.random.split(key, 4)
    return {
        "ln1": init_norm(cfg, cfg.d_model, param_dtype),
        "attn": _init_attn(ks[0], aspecs, param_dtype),
        "ln_x": init_norm(cfg, cfg.d_model, param_dtype),
        "xattn": _init_attn(ks[1], aspecs, param_dtype),
        "ln2": init_norm(cfg, cfg.d_model, param_dtype),
        "mlp": init_mlp(ks[2], mspecs, param_dtype),
    }


def init_lm(key, cfg: ModelConfig):
    param_dtype = dt(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    aspecs = attn_specs(cfg)
    mspecs = mlp_specs(cfg, True)
    std = 0.02
    return {
        "embed": init_embed(ks[0], cfg, param_dtype),
        "dec_pos": (jax.random.normal(ks[1], (cfg.max_seq_len, cfg.d_model), jnp.float32) * std).astype(param_dtype),
        "enc_pos": (jax.random.normal(ks[2], (cfg.enc_len, cfg.d_model), jnp.float32) * std).astype(param_dtype),
        "enc_blocks": stack_init(lambda k: init_enc_block(k, cfg, aspecs, mspecs, param_dtype), ks[3], cfg.n_enc_layers),
        "dec_blocks": stack_init(lambda k: init_dec_block(k, cfg, aspecs, mspecs, param_dtype), ks[4], cfg.n_layers),
        "enc_norm": init_norm(cfg, cfg.d_model, param_dtype),
        "final_norm": init_norm(cfg, cfg.d_model, param_dtype),
    }  # output head tied to embed (whisper ties)


# ---------------------------------------------------------------------------
# Attention helpers
# ---------------------------------------------------------------------------
def _heads(cfg, t):
    b, s, _ = t.shape
    return t.reshape(b, s, cfg.n_heads, cfg.head_dim)


def _mha(params, specs, cfg, xq, xkv, *, causal, compute_dtype, cache=None, pos=None,
         q_block=1024, kv_block=1024, residual=None):
    """Generic MHA: self (xq is xkv) or cross.  Optional decode ring cache.

    ``residual`` fuses into the wo projection's epilogue (TTDLinear-Res)."""
    q = _heads(cfg, apply_linear(params["wq"], xq, specs["wq"], compute_dtype))
    if cache is not None and "k" in cache and xkv is None:
        # cross-attention decode: fixed precomputed K/V
        k, v, kpos, kmask = cache["k"], cache["v"], cache["pos"], cache["pos"] >= 0
        qpos = pos[None].astype(jnp.int32) if pos is not None else jnp.arange(q.shape[1], dtype=jnp.int32)
        o = attention_dense(q, k, v, qpos=qpos, kpos=kpos, kmask=kmask, causal=False)
        new_cache = cache
    elif cache is not None:
        # self-attention decode
        k = _heads(cfg, apply_linear(params["wk"], xkv, specs["wk"], compute_dtype))
        v = _heads(cfg, apply_linear(params["wv"], xkv, specs["wv"], compute_dtype))
        w = cache["k"].shape[1]
        slot = (pos % w).astype(jnp.int32)
        k_new = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        v_new = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
        pos_new = jax.lax.dynamic_update_slice(cache["pos"], pos[None].astype(jnp.int32), (slot,))
        o = attention_dense(q, k_new, v_new, qpos=pos[None].astype(jnp.int32),
                            kpos=pos_new, kmask=pos_new >= 0, causal=causal)
        new_cache = {"k": k_new, "v": v_new, "pos": pos_new}
    else:
        k = _heads(cfg, apply_linear(params["wk"], xkv, specs["wk"], compute_dtype))
        v = _heads(cfg, apply_linear(params["wv"], xkv, specs["wv"], compute_dtype))
        qpos = jnp.arange(q.shape[1], dtype=jnp.int32)
        kpos = jnp.arange(k.shape[1], dtype=jnp.int32)
        o = flash_attention(q, k, v, qpos=qpos, kpos=kpos, causal=causal,
                            q_block=q_block, kv_block=kv_block)
        new_cache = (k, v)
    b, s = o.shape[:2]
    o = constrain(o, BATCH, None, "model", None)
    o = o.reshape(b, s, cfg.q_dim)
    if specs["wo"].kind == "tt":
        o = constrain(o, BATCH, "model", None)
    y = apply_linear(params["wo"], o, specs["wo"], compute_dtype,
                     residual=residual)
    return y, new_cache


# ---------------------------------------------------------------------------
# Encoder / decoder stacks
# ---------------------------------------------------------------------------
def encode(params, cfg: ModelConfig, enc_frames, compute_dtype, remat="none"):
    """enc_frames: (B, T_enc, D) stub frontend output."""
    aspecs, mspecs = attn_specs(cfg), mlp_specs(cfg, True)
    t = enc_frames.shape[1]
    x = enc_frames.astype(compute_dtype) + params["enc_pos"][:t].astype(compute_dtype)
    x = constrain(x, BATCH, "model", None)

    def body(carry, p):
        h = apply_norm(p["ln1"], carry, cfg)
        a, _ = _mha(p["attn"], aspecs, cfg, h, h, causal=False,
                    compute_dtype=compute_dtype, residual=carry)
        y = a.astype(carry.dtype)
        h = apply_norm(p["ln2"], y, cfg)
        y = apply_mlp(p["mlp"], h, mspecs, cfg, compute_dtype,
                      residual=y).astype(y.dtype)
        return constrain(y, BATCH, "model", None), None

    f = remat_wrap(body, remat)
    x, _ = jax.lax.scan(lambda c, p: f(c, p), x, params["enc_blocks"])
    return apply_norm(params["enc_norm"], x, cfg)


def decode_stack(params, cfg: ModelConfig, tokens, enc_out, compute_dtype, remat="none",
                 pos_offset=0):
    aspecs, mspecs = attn_specs(cfg), mlp_specs(cfg, True)
    b, s = tokens.shape
    x = embed_lookup(params["embed"], tokens, compute_dtype)
    x = x + params["dec_pos"][pos_offset : pos_offset + s].astype(compute_dtype)
    x = constrain(x, BATCH, "model", None)

    def body(carry, p):
        h = apply_norm(p["ln1"], carry, cfg)
        a, _ = _mha(p["attn"], aspecs, cfg, h, h, causal=True, compute_dtype=compute_dtype,
                    q_block=cfg.q_block, kv_block=cfg.kv_block, residual=carry)
        y = a.astype(carry.dtype)
        h = apply_norm(p["ln_x"], y, cfg)
        a, _ = _mha(p["xattn"], aspecs, cfg, h, enc_out, causal=False,
                    compute_dtype=compute_dtype, residual=y)
        y = a.astype(y.dtype)
        h = apply_norm(p["ln2"], y, cfg)
        y = apply_mlp(p["mlp"], h, mspecs, cfg, compute_dtype,
                      residual=y).astype(y.dtype)
        return constrain(y, BATCH, "model", None), None

    f = remat_wrap(body, remat)
    x, _ = jax.lax.scan(lambda c, p: f(c, p), x, params["dec_blocks"])
    return apply_norm(params["final_norm"], x, cfg)


# ---------------------------------------------------------------------------
# Public API (matches the Model protocol in models/api.py)
# ---------------------------------------------------------------------------
def forward(params, cfg: ModelConfig, tokens, positions=None, *, remat="none",
            enc_frames=None):
    compute_dtype = dt(cfg.compute_dtype)
    if enc_frames is None:  # tolerate LM-style calls in smoke tests
        b = tokens.shape[0]
        enc_frames = jnp.zeros((b, cfg.enc_len, cfg.d_model), compute_dtype)
    enc_out = encode(params, cfg, enc_frames, compute_dtype, remat)
    x = decode_stack(params, cfg, tokens, enc_out, compute_dtype, remat)
    return x, jnp.zeros((), jnp.float32)


def head_weight(params, cfg: ModelConfig):
    return params["embed"]["table"].T  # tied


def init_cache(cfg: ModelConfig, batch: int, max_len: int, cache_dtype=jnp.bfloat16):
    return {
        "self": {
            "k": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim), cache_dtype),
            "v": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim), cache_dtype),
            "pos": jnp.full((cfg.n_layers, max_len), -1, jnp.int32),
        },
        "cross": {
            "k": jnp.zeros((cfg.n_layers, batch, cfg.enc_len, cfg.n_heads, cfg.head_dim), cache_dtype),
            "v": jnp.zeros((cfg.n_layers, batch, cfg.enc_len, cfg.n_heads, cfg.head_dim), cache_dtype),
            "pos": jnp.zeros((cfg.n_layers, cfg.enc_len), jnp.int32),
        },
    }


def prefill(params, cfg: ModelConfig, tokens, positions=None, cache_dtype=jnp.bfloat16,
            max_len=None, enc_frames=None):
    compute_dtype = dt(cfg.compute_dtype)
    b, s = tokens.shape
    max_len = max_len or s
    if enc_frames is None:
        enc_frames = jnp.zeros((b, cfg.enc_len, cfg.d_model), compute_dtype)
    enc_out = encode(params, cfg, enc_frames, compute_dtype)
    aspecs, mspecs = attn_specs(cfg), mlp_specs(cfg, True)
    x = embed_lookup(params["embed"], tokens, compute_dtype)
    x = x + params["dec_pos"][:s].astype(compute_dtype)
    x = constrain(x, BATCH, "model", None)

    def body(carry, p):
        h = apply_norm(p["ln1"], carry, cfg)
        a, kv = _mha(p["attn"], aspecs, cfg, h, h, causal=True,
                     compute_dtype=compute_dtype, residual=carry)
        y = a.astype(carry.dtype)
        h = apply_norm(p["ln_x"], y, cfg)
        a, xkv = _mha(p["xattn"], aspecs, cfg, h, enc_out, causal=False,
                      compute_dtype=compute_dtype, residual=y)
        y = a.astype(y.dtype)
        h = apply_norm(p["ln2"], y, cfg)
        y = apply_mlp(p["mlp"], h, mspecs, cfg, compute_dtype,
                      residual=y).astype(y.dtype)
        k, v = kv
        k_c, v_c, pos_c = _ring_from_prefill(k, v, s, max_len, cache_dtype)
        # cross K/V from encoder projections (recompute once here, store)
        xk = _heads(cfg, apply_linear(p["xattn"]["wk"], enc_out, aspecs["wk"], compute_dtype)).astype(cache_dtype)
        xv = _heads(cfg, apply_linear(p["xattn"]["wv"], enc_out, aspecs["wv"], compute_dtype)).astype(cache_dtype)
        cache = {"self": {"k": k_c, "v": v_c, "pos": pos_c},
                 "cross": {"k": xk, "v": xv, "pos": jnp.arange(cfg.enc_len, dtype=jnp.int32)}}
        return constrain(y, BATCH, "model", None), cache

    x, caches = jax.lax.scan(body, x, params["dec_blocks"])
    x = apply_norm(params["final_norm"], x, cfg)
    logits = unembed(x[:, -1:], params["embed"]["table"], compute_dtype)[:, 0]
    return logits, {"self": caches["self"], "cross": caches["cross"]}


def decode_step(params, cfg: ModelConfig, caches, tokens, pos, positions=None):
    compute_dtype = dt(cfg.compute_dtype)
    aspecs, mspecs = attn_specs(cfg), mlp_specs(cfg, True)
    b = tokens.shape[0]
    x = embed_lookup(params["embed"], tokens, compute_dtype)
    x = x + jax.lax.dynamic_slice(params["dec_pos"], (pos, 0), (1, cfg.d_model)).astype(compute_dtype)

    def body(carry, xs):
        p, c_self, c_cross = xs
        h = apply_norm(p["ln1"], carry, cfg)
        a, ns = _mha(p["attn"], aspecs, cfg, h, h, causal=True, compute_dtype=compute_dtype,
                     cache=c_self, pos=pos, residual=carry)
        y = a.astype(carry.dtype)
        h = apply_norm(p["ln_x"], y, cfg)
        a, _ = _mha(p["xattn"], aspecs, cfg, h, None, causal=False, compute_dtype=compute_dtype,
                    cache=c_cross, pos=pos, residual=y)
        y = a.astype(y.dtype)
        h = apply_norm(p["ln2"], y, cfg)
        y = apply_mlp(p["mlp"], h, mspecs, cfg, compute_dtype,
                      residual=y).astype(y.dtype)
        return y, ns

    x, new_self = jax.lax.scan(body, x, (params["dec_blocks"], caches["self"], caches["cross"]))
    x = apply_norm(params["final_norm"], x, cfg)
    logits = unembed(x[:, 0:1], params["embed"]["table"], compute_dtype)[:, 0]
    return logits, {"self": new_self, "cross": caches["cross"]}


# ---------------------------------------------------------------------------
# Session serving path (DESIGN.md §7): paged-KV decoder self-attention +
# per-slot encoder cross-attention context riding in the state pytree.
# The decoder's learned positions are gathered per sequence, so ragged
# batches decode in one call like every other family.
# ---------------------------------------------------------------------------
def init_session_state(cfg: ModelConfig, batch: int, num_blocks: int,
                       block_size: int, cache_dtype=jnp.float32):
    """{"self": paged K/V pools, "cross": per-slot encoder-context K/V}."""
    shape = (cfg.n_layers, num_blocks, block_size, cfg.n_kv_heads, cfg.head_dim)
    self_c = {"k": jnp.zeros(shape, cache_dtype), "v": jnp.zeros(shape, cache_dtype)}
    if cache_dtype == jnp.int8:
        self_c["k_scale"] = jnp.zeros(shape[:-1], jnp.float32)
        self_c["v_scale"] = jnp.zeros(shape[:-1], jnp.float32)
    ctx = (cfg.n_layers, batch, cfg.enc_len, cfg.n_heads, cfg.head_dim)
    return {
        "self": self_c,
        "cross": {"k": jnp.zeros(ctx, jnp.float32), "v": jnp.zeros(ctx, jnp.float32)},
    }


def encode_ctx(params, cfg: ModelConfig, enc_frames):
    """Run the encoder and project per-decoder-layer cross K/V.

    enc_frames: (B, T_enc, D) -> {"k","v"}: (n_layers, B, T_enc, H, Dh) f32.
    Computed once per admitted request and scattered into the session state
    (recompute-style preemption simply reruns this on re-admission).
    """
    compute_dtype = dt(cfg.compute_dtype)
    enc_out = encode(params, cfg, enc_frames, compute_dtype)
    aspecs = attn_specs(cfg)

    def body(_, p):
        xk = _heads(cfg, apply_linear(p["xattn"]["wk"], enc_out, aspecs["wk"], compute_dtype))
        xv = _heads(cfg, apply_linear(p["xattn"]["wv"], enc_out, aspecs["wv"], compute_dtype))
        return None, (xk.astype(jnp.float32), xv.astype(jnp.float32))

    _, (ks, vs) = jax.lax.scan(body, None, params["dec_blocks"])
    return {"k": ks, "v": vs}


def _self_attn_paged(p, aspecs, cfg: ModelConfig, x, cache, block_tables,
                     positions, compute_dtype, residual=None):
    """Decoder self-attention against the paged block pool (one layer)."""
    b, s, _ = x.shape
    q = _heads(cfg, apply_linear(p["wq"], x, aspecs["wq"], compute_dtype))
    k = _heads(cfg, apply_linear(p["wk"], x, aspecs["wk"], compute_dtype))
    v = _heads(cfg, apply_linear(p["wv"], x, aspecs["wv"], compute_dtype))
    new_cache = paged_kv_update(cache, k, v, block_tables, positions)
    if s == 1:
        o = dispatch.paged_attention(q[:, 0], new_cache, block_tables,
                                     positions[:, 0])[:, None]
    else:
        o = dispatch.prefill_attention(q, positions, cache=new_cache,
                                       block_tables=block_tables)
    o = o.astype(compute_dtype).reshape(b, s, cfg.q_dim)
    y = apply_linear(p["wo"], o, aspecs["wo"], compute_dtype, residual=residual)
    return y, new_cache


def _cross_attn_ctx(p, aspecs, cfg: ModelConfig, x, ck, cv, compute_dtype,
                    residual=None):
    """Cross-attention against the per-slot encoder context (one layer)."""
    b, s, _ = x.shape
    q = _heads(cfg, apply_linear(p["wq"], x, aspecs["wq"], compute_dtype))
    kpos = jnp.arange(ck.shape[1], dtype=jnp.int32)
    o = attention_dense(q, ck, cv, qpos=jnp.arange(s, dtype=jnp.int32),
                        kpos=kpos, causal=False)
    y = apply_linear(p["wo"], o.reshape(b, s, cfg.q_dim), aspecs["wo"],
                     compute_dtype, residual=residual)
    return y


def _session_stack(params, cfg: ModelConfig, state, x, block_tables, positions,
                   compute_dtype):
    aspecs, mspecs = attn_specs(cfg), mlp_specs(cfg, True)

    def body(carry, xs):
        p, c_self, ck, cv = xs
        h = apply_norm(p["ln1"], carry, cfg)
        a, ns = _self_attn_paged(p["attn"], aspecs, cfg, h, c_self, block_tables,
                                 positions, compute_dtype, residual=carry)
        y = a.astype(carry.dtype)
        h = apply_norm(p["ln_x"], y, cfg)
        a = _cross_attn_ctx(p["xattn"], aspecs, cfg, h, ck, cv, compute_dtype,
                            residual=y)
        y = a.astype(y.dtype)
        h = apply_norm(p["ln2"], y, cfg)
        y = apply_mlp(p["mlp"], h, mspecs, cfg, compute_dtype,
                      residual=y).astype(y.dtype)
        return y, ns

    x, new_self = jax.lax.scan(
        body, x, (params["dec_blocks"], state["self"],
                  state["cross"]["k"], state["cross"]["v"]))
    x = apply_norm(params["final_norm"], x, cfg)
    return x, {"self": new_self, "cross": state["cross"]}


def _embed_positions(params, cfg: ModelConfig, tokens, positions, compute_dtype):
    x = embed_lookup(params["embed"], tokens, compute_dtype)
    pos_emb = jnp.take(params["dec_pos"], jnp.maximum(positions, 0),
                       axis=0).astype(compute_dtype)
    return x + pos_emb


def prefill_session_chunk(params, cfg: ModelConfig, state, tokens, block_tables,
                          positions):
    """One chunk of batched prefill.  tokens: (B,C); positions: (B,C)
    (``-1`` = padding).  Returns logits (B,C,V) f32 and the new state."""
    compute_dtype = dt(cfg.compute_dtype)
    positions = positions.astype(jnp.int32)
    x = _embed_positions(params, cfg, tokens, positions, compute_dtype)
    x, new_state = _session_stack(params, cfg, state, x, block_tables,
                                  positions, compute_dtype)
    return unembed(x, params["embed"]["table"], compute_dtype), new_state


def decode_session_step(params, cfg: ModelConfig, state, tokens, block_tables,
                        positions):
    """One ragged decode tick.  tokens: (B,1); positions: (B,)."""
    compute_dtype = dt(cfg.compute_dtype)
    pos2 = positions[:, None].astype(jnp.int32)
    x = _embed_positions(params, cfg, tokens, pos2, compute_dtype)
    x, new_state = _session_stack(params, cfg, state, x, block_tables, pos2,
                                  compute_dtype)
    return unembed(x, params["embed"]["table"], compute_dtype)[:, 0], new_state


def specs_tree(cfg: ModelConfig):
    asp = attn_specs(cfg)
    msp = mlp_specs(cfg, True)
    enc = {"ln1": None, "ln2": None, "attn": dict(asp), "mlp": dict(msp)}
    dec = {"ln1": None, "ln2": None, "ln_x": None, "attn": dict(asp),
           "xattn": dict(asp), "mlp": dict(msp)}
    return {"embed": None, "dec_pos": None, "enc_pos": None,
            "enc_blocks": enc, "dec_blocks": dec, "enc_norm": None,
            "final_norm": None}

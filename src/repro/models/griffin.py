"""Griffin / RecurrentGemma: RG-LRU recurrent blocks + local (sliding-window)
MQA attention in a 2:1 pattern (arXiv:2402.19427).

RG-LRU (per channel):

    r_t = σ(W_a u_t + b_a);  i_t = σ(W_x u_t + b_x)
    log a_t = -c · softplus(Λ) · r_t          (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ u_t)

computed through ``kernels.dispatch.rglru_scan`` (ref | pallas-interpret |
pallas): the ref oracle is an ``associative_scan`` over time (parallel depth
log S); the Pallas kernel streams token tiles through on-chip state for
prefill and fuses all slots' masked one-step updates for decode.  The
diagonal recurrence is already minimal — TTD applies to the in/out
projections and the MLP (DESIGN.md §5).

Layer pattern (rec, rec, attn) is scanned in *groups* so the HLO stays one
group-body deep; remainder layers form a tail segment.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from ..config import ModelConfig
from ..dist import constrain
from ..dist.api import BATCH
from ..kernels import dispatch
from .modules import (
    apply_linear, apply_mlp, apply_norm, dt, embed_lookup, init_embed,
    init_linear, init_mlp, init_norm, linear_spec, mlp_specs, remat_wrap,
    stack_init, unembed,
)
from .transformer import (
    _ring_from_prefill, _rope_tables, attn_decode, attn_full, make_block_specs,
)
from .transformer import init_block as init_attn_block

C_RGLRU = 8.0


# ---------------------------------------------------------------------------
# Pattern / segment planning
# ---------------------------------------------------------------------------
def pattern_plan(cfg: ModelConfig) -> tuple[int, tuple[str, ...]]:
    """(n_full_groups, tail_kinds)."""
    pat = cfg.pattern or ("rec", "rec", "attn")
    n_groups = cfg.n_layers // len(pat)
    tail = cfg.n_layers - n_groups * len(pat)
    return n_groups, tuple(pat[:tail])


def _pat(cfg):
    return cfg.pattern or ("rec", "rec", "attn")


# ---------------------------------------------------------------------------
# Specs / init
# ---------------------------------------------------------------------------
def rec_specs(cfg: ModelConfig, ttd_block: bool = True):
    d, w = cfg.d_model, cfg.lru_width or cfg.d_model
    return {
        "in_x": linear_spec(cfg, "lru_in", d, w, ttd_block=ttd_block),
        "in_g": linear_spec(cfg, "lru_in_gate", d, w, ttd_block=ttd_block),
        "gate_a": linear_spec(cfg, "lru_gate_a", w, w),
        "gate_x": linear_spec(cfg, "lru_gate_x", w, w),
        "out": linear_spec(cfg, "lru_out", w, d, ttd_block=ttd_block),
        "mlp": mlp_specs(cfg, ttd_block),
    }


def init_rec_block(key, cfg: ModelConfig, specs, param_dtype):
    w = cfg.lru_width or cfg.d_model
    ks = jax.random.split(key, 8)
    return {
        "ln1": init_norm(cfg, cfg.d_model, param_dtype),
        "ln2": init_norm(cfg, cfg.d_model, param_dtype),
        "in_x": init_linear(ks[0], specs["in_x"], param_dtype),
        "in_g": init_linear(ks[1], specs["in_g"], param_dtype),
        "gate_a": init_linear(ks[2], specs["gate_a"], param_dtype),
        "gate_x": init_linear(ks[3], specs["gate_x"], param_dtype),
        "out": init_linear(ks[4], specs["out"], param_dtype),
        "conv_w": (jax.random.normal(ks[5], (cfg.conv_width, w), jnp.float32) / math.sqrt(cfg.conv_width)).astype(param_dtype),
        "conv_b": jnp.zeros((w,), param_dtype),
        "lambda": jnp.full((w,), 0.7, param_dtype),
        "mlp": init_mlp(ks[6], specs["mlp"], param_dtype),
    }


def init_lm(key, cfg: ModelConfig):
    param_dtype = dt(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    n_groups, tail = pattern_plan(cfg)
    pat = _pat(cfg)
    rspecs = rec_specs(cfg, True)
    aspecs = make_block_specs(cfg, True)

    def init_group(k):
        gks = jax.random.split(k, len(pat))
        return {
            f"l{i}_{kind}": (init_rec_block(gk, cfg, rspecs, param_dtype) if kind == "rec"
                             else init_attn_block(gk, cfg, aspecs, param_dtype))
            for i, (kind, gk) in enumerate(zip(pat, gks))
        }

    params: dict[str, Any] = {
        "embed": init_embed(ks[0], cfg, param_dtype),
        "final_norm": init_norm(cfg, cfg.d_model, param_dtype),
    }
    if n_groups:
        params["groups"] = stack_init(init_group, ks[1], n_groups)
    if tail:
        tks = jax.random.split(ks[2], len(tail))
        params["tail"] = [
            (init_rec_block(tk, cfg, rspecs, param_dtype) if kind == "rec"
             else init_attn_block(tk, cfg, aspecs, param_dtype))
            for kind, tk in zip(tail, tks)
        ]
    return params


# ---------------------------------------------------------------------------
# Conv1d (causal depthwise) + RG-LRU
# ---------------------------------------------------------------------------
def causal_conv1d(p, u, conv_state=None):
    """u: (B,S,W).  conv_state: (B, cw-1, W) previous inputs or None (t=0).
    Returns y, new_conv_state (last cw-1 inputs)."""
    cw = p["conv_w"].shape[0]
    if conv_state is None:
        u_pad = jnp.pad(u, ((0, 0), (cw - 1, 0), (0, 0)))
    else:
        u_pad = jnp.concatenate([conv_state.astype(u.dtype), u], axis=1)
    y = sum(u_pad[:, i : i + u.shape[1]] * p["conv_w"][i].astype(u.dtype) for i in range(cw))
    y = y + p["conv_b"].astype(u.dtype)
    return y, u_pad[:, -(cw - 1):]


def rg_lru(p, specs, u, h0, compute_dtype, positions=None, scan_dtype=None):
    """u: (B,S,W); h0: (B,W) f32.  Returns h (B,S,W), h_last (B,W) f32.

    Gate math runs in f32; the scan itself carries ``compute_dtype``
    operands (Griffin trains in bf16 on TPU — halves the scan's memory
    traffic, hillclimb-2 iteration 3) — override with ``scan_dtype``.

    ``positions`` (B,S) marks padding steps ``-1``: ``dispatch.rglru_scan``
    gives a padded step a = 1 and no input contribution, so the state passes
    through untouched (the serving session's ragged chunked prefill).  Real
    steps are bitwise identical to the ``positions=None`` path.
    """
    r = jax.nn.sigmoid(apply_linear(p["gate_a"], u, specs["gate_a"], compute_dtype).astype(jnp.float32))
    i = jax.nn.sigmoid(apply_linear(p["gate_x"], u, specs["gate_x"], compute_dtype).astype(jnp.float32))
    log_a = -C_RGLRU * jax.nn.softplus(p["lambda"].astype(jnp.float32)) * r
    gx = i * u.astype(jnp.float32)
    return dispatch.rglru_scan(log_a, gx, h0, positions,
                               scan_dtype=scan_dtype or u.dtype)


def rg_lru_step(p, specs, u, h0, compute_dtype):
    """One-token update. u: (B,1,W); h0: (B,W) f32.  S == 1 routes through
    the fused masked decode-step path of ``dispatch.rglru_scan``."""
    return rg_lru(p, specs, u, h0, compute_dtype, scan_dtype=jnp.float32)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------
def rec_block_seq(p, specs, cfg: ModelConfig, x, compute_dtype, h0=None, conv0=None,
                  return_state=False):
    """Full-sequence recurrent block (train/prefill).

    The TT projections keep tokens (seq) sharded over `model`; the recurrence
    needs the full sequence locally with the LRU width sharded instead.  The
    seq→width reshard goes through an intermediate batch-only sharding: XLA
    handles each hop natively, where the direct transition falls into the
    "involuntary full rematerialization" replicate-everything path
    (EXPERIMENTS.md §Perf hillclimb 2)."""
    hid = apply_norm(p["ln1"], x, cfg)
    u = apply_linear(p["in_x"], hid, specs["in_x"], compute_dtype)
    g_lin = apply_linear(p["in_g"], hid, specs["in_g"], compute_dtype)
    u = constrain(u, BATCH, None, None)  # hop 1: gather seq
    g_lin = constrain(g_lin, BATCH, None, None)
    u = constrain(u, BATCH, None, "model")  # hop 2: shard width (local slice)
    g_lin = constrain(g_lin, BATCH, None, "model")
    g = jax.nn.gelu(g_lin.astype(jnp.float32), approximate=True)
    u, conv_state = causal_conv1d(p, u, conv0)
    if h0 is None:
        h0 = jnp.zeros((x.shape[0], u.shape[-1]), jnp.float32)
    h, h_last = rg_lru(p, specs, u, h0, compute_dtype)
    y = (h.astype(compute_dtype) * g.astype(compute_dtype))
    y = constrain(y, BATCH, None, None)  # reverse hops for the TT out-proj
    y = constrain(y, BATCH, "model", None)
    # skip connection fused into the out-projection / MLP-down epilogues
    x = apply_linear(p["out"], y, specs["out"], compute_dtype,
                     residual=x).astype(x.dtype)
    x = constrain(x, BATCH, "model", None)
    hid = apply_norm(p["ln2"], x, cfg)
    x = apply_mlp(p["mlp"], hid, specs["mlp"], cfg, compute_dtype,
                  residual=x).astype(x.dtype)
    x = constrain(x, BATCH, "model", None)
    if return_state:
        return x, {"h": h_last, "conv": conv_state}
    return x


def rec_block_decode(p, specs, cfg: ModelConfig, x, state, compute_dtype):
    hid = apply_norm(p["ln1"], x, cfg)
    u = apply_linear(p["in_x"], hid, specs["in_x"], compute_dtype)
    g = jax.nn.gelu(apply_linear(p["in_g"], hid, specs["in_g"], compute_dtype).astype(jnp.float32), approximate=True)
    u, conv_state = causal_conv1d(p, u, state["conv"])
    h, h_last = rg_lru_step(p, specs, u, state["h"].astype(jnp.float32), compute_dtype)
    y = (h * g).astype(compute_dtype)
    x = apply_linear(p["out"], y, specs["out"], compute_dtype,
                     residual=x).astype(x.dtype)
    hid = apply_norm(p["ln2"], x, cfg)
    x = apply_mlp(p["mlp"], hid, specs["mlp"], cfg, compute_dtype,
                  residual=x).astype(x.dtype)
    return x, {"h": h_last, "conv": conv_state.astype(state["conv"].dtype)}


def attn_block_seq(p, specs, cfg: ModelConfig, x, rope_cs, compute_dtype,
                   return_cache=False, cache_len=0, cache_dtype=jnp.bfloat16):
    hid = apply_norm(p["ln1"], x, cfg)
    a, kv = attn_full(p, specs, cfg, hid, rope_cs, compute_dtype,
                      return_kv=return_cache, residual=x)
    x = a.astype(x.dtype)
    hid = apply_norm(p["ln2"], x, cfg)
    x = apply_mlp(p["mlp"], hid, specs.mlp_d(), cfg, compute_dtype,
                  residual=x).astype(x.dtype)
    x = constrain(x, BATCH, "model", None)
    if return_cache:
        k, v = kv
        s = x.shape[1]
        k_c, v_c, pos_c = _ring_from_prefill(k, v, s, cache_len, cache_dtype)
        return x, {"k": k_c, "v": v_c, "pos": pos_c}
    return x


def attn_block_decode(p, specs, cfg: ModelConfig, x, cache, rope_cs, pos, compute_dtype):
    hid = apply_norm(p["ln1"], x, cfg)
    a, new_cache = attn_decode(p, specs, cfg, hid, rope_cs, cache, pos,
                               compute_dtype, residual=x)
    x = a.astype(x.dtype)
    hid = apply_norm(p["ln2"], x, cfg)
    x = apply_mlp(p["mlp"], hid, specs.mlp_d(), cfg, compute_dtype,
                  residual=x).astype(x.dtype)
    return x, new_cache


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int, cache_dtype=jnp.bfloat16):
    w = cfg.lru_width or cfg.d_model
    n_groups, tail = pattern_plan(cfg)
    pat = _pat(cfg)
    win = min(cfg.window or max_len, max_len)

    def rec_state(lead):
        return {"h": jnp.zeros(lead + (batch, w), jnp.float32),
                "conv": jnp.zeros(lead + (batch, cfg.conv_width - 1, w), cache_dtype)}

    def attn_state(lead):
        return {"k": jnp.zeros(lead + (batch, win, cfg.n_kv_heads, cfg.head_dim), cache_dtype),
                "v": jnp.zeros(lead + (batch, win, cfg.n_kv_heads, cfg.head_dim), cache_dtype),
                "pos": jnp.full(lead + (win,), -1, jnp.int32)}

    out: dict[str, Any] = {"tail": [rec_state(()) if k == "rec" else attn_state(()) for k in tail]}
    if n_groups:
        out["groups"] = {
            f"l{i}_{kind}": (rec_state((n_groups,)) if kind == "rec" else attn_state((n_groups,)))
            for i, kind in enumerate(pat)
        }
    return out


# ---------------------------------------------------------------------------
# Forward / prefill / decode
# ---------------------------------------------------------------------------
def forward(params, cfg: ModelConfig, tokens, positions=None, *, remat="none"):
    compute_dtype = dt(cfg.compute_dtype)
    b, s = tokens.shape
    x = embed_lookup(params["embed"], tokens, compute_dtype) * math.sqrt(cfg.d_model)
    x = constrain(x, BATCH, "model", None)
    rope_cs = _rope_tables(cfg, positions, b, s)
    n_groups, tail = pattern_plan(cfg)
    pat = _pat(cfg)
    rspecs, aspecs = rec_specs(cfg, True), make_block_specs(cfg, True)

    def group_body(carry, gp):
        h = carry
        for i, kind in enumerate(pat):
            key = f"l{i}_{kind}"
            if kind == "rec":
                h = rec_block_seq(gp[key], rspecs, cfg, h, compute_dtype)
            else:
                h = attn_block_seq(gp[key], aspecs, cfg, h, rope_cs, compute_dtype)
        return h, None

    f = remat_wrap(lambda c, gp: group_body(c, gp), remat)
    if n_groups:
        x, _ = jax.lax.scan(lambda c, gp: f(c, gp), x, params["groups"])
    for kind, p_ in zip(tail, params.get("tail", [])):
        if kind == "rec":
            x = rec_block_seq(p_, rspecs, cfg, x, compute_dtype)
        else:
            x = attn_block_seq(p_, aspecs, cfg, x, rope_cs, compute_dtype)
    x = apply_norm(params["final_norm"], x, cfg)
    return x, jnp.zeros((), jnp.float32)


def head_weight(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["embed"]["table"].T
    return params["head"]["w"]


def decode_step(params, cfg: ModelConfig, caches, tokens, pos, positions=None):
    compute_dtype = dt(cfg.compute_dtype)
    b = tokens.shape[0]
    x = embed_lookup(params["embed"], tokens, compute_dtype) * math.sqrt(cfg.d_model)
    rope_pos = jnp.broadcast_to(pos[None], (1,)).astype(jnp.int32)
    rope_cs = _rope_tables(cfg, rope_pos, b, 1)
    n_groups, tail = pattern_plan(cfg)
    pat = _pat(cfg)
    rspecs, aspecs = rec_specs(cfg, True), make_block_specs(cfg, True)

    def group_body(carry, xs):
        h = carry
        gp, gs = xs
        new_gs = {}
        for i, kind in enumerate(pat):
            key = f"l{i}_{kind}"
            if kind == "rec":
                h, ns = rec_block_decode(gp[key], rspecs, cfg, h, gs[key], compute_dtype)
            else:
                h, ns = attn_block_decode(gp[key], aspecs, cfg, h, gs[key], rope_cs, pos, compute_dtype)
            new_gs[key] = ns
        return h, new_gs

    new_caches: dict[str, Any] = {"tail": []}
    if n_groups:
        x, new_caches["groups"] = jax.lax.scan(group_body, x, (params["groups"], caches["groups"]))
    for (kind, p_), s_ in zip(zip(tail, params.get("tail", [])), caches["tail"]):
        if kind == "rec":
            x, ns = rec_block_decode(p_, rspecs, cfg, x, s_, compute_dtype)
        else:
            x, ns = attn_block_decode(p_, aspecs, cfg, x, s_, rope_cs, pos, compute_dtype)
        new_caches["tail"].append(ns)
    x = apply_norm(params["final_norm"], x, cfg)
    logits = unembed(x[:, 0:1], head_weight(params, cfg).T, compute_dtype)[:, 0]
    return logits, new_caches


def prefill(params, cfg: ModelConfig, tokens, positions=None, cache_dtype=jnp.bfloat16,
            max_len=None):
    compute_dtype = dt(cfg.compute_dtype)
    b, s = tokens.shape
    max_len = max_len or s
    win = min(cfg.window or max_len, max_len)
    x = embed_lookup(params["embed"], tokens, compute_dtype) * math.sqrt(cfg.d_model)
    x = constrain(x, BATCH, "model", None)
    rope_cs = _rope_tables(cfg, positions, b, s)
    n_groups, tail = pattern_plan(cfg)
    pat = _pat(cfg)
    rspecs, aspecs = rec_specs(cfg, True), make_block_specs(cfg, True)

    def group_body(carry, gp):
        h = carry
        states = {}
        for i, kind in enumerate(pat):
            key = f"l{i}_{kind}"
            if kind == "rec":
                h, ns = rec_block_seq(gp[key], rspecs, cfg, h, compute_dtype, return_state=True)
                ns = {"h": ns["h"], "conv": ns["conv"].astype(cache_dtype)}
            else:
                h, ns = attn_block_seq(gp[key], aspecs, cfg, h, rope_cs, compute_dtype,
                                       return_cache=True, cache_len=win, cache_dtype=cache_dtype)
            states[key] = ns
        return h, states

    caches: dict[str, Any] = {"tail": []}
    if n_groups:
        x, caches["groups"] = jax.lax.scan(group_body, x, params["groups"])
    for kind, p_ in zip(tail, params.get("tail", [])):
        if kind == "rec":
            x, ns = rec_block_seq(p_, rspecs, cfg, x, compute_dtype, return_state=True)
            ns = {"h": ns["h"], "conv": ns["conv"].astype(cache_dtype)}
        else:
            x, ns = attn_block_seq(p_, aspecs, cfg, x, rope_cs, compute_dtype,
                                   return_cache=True, cache_len=win, cache_dtype=cache_dtype)
        caches["tail"].append(ns)
    x = apply_norm(params["final_norm"], x, cfg)
    logits = unembed(x[:, -1:], head_weight(params, cfg).T, compute_dtype)[:, 0]
    return logits, caches


# ---------------------------------------------------------------------------
# Session serving path (DESIGN.md §7): position-addressed, ragged-batch
# steps over a constant-size per-slot state — RG-LRU h + conv tails for the
# recurrent blocks, per-slot windowed K/V rings for the attention blocks.
# One function serves batched chunked prefill (S = chunk, tail-padded with
# position -1) and ragged decode (S = 1, per-sequence positions).
# ---------------------------------------------------------------------------
def init_session_state(cfg: ModelConfig, batch: int, max_len: int, chunk: int,
                       cache_dtype=jnp.float32):
    from .transformer import ring_width

    w = cfg.lru_width or cfg.d_model
    wr = ring_width(cfg, max_len, chunk)
    n_groups, tail = pattern_plan(cfg)
    pat = _pat(cfg)
    int8 = jnp.dtype(cache_dtype) == jnp.int8

    def rec_state(lead):
        # the RG-LRU carry h stays f32 (it is the recurrence accumulator);
        # int8 applies to the conv tail with a per-(slot, tap) scale table
        st = {"h": jnp.zeros(lead + (batch, w), jnp.float32),
              "conv": jnp.zeros(lead + (batch, cfg.conv_width - 1, w), cache_dtype)}
        if int8:
            st["conv_scale"] = jnp.full(lead + (batch, cfg.conv_width - 1),
                                        1e-8 / 127.0, jnp.float32)
        return st

    def attn_state(lead):
        st = {"k": jnp.zeros(lead + (batch, wr, cfg.n_kv_heads, cfg.head_dim), cache_dtype),
              "v": jnp.zeros(lead + (batch, wr, cfg.n_kv_heads, cfg.head_dim), cache_dtype),
              "pos": jnp.full(lead + (batch, wr), -1, jnp.int32)}
        if int8:
            st["k_scale"] = jnp.zeros(lead + (batch, wr, cfg.n_kv_heads), jnp.float32)
            st["v_scale"] = jnp.zeros(lead + (batch, wr, cfg.n_kv_heads), jnp.float32)
        return st

    out: dict[str, Any] = {"tail": [rec_state(()) if k == "rec" else attn_state(())
                                    for k in tail]}
    if n_groups:
        out["groups"] = {
            f"l{i}_{kind}": (rec_state((n_groups,)) if kind == "rec"
                             else attn_state((n_groups,)))
            for i, kind in enumerate(pat)
        }
    return out


def _conv_state_masked(conv0, u, mask):
    """Last ``cw-1`` *real* conv inputs per row (padding is tail-only).

    conv0: (B, cw-1, W) previous inputs; u: (B, S, W) this call's inputs;
    mask: (B, S) f32.  A row with L real tokens keeps inputs ending at its
    L-th token; L = 0 keeps ``conv0`` untouched.
    """
    full = jnp.concatenate([conv0.astype(u.dtype), u], axis=1)
    n_real = mask.sum(axis=1).astype(jnp.int32)  # (B,)
    idx = n_real[:, None] + jnp.arange(conv0.shape[1], dtype=jnp.int32)[None, :]
    return jnp.take_along_axis(full, idx[:, :, None], axis=1)


def rec_block_session(p, specs, cfg: ModelConfig, x, state, positions,
                      compute_dtype):
    """Position-addressed recurrent block: prefill chunk or decode step.

    x: (B,S,D); state: {"h": (B,W) f32, "conv": (B,cw-1,W)} plus
    ``"conv_scale"`` (B,cw-1) f32 when the conv tail is int8; positions:
    (B,S) int32 (``-1`` = padding step — the state passes through untouched,
    idle rows bitwise including the int8 payload + scale).
    """
    mask = (positions >= 0).astype(jnp.float32)
    conv_scale = state.get("conv_scale")
    conv0 = state["conv"]
    if conv_scale is not None:
        conv0 = conv0.astype(jnp.float32) * conv_scale[..., None]
    hid = apply_norm(p["ln1"], x, cfg)
    u = apply_linear(p["in_x"], hid, specs["in_x"], compute_dtype)
    g = jax.nn.gelu(apply_linear(p["in_g"], hid, specs["in_g"], compute_dtype).astype(jnp.float32), approximate=True)
    u_conv, _ = causal_conv1d(p, u, conv0)
    h, h_last = rg_lru(p, specs, u_conv, state["h"].astype(jnp.float32),
                       compute_dtype, positions=positions)
    y = (h.astype(compute_dtype) * g.astype(compute_dtype))
    y = apply_linear(p["out"], y, specs["out"], compute_dtype,
                     residual=x).astype(x.dtype)
    hid = apply_norm(p["ln2"], y, cfg)
    y = apply_mlp(p["mlp"], hid, specs["mlp"], cfg, compute_dtype,
                  residual=y).astype(y.dtype)
    new_conv = _conv_state_masked(conv0, u, mask)
    if conv_scale is None:
        return y, {"h": h_last, "conv": new_conv.astype(state["conv"].dtype)}
    nc = new_conv.astype(jnp.float32)
    sc = jnp.maximum(jnp.max(jnp.abs(nc), axis=-1), 1e-8) / 127.0
    q = jnp.round(nc / sc[..., None]).astype(jnp.int8)
    idle = mask.sum(axis=1) == 0  # (B,): keep payload + scale bitwise
    q = jnp.where(idle[:, None, None], state["conv"], q)
    sc = jnp.where(idle[:, None], conv_scale, sc)
    return y, {"h": h_last, "conv": q, "conv_scale": sc}


def attn_block_session(p, aspecs, cfg: ModelConfig, x, cache, rope_cs, positions,
                       compute_dtype):
    """Windowed attention block over a per-slot ring (ragged positions)."""
    from .transformer import attn_ring

    hid = apply_norm(p["ln1"], x, cfg)
    a, new_cache = attn_ring(p, aspecs, cfg, hid, rope_cs, cache, positions,
                             compute_dtype, residual=x)
    y = a.astype(x.dtype)
    hid = apply_norm(p["ln2"], y, cfg)
    y = apply_mlp(p["mlp"], hid, aspecs.mlp_d(), cfg, compute_dtype,
                  residual=y).astype(y.dtype)
    return y, new_cache


def _session_stack(params, cfg: ModelConfig, state, x, positions, compute_dtype):
    from .transformer import _paged_rope

    rope_cs = _paged_rope(cfg, positions)
    n_groups, tail = pattern_plan(cfg)
    pat = _pat(cfg)
    rspecs, aspecs = rec_specs(cfg, True), make_block_specs(cfg, True)

    def group_body(carry, xs):
        h = carry
        gp, gs = xs
        new_gs = {}
        for i, kind in enumerate(pat):
            key = f"l{i}_{kind}"
            if kind == "rec":
                h, ns = rec_block_session(gp[key], rspecs, cfg, h, gs[key],
                                          positions, compute_dtype)
            else:
                h, ns = attn_block_session(gp[key], aspecs, cfg, h, gs[key],
                                           rope_cs, positions, compute_dtype)
            new_gs[key] = ns
        return h, new_gs

    new_state: dict[str, Any] = {"tail": []}
    if n_groups:
        x, new_state["groups"] = jax.lax.scan(group_body, x,
                                              (params["groups"], state["groups"]))
    for (kind, p_), s_ in zip(zip(tail, params.get("tail", [])), state["tail"]):
        if kind == "rec":
            x, ns = rec_block_session(p_, rspecs, cfg, x, s_, positions,
                                      compute_dtype)
        else:
            x, ns = attn_block_session(p_, aspecs, cfg, x, s_, rope_cs,
                                       positions, compute_dtype)
        new_state["tail"].append(ns)
    return apply_norm(params["final_norm"], x, cfg), new_state


def prefill_session_chunk(params, cfg: ModelConfig, state, tokens, positions):
    """One chunk of batched prefill.  tokens: (B,C); positions: (B,C)
    absolute (``-1`` = padding).  Returns logits (B,C,V) f32 + new state."""
    compute_dtype = dt(cfg.compute_dtype)
    x = embed_lookup(params["embed"], tokens, compute_dtype) * math.sqrt(cfg.d_model)
    x, new_state = _session_stack(params, cfg, state, x,
                                  positions.astype(jnp.int32), compute_dtype)
    logits = unembed(x, head_weight(params, cfg).T, compute_dtype)
    return logits, new_state


def decode_session_step(params, cfg: ModelConfig, state, tokens, positions):
    """One ragged decode tick.  tokens: (B,1); positions: (B,) (``-1`` =
    inactive row).  Returns logits (B,V) f32 + new state."""
    logits, new_state = prefill_session_chunk(params, cfg, state, tokens,
                                              positions[:, None])
    return logits[:, 0], new_state


def specs_tree(cfg: ModelConfig):
    rsp = rec_specs(cfg, True)
    asp = make_block_specs(cfg, True)
    rec = {"ln1": None, "ln2": None, "conv_w": None, "conv_b": None, "lambda": None,
           "in_x": rsp["in_x"], "in_g": rsp["in_g"], "gate_a": rsp["gate_a"],
           "gate_x": rsp["gate_x"], "out": rsp["out"], "mlp": dict(rsp["mlp"])}
    attn = {"ln1": None, "ln2": None, "attn": dict(asp.attn), "mlp": asp.mlp_d()}
    n_groups, tail = pattern_plan(cfg)
    pat = _pat(cfg)
    tree = {"embed": None, "final_norm": None}
    if n_groups:
        tree["groups"] = {f"l{i}_{k}": (rec if k == "rec" else attn)
                          for i, k in enumerate(pat)}
    if tail:
        tree["tail"] = [rec if k == "rec" else attn for k in tail]
    if not cfg.tie_embeddings:
        tree["head"] = None
    return tree

"""Decoder-only transformer family: dense, MoE, and M-RoPE (VLM backbone).

Covers kimi-k2, mixtral, phi4-mini, tinyllama, qwen1.5-110b, granite-3,
qwen2-vl, chatglm3-6b, llama2-7b.  Layers are stacked and scanned; the layer
stack is split into *segments* so the paper's "compress only k of L blocks"
recipe keeps scan homogeneity (each segment is internally homogeneous).

Sequence-parallel convention: between blocks activations are sharded
(batch → data/pod, seq → model); inside attention/MLP the seq dim is gathered
and heads / d_ff take over the model axis (Megatron-SP, driven purely by
sharding constraints — XLA inserts the all-gather / reduce-scatter pairs).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from ..config import ModelConfig
from ..dist import constrain
from ..dist.api import BATCH
from ..kernels import dispatch
from .modules import (
    LinearSpec,
    apply_linear,
    apply_mlp,
    apply_norm,
    apply_rope,
    attention_dense,
    dt,
    embed_lookup,
    embed_spec,
    flash_attention,
    init_embed,
    init_linear,
    init_mlp,
    init_norm,
    linear_spec,
    mlp_specs,
    paged_kv_update,
    remat_wrap,
    ring_kv_update,
    rope_angles,
    stack_init,
    unembed,
)
from .moe import apply_moe, init_moe, moe_specs


# ---------------------------------------------------------------------------
# Static block specs
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class BlockSpecs:
    attn: tuple[tuple[str, LinearSpec], ...]
    mlp: tuple[tuple[str, LinearSpec], ...] | None
    moe: Any | None  # dict from moe_specs (hashable enough for our use)

    def attn_d(self):
        return dict(self.attn)

    def mlp_d(self):
        return dict(self.mlp) if self.mlp is not None else None


def make_block_specs(cfg: ModelConfig, ttd_block: bool) -> BlockSpecs:
    attn = (
        ("wq", linear_spec(cfg, "attn_q", cfg.d_model, cfg.q_dim, bias=cfg.qkv_bias, ttd_block=ttd_block)),
        ("wk", linear_spec(cfg, "attn_k", cfg.d_model, cfg.kv_dim, bias=cfg.qkv_bias, ttd_block=ttd_block)),
        ("wv", linear_spec(cfg, "attn_v", cfg.d_model, cfg.kv_dim, bias=cfg.qkv_bias, ttd_block=ttd_block)),
        ("wo", linear_spec(cfg, "attn_o", cfg.q_dim, cfg.d_model, ttd_block=ttd_block)),
    )
    if cfg.family == "moe":
        return BlockSpecs(attn, None, moe_specs(cfg, ttd_block))
    return BlockSpecs(attn, tuple(mlp_specs(cfg, ttd_block).items()), None)


def segment_plan(cfg: ModelConfig) -> list[tuple[int, bool]]:
    """[(n_layers, ttd_enabled_for_these_blocks), ...]"""
    ft = cfg.ttd.first_tt_block if cfg.ttd.enabled else cfg.n_layers
    ft = max(0, min(ft, cfg.n_layers))
    segs = []
    if ft > 0:
        segs.append((ft, False))
    if cfg.n_layers - ft > 0:
        segs.append((cfg.n_layers - ft, True))
    return segs


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def init_block(key, cfg: ModelConfig, specs: BlockSpecs, param_dtype):
    keys = jax.random.split(key, 6)
    p = {
        "ln1": init_norm(cfg, cfg.d_model, param_dtype),
        "ln2": init_norm(cfg, cfg.d_model, param_dtype),
        "attn": {nm: init_linear(k, sp, param_dtype)
                 for (nm, sp), k in zip(specs.attn, jax.random.split(keys[0], 4))},
    }
    if specs.moe is not None:
        p["moe"] = init_moe(keys[1], cfg, specs.moe, param_dtype)
    else:
        p["mlp"] = init_mlp(keys[1], specs.mlp_d(), param_dtype)
    return p


def init_lm(key, cfg: ModelConfig):
    param_dtype = dt(cfg.param_dtype)
    keys = jax.random.split(key, 4 + len(segment_plan(cfg)))
    params: dict[str, Any] = {"embed": init_embed(keys[0], cfg, param_dtype)}
    segments = []
    for i, (n, ttd_on) in enumerate(segment_plan(cfg)):
        specs = make_block_specs(cfg, ttd_on)
        segments.append(stack_init(lambda k, s=specs: init_block(k, cfg, s, param_dtype), keys[2 + i], n))
    params["segments"] = segments
    params["final_norm"] = init_norm(cfg, cfg.d_model, param_dtype)
    if not cfg.tie_embeddings:
        std = 1.0 / math.sqrt(cfg.d_model)
        params["head"] = {"w": (jax.random.normal(keys[1], (cfg.d_model, cfg.vocab_size), jnp.float32) * std).astype(param_dtype)}
    return params


# ---------------------------------------------------------------------------
# Attention (shared by train / prefill / decode)
# ---------------------------------------------------------------------------
def _qkv(params, specs: BlockSpecs, cfg: ModelConfig, x, rope_cs, compute_dtype):
    a = specs.attn_d()
    b, s, _ = x.shape
    q = apply_linear(params["attn"]["wq"], x, a["wq"], compute_dtype).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = apply_linear(params["attn"]["wk"], x, a["wk"], compute_dtype).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = apply_linear(params["attn"]["wv"], x, a["wv"], compute_dtype).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    if rope_cs is not None:
        cos, sin = rope_cs
        q = apply_rope(q, cos, sin, cfg.partial_rotary)
        k = apply_rope(k, cos, sin, cfg.partial_rotary)
    q = constrain(q, BATCH, None, "model", None)
    k = constrain(k, BATCH, None, "model", None)
    v = constrain(v, BATCH, None, "model", None)
    return q, k, v


def attn_full(params, specs, cfg: ModelConfig, x, rope_cs, compute_dtype,
              *, return_kv=False, residual=None):
    """Self-attention over the whole sequence (train / prefill).

    ``residual`` (the block's skip connection) fuses into the output
    projection's epilogue — the paper's TTDLinear-Res at the attn-out site.
    """
    b, s, _ = x.shape
    q, k, v = _qkv(params, specs, cfg, x, rope_cs, compute_dtype)
    pos = jnp.arange(s, dtype=jnp.int32)
    o = flash_attention(q, k, v, qpos=pos, kpos=pos, causal=True, window=cfg.window,
                        q_block=cfg.q_block, kv_block=cfg.kv_block)
    o = constrain(o, BATCH, None, "model", None)
    o = o.reshape(b, s, cfg.q_dim)
    if specs.attn_d()["wo"].kind == "tt":
        # SP boundary: heads→seq reshard so the TT segment stays token-sharded
        o = constrain(o, BATCH, "model", None)
    o = apply_linear(params["attn"]["wo"], o, specs.attn_d()["wo"], compute_dtype,
                     residual=residual)
    return (o, (k, v)) if return_kv else (o, None)


def attn_decode(params, specs, cfg: ModelConfig, x, rope_cs, cache, pos,
                compute_dtype, residual=None):
    """One-token decode against a (ring) KV cache.

    cache: {"k": (B, W, Hkv, Dh), "v": ..., "pos": (W,) int32, -1 = empty}.
    ``pos`` is the absolute position of the new token (scalar int32).
    """
    b, s, _ = x.shape  # s == 1
    q, k, v = _qkv(params, specs, cfg, x, rope_cs, compute_dtype)
    w = cache["k"].shape[1]
    slot = (pos % w).astype(jnp.int32)
    k_new = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
    v_new = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
    pos_new = jax.lax.dynamic_update_slice(cache["pos"], pos[None].astype(jnp.int32), (slot,))
    kmask = pos_new >= 0
    qpos = pos[None].astype(jnp.int32)
    o = attention_dense(q, k_new, v_new, qpos=qpos, kpos=pos_new, kmask=kmask,
                        causal=True, window=cfg.window)
    o = constrain(o, BATCH, None, "model", None)
    o = apply_linear(params["attn"]["wo"], o.reshape(b, s, cfg.q_dim),
                     specs.attn_d()["wo"], compute_dtype, residual=residual)
    return o, {"k": k_new, "v": v_new, "pos": pos_new}


def attn_paged(params, specs, cfg: ModelConfig, x, rope_cs, cache, block_tables,
               positions, compute_dtype, residual=None):
    """Attention against a paged KV cache (serve path; DESIGN.md §6).

    cache: one layer's ``{"k","v"[, "k_scale","v_scale"]}`` block pool;
    positions: (B, S) absolute token positions (``-1`` = padding, routed to
    the null block and masked out).  S == 1 is the decode shape and runs the
    fused Pallas kernel via ``kernels.dispatch.paged_attention``; S > 1 is a
    chunked-prefill step and runs the ragged prefill flash-attention kernel
    via ``kernels.dispatch.prefill_attention`` (both with the gather oracle
    as their ``ref`` backend).
    """
    b, s, _ = x.shape
    q, k, v = _qkv(params, specs, cfg, x, rope_cs, compute_dtype)
    new_cache = paged_kv_update(cache, k, v, block_tables, positions)
    if s == 1:
        o = dispatch.paged_attention(q[:, 0], new_cache, block_tables,
                                     positions[:, 0])[:, None]
    else:
        o = dispatch.prefill_attention(q, positions, cache=new_cache,
                                       block_tables=block_tables)
    o = constrain(o.astype(compute_dtype), BATCH, None, "model", None)
    o = apply_linear(params["attn"]["wo"], o.reshape(b, s, cfg.q_dim),
                     specs.attn_d()["wo"], compute_dtype, residual=residual)
    return o, new_cache


# ---------------------------------------------------------------------------
# Block
# ---------------------------------------------------------------------------
def apply_block(params, specs: BlockSpecs, cfg: ModelConfig, x, rope_cs,
                compute_dtype, cache=None, pos=None):
    h = apply_norm(params["ln1"], x, cfg)
    if cache is None:
        a, _ = attn_full(params, specs, cfg, h, rope_cs, compute_dtype, residual=x)
        new_cache = None
    else:
        a, new_cache = attn_decode(params, specs, cfg, h, rope_cs, cache, pos,
                                   compute_dtype, residual=x)
    x = constrain(a.astype(x.dtype), BATCH, "model", None)
    h = apply_norm(params["ln2"], x, cfg)
    if specs.moe is not None:
        # MoE combine is gated per token-expert pair — the skip connection
        # can't ride a single linear's epilogue; added after the combine.
        m, aux = apply_moe(params["moe"], h, specs.moe, cfg, compute_dtype)
        x = x + m.astype(x.dtype)
    else:
        x = apply_mlp(params["mlp"], h, specs.mlp_d(), cfg, compute_dtype,
                      residual=x).astype(x.dtype)
        aux = jnp.zeros((), jnp.float32)
    x = constrain(x, BATCH, "model", None)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Full forward (train / prefill) and decode step
# ---------------------------------------------------------------------------
def _rope_tables(cfg: ModelConfig, positions, b, s):
    if cfg.pos_type == "rope":
        if positions is None:
            positions = jnp.arange(s, dtype=jnp.int32)
        return rope_angles(positions, cfg.head_dim, cfg.rope_theta, cfg.partial_rotary)
    if cfg.pos_type == "mrope":
        if positions is None:
            p = jnp.arange(s, dtype=jnp.int32)
            positions = jnp.broadcast_to(p, (3, b, s))
        return rope_angles(positions, cfg.head_dim, cfg.rope_theta, cfg.partial_rotary,
                           mrope_sections=cfg.mrope_sections)
    return None


def forward(params, cfg: ModelConfig, tokens, positions=None, *, remat="none",
            inputs_embeds=None):
    """tokens: (B, S) int32 -> logits (B, S, V) f32, aux scalar."""
    compute_dtype = dt(cfg.compute_dtype)
    b, s = tokens.shape[:2]
    x = inputs_embeds if inputs_embeds is not None else embed_lookup(params["embed"], tokens, compute_dtype, cfg)
    x = constrain(x, BATCH, "model", None)
    rope_cs = _rope_tables(cfg, positions, b, s)
    aux_total = jnp.zeros((), jnp.float32)
    for seg_params, (n, ttd_on) in zip(params["segments"], segment_plan(cfg)):
        specs = make_block_specs(cfg, ttd_on)

        def body(carry, layer_params, specs=specs):
            y, _, aux = apply_block(layer_params, specs, cfg, carry, rope_cs, compute_dtype)
            return y, aux

        f = remat_wrap(body, remat)
        x, auxs = jax.lax.scan(lambda c, p: f(c, p), x, seg_params)
        aux_total = aux_total + auxs.sum()
    x = apply_norm(params["final_norm"], x, cfg)
    return x, aux_total


def logits_from_hidden(params, cfg: ModelConfig, x, compute_dtype=None):
    compute_dtype = compute_dtype or dt(cfg.compute_dtype)
    if cfg.tie_embeddings and "cores" in params["embed"]:
        # tied TT embedding: the unembed IS the TT linear — the cores'
        # (M, N) = (V, D) weight maps (…, D) -> (…, V) directly
        sp = embed_spec(cfg)
        if sp is None:
            raise ValueError(
                "embed params carry TT cores but cfg.ttd.embed is off")
        backend = dispatch.resolve_backend(None, role="unembed",
                                           preferred=sp.backend)
        return dispatch.tt_linear(x.astype(jnp.float32), params["embed"]["cores"],
                                  sp.tt, backend=backend, role="unembed")
    table = params["embed"]["table"] if cfg.tie_embeddings else params["head"]["w"].T
    return unembed(x, table, compute_dtype)


def head_weight(params, cfg: ModelConfig):
    """(D, V) unembedding weight (tied or separate)."""
    if cfg.tie_embeddings:
        if "cores" in params["embed"]:
            raise ValueError(
                "tied TT-compressed embedding has no dense head weight — "
                "logits go through logits_from_hidden's TT unembed path; "
                "reconstruct via core.ttd.tt_reconstruct if a dense (D, V) "
                "matrix is genuinely needed")
        return params["embed"]["table"].T
    return params["head"]["w"]


def init_cache(cfg: ModelConfig, batch: int, max_len: int, cache_dtype=jnp.bfloat16):
    """Stacked per-layer ring caches.  Ring size = window if SWA else max_len."""
    w = min(cfg.window, max_len) if cfg.window else max_len
    def one(n):
        return {
            "k": jnp.zeros((n, batch, w, cfg.n_kv_heads, cfg.head_dim), cache_dtype),
            "v": jnp.zeros((n, batch, w, cfg.n_kv_heads, cfg.head_dim), cache_dtype),
            "pos": jnp.full((n, w), -1, jnp.int32),
        }
    return [one(n) for n, _ in segment_plan(cfg)]


def decode_step(params, cfg: ModelConfig, caches, tokens, pos, positions=None):
    """tokens: (B, 1); pos: scalar int32 absolute position.
    Returns logits (B, V) f32 and updated caches."""
    compute_dtype = dt(cfg.compute_dtype)
    b = tokens.shape[0]
    x = embed_lookup(params["embed"], tokens, compute_dtype, cfg)
    x = constrain(x, BATCH, None, None)
    if positions is None:
        rope_pos = jnp.broadcast_to(pos[None], (1,)).astype(jnp.int32)
    else:
        rope_pos = positions
    rope_cs = _rope_tables(cfg, rope_pos if cfg.pos_type != "mrope" else positions, b, 1)
    new_caches = []
    for seg_params, seg_cache, (n, ttd_on) in zip(params["segments"], caches, segment_plan(cfg)):
        specs = make_block_specs(cfg, ttd_on)

        def body(carry, xs, specs=specs):
            layer_params, layer_cache = xs
            y, new_cache, _ = apply_block(layer_params, specs, cfg, carry, rope_cs,
                                          compute_dtype, cache=layer_cache, pos=pos)
            return y, new_cache

        x, new_cache = jax.lax.scan(body, x, (seg_params, seg_cache))
        new_caches.append(new_cache)
    x = apply_norm(params["final_norm"], x, cfg)
    logits = logits_from_hidden(params, cfg, x)[:, 0]
    return logits, new_caches


def prefill(params, cfg: ModelConfig, tokens, positions=None, cache_dtype=jnp.bfloat16,
            max_len: int | None = None):
    """Full-sequence prefill; returns (last-token logits, caches filled to S)."""
    compute_dtype = dt(cfg.compute_dtype)
    b, s = tokens.shape
    max_len = max_len or s
    x = embed_lookup(params["embed"], tokens, compute_dtype, cfg)
    x = constrain(x, BATCH, "model", None)
    rope_cs = _rope_tables(cfg, positions, b, s)
    caches = []
    for seg_params, (n, ttd_on) in zip(params["segments"], segment_plan(cfg)):
        specs = make_block_specs(cfg, ttd_on)

        def body(carry, layer_params, specs=specs):
            h = apply_norm(layer_params["ln1"], carry, cfg)
            a, kv = attn_full(layer_params, specs, cfg, h, rope_cs, compute_dtype,
                              return_kv=True, residual=carry)
            y = a.astype(carry.dtype)
            h2 = apply_norm(layer_params["ln2"], y, cfg)
            if specs.moe is not None:
                m, _ = apply_moe(layer_params["moe"], h2, specs.moe, cfg, compute_dtype)
                y = y + m.astype(y.dtype)
            else:
                y = apply_mlp(layer_params["mlp"], h2, specs.mlp_d(), cfg,
                              compute_dtype, residual=y).astype(y.dtype)
            y = constrain(y, BATCH, "model", None)
            k, v = kv
            w = min(cfg.window, max_len) if cfg.window else max_len
            k_c, v_c, pos_c = _ring_from_prefill(k, v, s, w, cache_dtype)
            return y, {"k": k_c, "v": v_c, "pos": pos_c}

        x, cache = jax.lax.scan(body, x, seg_params)
        caches.append(cache)
    x = apply_norm(params["final_norm"], x, cfg)
    logits = logits_from_hidden(params, cfg, x[:, -1:])[:, 0]
    return logits, caches


def _ring_from_prefill(k, v, s, w, cache_dtype):
    """Pack the last ``w`` prefilled KVs into ring layout (slot = pos % w)."""
    b, _, hkv, dh = k.shape
    if s <= w:
        pad = w - s
        k_c = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(cache_dtype)
        v_c = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(cache_dtype)
        pos_c = jnp.concatenate([jnp.arange(s, dtype=jnp.int32),
                                 jnp.full((pad,), -1, jnp.int32)])
        return k_c, v_c, pos_c
    # keep positions [s-w, s): position p lives at slot p % w
    tail_pos = jnp.arange(s - w, s, dtype=jnp.int32)  # positions kept
    slots = tail_pos % w
    k_tail = k[:, -w:].astype(cache_dtype)
    v_tail = v[:, -w:].astype(cache_dtype)
    k_c = jnp.zeros((b, w, hkv, dh), cache_dtype).at[:, slots].set(k_tail)
    v_c = jnp.zeros((b, w, hkv, dh), cache_dtype).at[:, slots].set(v_tail)
    pos_c = jnp.zeros((w,), jnp.int32).at[slots].set(tail_pos)
    return k_c, v_c, pos_c


# ---------------------------------------------------------------------------
# Paged-cache serving path (DESIGN.md §6).  Decode takes *per-sequence*
# positions — ragged batches decode in one call, unlike the ring path whose
# shared scalar ``pos`` forces the engine to group slots by position.
# ---------------------------------------------------------------------------
def init_paged_cache(cfg: ModelConfig, num_blocks: int, block_size: int,
                     cache_dtype=jnp.bfloat16):
    """Stacked per-layer paged K/V block pools (block 0 = reserved null).

    ``cache_dtype`` may be jnp.int8, in which case per-(block-slot, head)
    scale tables ride alongside the quantized values.
    """
    quantized = cache_dtype == jnp.int8

    def one(n):
        shape = (n, num_blocks, block_size, cfg.n_kv_heads, cfg.head_dim)
        c = {"k": jnp.zeros(shape, cache_dtype), "v": jnp.zeros(shape, cache_dtype)}
        if quantized:
            c["k_scale"] = jnp.zeros(shape[:-1], jnp.float32)
            c["v_scale"] = jnp.zeros(shape[:-1], jnp.float32)
        return c

    return [one(n) for n, _ in segment_plan(cfg)]


def _paged_rope(cfg: ModelConfig, positions):
    """Per-sequence rope tables; padding positions (-1) clamp to 0 (their
    outputs are masked/ignored downstream)."""
    if cfg.pos_type != "rope":
        if cfg.pos_type == "none":
            return None
        raise NotImplementedError(
            f"paged serving supports pos_type rope|none, not {cfg.pos_type!r}")
    return rope_angles(jnp.maximum(positions, 0), cfg.head_dim, cfg.rope_theta,
                       cfg.partial_rotary)


def _paged_body(params, specs, cfg, x, rope_cs, cache, block_tables, positions,
                compute_dtype):
    h = apply_norm(params["ln1"], x, cfg)
    a, new_cache = attn_paged(params, specs, cfg, h, rope_cs, cache,
                              block_tables, positions, compute_dtype, residual=x)
    x = constrain(a.astype(x.dtype), BATCH, "model", None)
    h = apply_norm(params["ln2"], x, cfg)
    if specs.moe is not None:
        m, _ = apply_moe(params["moe"], h, specs.moe, cfg, compute_dtype)
        x = x + m.astype(x.dtype)
    else:
        x = apply_mlp(params["mlp"], h, specs.mlp_d(), cfg, compute_dtype,
                      residual=x).astype(x.dtype)
    return constrain(x, BATCH, "model", None), new_cache


def _paged_stack(params, cfg: ModelConfig, caches, x, rope_cs, block_tables,
                 positions, compute_dtype):
    new_caches = []
    for seg_params, seg_cache, (n, ttd_on) in zip(params["segments"], caches,
                                                  segment_plan(cfg)):
        specs = make_block_specs(cfg, ttd_on)

        def body(carry, xs, specs=specs):
            layer_params, layer_cache = xs
            return _paged_body(layer_params, specs, cfg, carry, rope_cs,
                               layer_cache, block_tables, positions,
                               compute_dtype)

        x, new_cache = jax.lax.scan(body, x, (seg_params, seg_cache))
        new_caches.append(new_cache)
    return apply_norm(params["final_norm"], x, cfg), new_caches


def decode_step_paged(params, cfg: ModelConfig, caches, tokens, block_tables,
                      positions):
    """One decode tick against the paged cache.

    tokens: (B, 1); positions: (B,) absolute position of each new token
    (``-1`` = inactive row: its write lands in the null block and its logits
    are garbage the scheduler ignores).  Returns logits (B, V) f32 and the
    updated caches.
    """
    compute_dtype = dt(cfg.compute_dtype)
    x = embed_lookup(params["embed"], tokens, compute_dtype, cfg)
    x = constrain(x, BATCH, None, None)
    pos2 = positions[:, None].astype(jnp.int32)
    rope_cs = _paged_rope(cfg, pos2)
    x, new_caches = _paged_stack(params, cfg, caches, x, rope_cs, block_tables,
                                 pos2, compute_dtype)
    return logits_from_hidden(params, cfg, x)[:, 0], new_caches


def prefill_paged_chunk(params, cfg: ModelConfig, caches, tokens, block_tables,
                        positions):
    """One chunk of batched prefill, writing K/V straight into paged blocks.

    tokens: (B, C); positions: (B, C) absolute positions (``-1`` = padding —
    prompts shorter than the chunk grid).  Earlier chunks must already be
    written (the serve driver ``serve.steps.chunked_prefill`` guarantees
    order).  Returns logits (B, C, V) f32 for *every* chunk position — the
    driver picks each sequence's last-real-token row — and updated caches.
    """
    compute_dtype = dt(cfg.compute_dtype)
    x = embed_lookup(params["embed"], tokens, compute_dtype, cfg)
    x = constrain(x, BATCH, "model", None)
    rope_cs = _paged_rope(cfg, positions.astype(jnp.int32))
    x, new_caches = _paged_stack(params, cfg, caches, x, rope_cs, block_tables,
                                 positions.astype(jnp.int32), compute_dtype)
    return logits_from_hidden(params, cfg, x), new_caches


# ---------------------------------------------------------------------------
# Ring-cache serving path (session API, DESIGN.md §7).  Same position
# conventions as the paged path — per-sequence absolute positions, ``-1`` =
# inactive — but K/V live in per-slot rings of ``window + chunk`` entries
# instead of shared block pools.  This is the constant-footprint backend for
# sliding-window attention (paged block pools cannot express SWA eviction).
# ---------------------------------------------------------------------------
def ring_width(cfg: ModelConfig, max_len: int, chunk: int) -> int:
    """Per-slot ring entries: the visible window plus the widest same-call
    write (so a chunk write never evicts a key still visible to its own
    earliest query); full attention keeps the whole ``max_len``."""
    if cfg.window:
        return min(cfg.window, max_len) + chunk
    return max_len


def init_ring_cache(cfg: ModelConfig, batch: int, max_len: int, chunk: int,
                    cache_dtype=jnp.bfloat16):
    """Stacked per-layer per-slot ring caches with per-sequence positions.
    int8 rings carry per-(entry, head) f32 scale tables next to the payload
    (``ring_kv_update`` writes them; the prefill kernel dequantizes
    in-tile)."""
    wr = ring_width(cfg, max_len, chunk)
    int8 = jnp.dtype(cache_dtype) == jnp.int8

    def one(n):
        c = {
            "k": jnp.zeros((n, batch, wr, cfg.n_kv_heads, cfg.head_dim), cache_dtype),
            "v": jnp.zeros((n, batch, wr, cfg.n_kv_heads, cfg.head_dim), cache_dtype),
            "pos": jnp.full((n, batch, wr), -1, jnp.int32),
        }
        if int8:
            c["k_scale"] = jnp.zeros((n, batch, wr, cfg.n_kv_heads), jnp.float32)
            c["v_scale"] = jnp.zeros((n, batch, wr, cfg.n_kv_heads), jnp.float32)
        return c

    return [one(n) for n, _ in segment_plan(cfg)]


def attn_ring(params, specs, cfg: ModelConfig, x, rope_cs, cache, positions,
              compute_dtype, residual=None):
    """Attention against a per-slot ring cache (write-then-attend).

    cache: one layer's ``{"k","v","pos"}`` rings; positions: (B, S) absolute
    positions (``-1`` = padding, write dropped / query masked).  Both chunked
    prefill (S > 1) and ragged decode (S == 1) run the streaming kernel via
    ``kernels.dispatch.prefill_attention`` (ring layout: the ring's ``pos``
    array is the kernel's ``kpos`` operand).
    """
    b, s, _ = x.shape
    q, k, v = _qkv(params, specs, cfg, x, rope_cs, compute_dtype)
    new_cache = ring_kv_update(cache, k, v, positions)
    o = dispatch.prefill_attention(q, positions, k=new_cache["k"],
                                   v=new_cache["v"], kpos=new_cache["pos"],
                                   window=cfg.window,
                                   k_scale=new_cache.get("k_scale"),
                                   v_scale=new_cache.get("v_scale"))
    o = constrain(o.astype(compute_dtype), BATCH, None, "model", None)
    o = apply_linear(params["attn"]["wo"], o.reshape(b, s, cfg.q_dim),
                     specs.attn_d()["wo"], compute_dtype, residual=residual)
    return o, new_cache


def _ring_stack(params, cfg: ModelConfig, caches, x, rope_cs, positions,
                compute_dtype):
    new_caches = []
    for seg_params, seg_cache, (n, ttd_on) in zip(params["segments"], caches,
                                                  segment_plan(cfg)):
        specs = make_block_specs(cfg, ttd_on)

        def body(carry, xs, specs=specs):
            layer_params, layer_cache = xs
            h = apply_norm(layer_params["ln1"], carry, cfg)
            a, new_cache = attn_ring(layer_params, specs, cfg, h, rope_cs,
                                     layer_cache, positions, compute_dtype,
                                     residual=carry)
            y = constrain(a.astype(carry.dtype), BATCH, "model", None)
            h = apply_norm(layer_params["ln2"], y, cfg)
            if specs.moe is not None:
                m, _ = apply_moe(layer_params["moe"], h, specs.moe, cfg, compute_dtype)
                y = y + m.astype(y.dtype)
            else:
                y = apply_mlp(layer_params["mlp"], h, specs.mlp_d(), cfg,
                              compute_dtype, residual=y).astype(y.dtype)
            return constrain(y, BATCH, "model", None), new_cache

        x, new_cache = jax.lax.scan(body, x, (seg_params, seg_cache))
        new_caches.append(new_cache)
    return apply_norm(params["final_norm"], x, cfg), new_caches


def prefill_ring_chunk(params, cfg: ModelConfig, caches, tokens, positions):
    """One chunk of batched prefill into per-slot rings.

    tokens: (B, C); positions: (B, C) absolute (``-1`` = padding).  Returns
    logits (B, C, V) f32 for every chunk position and the updated caches.
    """
    compute_dtype = dt(cfg.compute_dtype)
    x = embed_lookup(params["embed"], tokens, compute_dtype, cfg)
    x = constrain(x, BATCH, "model", None)
    rope_cs = _paged_rope(cfg, positions.astype(jnp.int32))
    x, new_caches = _ring_stack(params, cfg, caches, x, rope_cs,
                                positions.astype(jnp.int32), compute_dtype)
    return logits_from_hidden(params, cfg, x), new_caches


def decode_step_ring(params, cfg: ModelConfig, caches, tokens, positions):
    """One ragged decode tick against per-slot rings.

    tokens: (B, 1); positions: (B,) absolute position of each new token
    (``-1`` = inactive row).  Returns logits (B, V) f32 and updated caches.
    """
    compute_dtype = dt(cfg.compute_dtype)
    x = embed_lookup(params["embed"], tokens, compute_dtype, cfg)
    x = constrain(x, BATCH, None, None)
    pos2 = positions[:, None].astype(jnp.int32)
    rope_cs = _paged_rope(cfg, pos2)
    x, new_caches = _ring_stack(params, cfg, caches, x, rope_cs, pos2,
                                compute_dtype)
    return logits_from_hidden(params, cfg, x)[:, 0], new_caches


# ---------------------------------------------------------------------------
# Specs tree (mirrors init_lm params structure; used by core.compress)
# ---------------------------------------------------------------------------
def specs_tree(cfg: ModelConfig):
    segs = []
    for n, ttd_on in segment_plan(cfg):
        sp = make_block_specs(cfg, ttd_on)
        seg = {"ln1": None, "ln2": None, "attn": {nm: s for nm, s in sp.attn}}
        if sp.moe is not None:
            seg["moe"] = {"router": sp.moe["router"],
                          "experts": dict(sp.moe["expert"])}
        else:
            seg["mlp"] = sp.mlp_d()
        segs.append(seg)
    tree = {"embed": embed_spec(cfg), "segments": segs, "final_norm": None}
    if not cfg.tie_embeddings:
        tree["head"] = None
    return tree

"""Uniform Model protocol over all families.

Two surfaces live here (DESIGN.md §5/§7):

* :class:`Model` — the functional train/eval protocol (``init`` /
  ``forward`` / ``head_weight``) plus the *single-sequence* ``init_cache`` /
  ``prefill`` / ``decode_step`` path.  The latter is the one-request-at-a-
  time reference that the serving fuzz suite checks the engine against.
* The typed serving surface — :class:`~repro.models.sessions.InferenceSession`
  state backends built by :func:`repro.models.sessions.make_session` — is
  what ``serve.engine.Engine`` consumes.  Paged/ring/recurrent capability is
  declared per family there; it is **not** probed off this protocol anymore.

``batch`` convention:
  {"tokens": (B,S) int32}                              LM families
  {"tokens": ..., "positions": (3,B,S) int32}          M-RoPE (qwen2-vl)
  {"tokens": ..., "enc_frames": (B,T_enc,D)}           enc-dec (whisper)
Decode batches carry tokens of shape (B,1) plus scalar ``pos``.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..config import ModelConfig
from . import griffin, rwkv, transformer, whisper


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[[jax.Array], Any]
    forward: Callable[..., tuple[jax.Array, jax.Array]]  # (params, batch, remat) -> (hidden, aux)
    head_weight: Callable[[Any], jax.Array]  # (params) -> (D, V)
    init_cache: Callable[..., Any]
    prefill: Callable[..., tuple[jax.Array, Any]]
    decode_step: Callable[..., tuple[jax.Array, Any]]


def _lm_adapter(mod, cfg: ModelConfig) -> Model:
    def forward(params, batch, remat="none"):
        return mod.forward(params, cfg, batch["tokens"],
                           positions=batch.get("positions"), remat=remat)

    def prefill_fn(params, batch, cache_dtype=jnp.bfloat16, max_len=None):
        return mod.prefill(params, cfg, batch["tokens"],
                           positions=batch.get("positions"),
                           cache_dtype=cache_dtype, max_len=max_len)

    def decode_fn(params, cache, batch, pos):
        return mod.decode_step(params, cfg, cache, batch["tokens"], pos,
                               positions=batch.get("positions"))

    return Model(
        cfg=cfg,
        init=lambda key: mod.init_lm(key, cfg),
        forward=forward,
        head_weight=lambda params: mod.head_weight(params, cfg),
        init_cache=lambda batch, max_len, dtype=jnp.bfloat16: mod.init_cache(cfg, batch, max_len, dtype),
        prefill=prefill_fn,
        decode_step=decode_fn,
    )


def _whisper_adapter(cfg: ModelConfig) -> Model:
    def forward(params, batch, remat="none"):
        return whisper.forward(params, cfg, batch["tokens"], remat=remat,
                               enc_frames=batch.get("enc_frames"))

    def prefill_fn(params, batch, cache_dtype=jnp.bfloat16, max_len=None):
        return whisper.prefill(params, cfg, batch["tokens"], cache_dtype=cache_dtype,
                               max_len=max_len, enc_frames=batch.get("enc_frames"))

    def decode_fn(params, cache, batch, pos):
        return whisper.decode_step(params, cfg, cache, batch["tokens"], pos)

    return Model(
        cfg=cfg,
        init=lambda key: whisper.init_lm(key, cfg),
        forward=forward,
        head_weight=lambda params: whisper.head_weight(params, cfg),
        init_cache=lambda batch, max_len, dtype=jnp.bfloat16: whisper.init_cache(cfg, batch, max_len, dtype),
        prefill=prefill_fn,
        decode_step=decode_fn,
    )


def build_model(cfg: ModelConfig) -> Model:
    """Canonical Model constructor (train/eval + single-sequence reference)."""
    fam = cfg.family
    if fam in ("dense", "moe"):
        return _lm_adapter(transformer, cfg)
    if fam == "rwkv":
        return _lm_adapter(rwkv, cfg)
    if fam == "griffin":
        return _lm_adapter(griffin, cfg)
    if fam == "encdec":
        return _whisper_adapter(cfg)
    raise ValueError(f"unknown family {fam}")


def get_model(cfg: ModelConfig) -> Model:
    """Deprecated alias of :func:`build_model`.

    Serving callers that used to probe ``model.init_paged_cache`` should go
    through :func:`repro.models.sessions.make_session`, which declares each
    family's state-backend capabilities explicitly.
    """
    warnings.warn("get_model() is deprecated: use build_model() (train/eval) "
                  "or models.sessions.make_session() (serving)",
                  DeprecationWarning, stacklevel=2)
    return build_model(cfg)

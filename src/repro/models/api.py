"""Uniform Model protocol over all families.

``batch`` convention:
  {"tokens": (B,S) int32}                              LM families
  {"tokens": ..., "positions": (3,B,S) int32}          M-RoPE (qwen2-vl)
  {"tokens": ..., "enc_frames": (B,T_enc,D)}           enc-dec (whisper)
Decode batches carry tokens of shape (B,1) plus scalar ``pos``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..config import ModelConfig
from . import griffin, rwkv, transformer, whisper


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[[jax.Array], Any]
    forward: Callable[..., tuple[jax.Array, jax.Array]]  # (params, batch, remat) -> (hidden, aux)
    head_weight: Callable[[Any], jax.Array]  # (params) -> (D, V)
    init_cache: Callable[..., Any]
    prefill: Callable[..., tuple[jax.Array, Any]]
    decode_step: Callable[..., tuple[jax.Array, Any]]
    # paged-KV serving path (DESIGN.md §6) — attention families only; None
    # for the stateful recurrences (griffin/rwkv) and enc-dec, whose ring /
    # state caches are already O(1) per token.
    init_paged_cache: Callable[..., Any] | None = None
    prefill_paged_chunk: Callable[..., tuple[jax.Array, Any]] | None = None
    decode_step_paged: Callable[..., tuple[jax.Array, Any]] | None = None


def _lm_adapter(mod, cfg: ModelConfig) -> Model:
    def forward(params, batch, remat="none"):
        return mod.forward(params, cfg, batch["tokens"],
                           positions=batch.get("positions"), remat=remat)

    def prefill_fn(params, batch, cache_dtype=jnp.bfloat16, max_len=None):
        return mod.prefill(params, cfg, batch["tokens"],
                           positions=batch.get("positions"),
                           cache_dtype=cache_dtype, max_len=max_len)

    def decode_fn(params, cache, batch, pos):
        return mod.decode_step(params, cfg, cache, batch["tokens"], pos,
                               positions=batch.get("positions"))

    paged = {}
    if hasattr(mod, "init_paged_cache"):
        paged = dict(
            init_paged_cache=lambda num_blocks, block_size, dtype=jnp.bfloat16:
                mod.init_paged_cache(cfg, num_blocks, block_size, dtype),
            prefill_paged_chunk=lambda params, caches, batch, bt, positions:
                mod.prefill_paged_chunk(params, cfg, caches, batch["tokens"],
                                        bt, positions),
            decode_step_paged=lambda params, caches, batch, bt, positions:
                mod.decode_step_paged(params, cfg, caches, batch["tokens"],
                                      bt, positions),
        )
    return Model(
        cfg=cfg,
        init=lambda key: mod.init_lm(key, cfg),
        forward=forward,
        head_weight=lambda params: mod.head_weight(params, cfg),
        init_cache=lambda batch, max_len, dtype=jnp.bfloat16: mod.init_cache(cfg, batch, max_len, dtype),
        prefill=prefill_fn,
        decode_step=decode_fn,
        **paged,
    )


def _whisper_adapter(cfg: ModelConfig) -> Model:
    def forward(params, batch, remat="none"):
        return whisper.forward(params, cfg, batch["tokens"], remat=remat,
                               enc_frames=batch.get("enc_frames"))

    def prefill_fn(params, batch, cache_dtype=jnp.bfloat16, max_len=None):
        return whisper.prefill(params, cfg, batch["tokens"], cache_dtype=cache_dtype,
                               max_len=max_len, enc_frames=batch.get("enc_frames"))

    def decode_fn(params, cache, batch, pos):
        return whisper.decode_step(params, cfg, cache, batch["tokens"], pos)

    return Model(
        cfg=cfg,
        init=lambda key: whisper.init_lm(key, cfg),
        forward=forward,
        head_weight=lambda params: whisper.head_weight(params, cfg),
        init_cache=lambda batch, max_len, dtype=jnp.bfloat16: whisper.init_cache(cfg, batch, max_len, dtype),
        prefill=prefill_fn,
        decode_step=decode_fn,
    )


def get_model(cfg: ModelConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe"):
        return _lm_adapter(transformer, cfg)
    if fam == "rwkv":
        return _lm_adapter(rwkv, cfg)
    if fam == "griffin":
        return _lm_adapter(griffin, cfg)
    if fam == "encdec":
        return _whisper_adapter(cfg)
    raise ValueError(f"unknown family {fam}")

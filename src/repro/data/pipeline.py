"""Deterministic, resumable, shardable data pipeline.

Two sources:
  * ``SyntheticLM`` — a seeded Markov-ish token generator with enough
    structure to be learnable (bigram transition table), used by tests,
    examples, and the e2e train driver.  No external data gates.
  * ``PackedDocs``  — packs variable-length documents (any iterator of token
    lists) into fixed (B, S) training batches with loss masks.

Determinism/resume: every batch is a pure function of (seed, step), so
restoring a checkpoint at step k reproduces the exact stream — the trainer
stores only the step counter (checkpoint/ relies on this).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "synthetic"  # synthetic | packed


class SyntheticLM:
    """Learnable synthetic LM stream: tokens follow a fixed random bigram
    table with temperature, so cross-entropy has a known floor well below
    log(V) — training curves show real learning."""

    def __init__(self, cfg: DataConfig, branching: int = 4):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed ^ 0x5EED)
        v = cfg.vocab_size
        # each token can transition to `branching` successors
        self.next_tokens = rng.integers(0, v, size=(v, branching))

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed << 20) ^ step)
        b, s = cfg.global_batch, cfg.seq_len
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab_size, size=b)
        choices = rng.integers(0, self.next_tokens.shape[1], size=(b, s))
        for t in range(s):
            toks[:, t + 1] = self.next_tokens[toks[:, t], choices[:, t]]
        return {
            "tokens": toks[:, :-1],
            "targets": toks[:, 1:].astype(np.int32),
            "loss_mask": np.ones((b, s), np.float32),
        }


class PackedDocs:
    """Greedy packing of documents into fixed-length rows.

    Documents are delimited by ``eos``; loss_mask zeros out padding.  The
    packer is driven by a seeded generator so it's restartable from a step
    index (documents are re-derived, not stored)."""

    def __init__(self, cfg: DataConfig, doc_sampler=None, eos: int = 0):
        self.cfg = cfg
        self.eos = eos
        self._sampler = doc_sampler or self._default_sampler

    def _default_sampler(self, rng: np.random.Generator) -> np.ndarray:
        n = int(rng.integers(8, self.cfg.seq_len // 2 + 8))
        return rng.integers(1, self.cfg.vocab_size, size=n).astype(np.int32)

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed << 20) ^ (step * 2 + 1))
        b, s = cfg.global_batch, cfg.seq_len
        toks = np.zeros((b, s + 1), np.int32)
        mask = np.zeros((b, s), np.float32)
        for i in range(b):
            fill = 0
            while fill < s + 1:
                doc = self._sampler(rng)
                take = min(len(doc), s + 1 - fill)
                toks[i, fill:fill + take] = doc[:take]
                fill += take
                if fill < s + 1:
                    toks[i, fill] = self.eos
                    fill += 1
            mask[i] = 1.0
        return {
            "tokens": toks[:, :-1],
            "targets": toks[:, 1:].astype(np.int32),
            "loss_mask": mask,
        }


def make_source(cfg: DataConfig):
    return SyntheticLM(cfg) if cfg.kind == "synthetic" else PackedDocs(cfg)


def make_batches(cfg: DataConfig, start_step: int = 0) -> Iterator[dict]:
    """Infinite stream resuming at ``start_step`` (checkpoint-resume path)."""
    src = make_source(cfg)
    step = start_step
    while True:
        yield src.batch(step)
        step += 1

from .pipeline import DataConfig, SyntheticLM, make_batches  # noqa: F401

"""Configuration system: model / TTD / quant / parallelism / train / serve.

Everything is a frozen dataclass so configs are hashable static arguments to
jitted step builders.  Architecture files in ``repro/configs`` construct
``ModelConfig`` instances; launchers layer ``RunConfig`` on top.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Mapping


# ---------------------------------------------------------------------------
# Paper technique configs
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TTLayerOverride:
    """Explicit per-role factorization (paper Table I rows)."""

    in_modes: tuple[int, ...]
    out_modes: tuple[int, ...]
    rank: int = 16


@dataclass(frozen=True)
class TTDConfig:
    """Which linear roles get TT-compressed and how (paper §II.D, Table I).

    The paper's recipe: compress attn output + all MLP linears, keep Q/K/V
    dense; d=4, rank=16.  ``overrides`` pins exact factorizations per role.
    """

    enabled: bool = False
    rank: int = 16
    d: int = 4
    roles: tuple[str, ...] = (
        "attn_o",
        "mlp_gate",
        "mlp_up",
        "mlp_down",
        "expert_gate",
        "expert_up",
        "expert_down",
        "cm_key",
        "cm_value",
        "tm_out",
        "lru_in",
        "lru_out",
    )
    overrides: tuple[tuple[str, TTLayerOverride], ...] = ()
    # fraction of blocks compressed, from the end (paper: 15/28 and 19/32,
    # chosen blocks are TT'd, the rest stay dense/quant-only)
    first_tt_block: int = 0  # blocks [first_tt_block, n_layers) are TT'd
    # TensorGPT-style TT compression of the embedding table: the (V, D)
    # table is treated as the TT's (M, N) weight with the vocab on the
    # output axis, so a row gather becomes a digit-indexed core contraction
    embed: bool = False
    embed_rank: int = 0  # 0 -> use `rank`
    embed_d: int = 0  # 0 -> use `d`

    def override_for(self, role: str) -> TTLayerOverride | None:
        return dict(self.overrides).get(role)


@dataclass(frozen=True)
class QuantConfig:
    """INT4 weight-only quantization (paper: Wt INT4 / Act FP16)."""

    enabled: bool = False
    bits: int = 4
    group_size: int = 128


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | rwkv | griffin | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    d_ff_expert: int = 0
    moe_impl: str = "ep"  # "ep" (sort + all_to_all expert parallel) | "dense"
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- attention / positions ---
    rope_theta: float = 10000.0
    window: int = 0  # sliding-window size, 0 = full attention
    qkv_bias: bool = False
    pos_type: str = "rope"  # rope | mrope | learned | none
    mrope_sections: tuple[int, ...] = (16, 24, 24)
    partial_rotary: float = 1.0
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "swiglu"  # swiglu | geglu | gelu_mlp
    tie_embeddings: bool = False
    max_seq_len: int = 32768

    # --- griffin (RG-LRU) ---
    lru_width: int = 0
    conv_width: int = 4
    pattern: tuple[str, ...] = ()  # e.g. ("rec", "rec", "attn")

    # --- rwkv ---
    rwkv_head_dim: int = 64
    rwkv_lora_decay: int = 64
    rwkv_lora_mix: int = 32

    # --- encoder-decoder ---
    n_enc_layers: int = 0
    enc_len: int = 1500

    # --- compression (the paper's technique) ---
    ttd: TTDConfig = field(default_factory=TTDConfig)
    quant: QuantConfig = field(default_factory=QuantConfig)

    # --- numerics ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # --- kernel execution backend (repro.kernels.dispatch) ---
    # "auto" -> Pallas kernels on TPU, pure-JAX reference elsewhere; the
    # REPRO_KERNEL_BACKEND env var (and per-role REPRO_KERNEL_BACKEND_<ROLE>
    # vars) override this at trace time.
    kernel_backend: str = "auto"  # auto | ref | pallas-interpret | pallas

    # --- attention blocking (pure-JAX flash) ---
    q_block: int = 1024
    kv_block: int = 1024

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def config_to_dict(cfg: ModelConfig) -> dict:
    """JSON-serializable form of a ``ModelConfig`` (checkpoint manifests)."""
    return dataclasses.asdict(cfg)


def config_from_dict(d: Mapping[str, Any]) -> ModelConfig:
    """Inverse of :func:`config_to_dict`, tolerant of a JSON round trip
    (tuples come back as lists)."""
    d = dict(d)
    ttd = d.pop("ttd", None)
    quant = d.pop("quant", None)
    if isinstance(ttd, Mapping):
        t = dict(ttd)
        t["roles"] = tuple(t.get("roles", ()))
        t["overrides"] = tuple(
            (role, ov if isinstance(ov, TTLayerOverride) else TTLayerOverride(
                in_modes=tuple(ov["in_modes"]),
                out_modes=tuple(ov["out_modes"]),
                rank=ov.get("rank", 16)))
            for role, ov in (tuple(pair) for pair in t.get("overrides", ())))
        ttd = TTDConfig(**t)
    if isinstance(quant, Mapping):
        quant = QuantConfig(**quant)
    for k in ("mrope_sections", "pattern"):
        if d.get(k) is not None:
            d[k] = tuple(d[k])
    return ModelConfig(**d, ttd=ttd or TTDConfig(), quant=quant or QuantConfig())


# ---------------------------------------------------------------------------
# Parallelism / run configs
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class MeshConfig:
    """Logical mesh.  ``data`` composes with ``pod`` for DP; ``model`` is the
    TP/EP/SP axis.  FSDP (ZeRO-3 param sharding) uses ``data`` within a pod."""

    data: int = 16
    model: int = 16
    pods: int = 1
    fsdp: bool = True  # shard params/optstate over the data axis too

    @property
    def axis_names(self) -> tuple[str, ...]:
        return ("pod", "data", "model") if self.pods > 1 else ("data", "model")

    @property
    def shape(self) -> tuple[int, ...]:
        return (
            (self.pods, self.data, self.model)
            if self.pods > 1
            else (self.data, self.model)
        )

    @property
    def n_devices(self) -> int:
        return self.pods * self.data * self.model


@dataclass(frozen=True)
class TrainConfig:
    global_batch: int = 256
    seq_len: int = 4096
    microbatches: int = 1  # gradient accumulation steps inside train_step
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    optimizer: str = "adamw"  # adamw | adafactor
    remat: str = "full"  # full | dots | none
    grad_compression: str = "none"  # none | int8 (cross-pod hop)
    seed: int = 0


@dataclass(frozen=True)
class ServeConfig:
    batch: int = 128
    max_seq_len: int = 32768
    prefill_chunk: int = 0  # 0 = single-shot prefill
    cache_dtype: str = "bfloat16"
    greedy: bool = True
    temperature: float = 1.0


@dataclass(frozen=True)
class ShapeCell:
    """One assigned (shape) cell: what the dry-run lowers."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPE_CELLS: tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", "train", 4096, 256),
    ShapeCell("prefill_32k", "prefill", 32768, 32),
    ShapeCell("decode_32k", "decode", 32768, 128),
    ShapeCell("long_500k", "decode", 524288, 1),
)


def shape_cell(name: str) -> ShapeCell:
    for c in SHAPE_CELLS:
        if c.name == name:
            return c
    raise KeyError(name)

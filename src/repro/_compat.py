"""Backfills for newer-JAX mesh APIs on jax 0.4.x.

The codebase is written against the current jax API surface
(``jax.set_mesh``, ``jax.shard_map``, ``jax.sharding.AxisType``,
``jax.make_mesh(..., axis_types=...)``).  The pinned container toolchain
ships jax 0.4.37, where these live elsewhere or don't exist yet.  This
module installs equivalents *only when missing* (every patch is gated on a
hasattr/signature probe, so on a current jax it is a no-op) and is imported
from ``repro/__init__.py`` so any ``import repro.*`` makes the shims
available before user code touches a mesh.

It also owns the active-mesh stack that backs ``repro.dist.api``:
``set_mesh`` pushes here, ``active_mesh()`` reads here (falling back to the
classic ``with mesh:`` resource env and, on new jax, the abstract mesh).
"""
from __future__ import annotations

import enum
import inspect
import threading

import jax

_state = threading.local()


def _stack() -> list:
    if not hasattr(_state, "meshes"):
        _state.meshes = []
    return _state.meshes


def active_mesh():
    """The mesh made current by ``jax.set_mesh`` (or ``with mesh:``), else None."""
    st = _stack()
    if st:
        return st[-1]
    try:  # classic pjit resource env (`with mesh:`)
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:  # noqa: BLE001 - internal layout differs across versions
        pass
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:
        try:
            m = get_abstract()
            if m is not None and getattr(m, "axis_names", ()):
                return m
        except Exception:  # noqa: BLE001
            pass
    return None


def manual_axis_names() -> set:
    """Axis names currently bound as manual/mapped (inside shard_map et al.)."""
    try:
        from jax._src.core import get_axis_env

        return set(get_axis_env().axis_sizes)
    except Exception:  # noqa: BLE001
        return set()


class _SetMesh:
    """Context manager mimicking ``jax.set_mesh``: tracks the mesh for
    :func:`active_mesh` and enters the legacy resource-env context so
    PartitionSpec-based APIs resolve too."""

    def __init__(self, mesh):
        self.mesh = mesh

    def __enter__(self):
        _stack().append(self.mesh)
        self.mesh.__enter__()
        return self.mesh

    def __exit__(self, *exc):
        self.mesh.__exit__(*exc)
        _stack().pop()
        return False


def _install():
    # Newer jax defaults to the partitionable threefry implementation, whose
    # values are invariant to output sharding; 0.4.x defaults to the legacy
    # scheme, which makes sharded-vs-single-device init diverge.  Align with
    # the target semantics.
    try:
        jax.config.update("jax_threefry_partitionable", True)
    except Exception:  # noqa: BLE001
        pass

    # jax.sharding.AxisType (Auto / Explicit / Manual)
    if not hasattr(jax.sharding, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType

    # jax.make_mesh(..., axis_types=...)
    try:
        has_axis_types = "axis_types" in inspect.signature(jax.make_mesh).parameters
    except (TypeError, ValueError):
        has_axis_types = True
    if not has_axis_types:
        _orig_make_mesh = jax.make_mesh

        def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
            del axis_types  # 0.4.x meshes are implicitly Auto
            return _orig_make_mesh(axis_shapes, axis_names, devices=devices)

        jax.make_mesh = make_mesh

    # jax.set_mesh
    if not hasattr(jax, "set_mesh"):
        jax.set_mesh = _SetMesh

    # jax.shard_map (top-level, check_vma spelling)
    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        def shard_map(f, mesh=None, in_specs=None, out_specs=None,
                      check_vma=None, check_rep=None, **kw):
            if check_rep is None:
                check_rep = True if check_vma is None else check_vma
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_rep, **kw)

        jax.shard_map = shard_map


_install()

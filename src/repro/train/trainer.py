"""Fault-tolerant training loop.

1000+-node posture (mechanisms implemented + unit-tested here, exercised at
single-process scale in this container):

  * **checkpoint/restart**: async sharded checkpoints every N steps; on any
    step failure the trainer restores the last committed checkpoint and
    replays — data is a pure function of (seed, step) so replay is exact.
  * **straggler mitigation**: a step-time watchdog tracks a rolling median;
    steps slower than ``straggler_factor``× median fire a callback (logs by
    default; a cluster deployment would trigger hot-spare swap / re-shard —
    the elastic restore path in checkpoint/store.py is the re-shard half).
  * **preemption**: ``request_stop()`` (wired to SIGTERM by the launcher)
    finishes the current step, force-saves, and exits cleanly.
  * **elastic scaling**: restore accepts a different mesh than the one that
    saved (see tests/test_checkpoint.py::test_elastic_reshard).
"""
from __future__ import annotations

import logging
import statistics
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable

import jax
import numpy as np

from ..checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from ..config import TrainConfig
from ..data.pipeline import DataConfig, make_source
from ..obs import resolve_observer
from .step import TrainState

log = logging.getLogger("repro.trainer")


@dataclass
class TrainerReport:
    steps_done: int = 0
    restarts: int = 0
    straggler_events: list[int] = field(default_factory=list)
    losses: list[float] = field(default_factory=list)
    step_times: list[float] = field(default_factory=list)


class Trainer:
    def __init__(
        self,
        train_step: Callable[[TrainState, dict], tuple[TrainState, dict]],
        state: TrainState,
        data_cfg: DataConfig,
        *,
        ckpt_dir: str | Path | None = None,
        ckpt_every: int = 50,
        max_retries: int = 3,
        straggler_factor: float = 3.0,
        on_straggler: Callable[[int, float, float], None] | None = None,
        state_shardings=None,
        obs=None,
    ):
        self.train_step = train_step
        self.state = state
        self.data = make_source(data_cfg)
        self.ckpt = AsyncCheckpointer(ckpt_dir, every=ckpt_every) if ckpt_dir else None
        self.max_retries = max_retries
        self.straggler_factor = straggler_factor
        self.on_straggler = on_straggler or self._log_straggler
        self.state_shardings = state_shardings
        self.report = TrainerReport()
        self._stop = False
        # observability: same registry contract as the serving engine
        # (DESIGN.md §9); obs=None -> env default, False -> force off
        self.obs = resolve_observer(obs)
        if self.obs is not None:
            reg = self.obs.registry
            self._h_step = reg.histogram("train_step_seconds")
            self._g_tps = reg.gauge("train_tokens_per_second")
            self._c_steps = reg.counter("train_steps_total")
            self._c_restarts = reg.counter("train_restarts_total")

    # -- fault-tolerance hooks ------------------------------------------------
    def request_stop(self):
        self._stop = True

    def _log_straggler(self, step: int, dt: float, median: float):
        log.warning("straggler: step %d took %.3fs (median %.3fs)", step, dt, median)

    def _restore_latest(self) -> bool:
        if self.ckpt is None:
            return False
        self.ckpt.wait()
        step = latest_step(self.ckpt.ckpt_dir)
        if step is None:
            return False
        self.state, _ = restore_checkpoint(
            self.ckpt.ckpt_dir, step, self.state, shardings=self.state_shardings)
        log.warning("restored checkpoint at step %d", step)
        return True

    # -- main loop ------------------------------------------------------------
    def current_step(self) -> int:
        return int(jax.device_get(self.state.step))

    def run(self, num_steps: int, log_every: int = 10,
            fault_injector: Callable[[int], None] | None = None) -> TrainerReport:
        retries = 0
        while self.report.steps_done < num_steps and not self._stop:
            step = self.current_step()
            batch = {k: jax.numpy.asarray(v) for k, v in self.data.batch(step).items()}
            # monotonic clock: wall-time steps must not corrupt step timing
            t0 = time.perf_counter()
            try:
                if fault_injector is not None:
                    fault_injector(step)
                new_state, metrics = self.train_step(self.state, batch)
                loss = float(jax.device_get(metrics["loss"]))
                if not np.isfinite(loss):
                    raise FloatingPointError(f"non-finite loss at step {step}: {loss}")
                self.state = new_state
                retries = 0
            except Exception as e:  # noqa: BLE001 — any step failure
                retries += 1
                self.report.restarts += 1
                if self.obs is not None:
                    self._c_restarts.inc()
                log.warning("step %d failed (%r); restore+retry %d/%d",
                            step, e, retries, self.max_retries)
                if retries > self.max_retries:
                    raise
                if not self._restore_latest():
                    log.warning("no checkpoint to restore; retrying same step")
                continue

            dt = time.perf_counter() - t0
            self.report.step_times.append(dt)
            self.report.losses.append(loss)
            self.report.steps_done += 1
            if self.obs is not None:
                self._c_steps.inc()
                self._h_step.observe(dt)
                toks = getattr(batch.get("tokens"), "size", 0)
                if toks and dt > 0:
                    self._g_tps.set(toks / dt)
            if len(self.report.step_times) >= 5:
                med = statistics.median(self.report.step_times[-50:])
                if dt > self.straggler_factor * med:
                    self.report.straggler_events.append(step)
                    self.on_straggler(step, dt, med)
            if self.ckpt is not None:
                self.ckpt.maybe_save(step + 1, self.state)
            if log_every and self.report.steps_done % log_every == 0:
                log.info("step %d loss %.4f (%.2fs)", step, loss, dt)
        if self.ckpt is not None:
            self.ckpt.maybe_save(self.current_step(), self.state, force=True)
            self.ckpt.wait()
        return self.report

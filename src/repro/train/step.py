"""Jitted training step builder: loss -> grads (with microbatch accumulation)
-> clip -> optimizer, all under GSPMD sharding."""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..config import ModelConfig, TrainConfig
from ..dist.api import batch_axes
from ..dist.sharding import param_pspecs
from ..models.api import Model
from ..optim import apply_optimizer, init_optimizer, opt_state_pspecs, warmup_cosine
from .losses import chunked_cross_entropy


@dataclass
class TrainState:
    params: Any
    opt: Any
    step: jax.Array


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt, s.step), None),
    lambda _, c: TrainState(*c),
)


def batch_pspec(mesh, extra_dims: int = 1):
    baxes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    return P(baxes, *([None] * extra_dims))


def loss_fn(model: Model, params, batch, train_cfg: TrainConfig):
    hidden, aux = model.forward(params, batch, remat=train_cfg.remat)
    head = model.head_weight(params)
    loss, metrics = chunked_cross_entropy(hidden, head, batch["targets"],
                                          batch["loss_mask"])
    return loss + aux, {**metrics, "aux": aux}


def _grads_one(model, params, batch, train_cfg):
    (loss, metrics), grads = jax.value_and_grad(
        partial(loss_fn, model), has_aux=True)(params, batch, train_cfg)
    return loss, metrics, grads


def build_train_step(model: Model, train_cfg: TrainConfig):
    """Returns ``train_step(state, batch) -> (state, metrics)`` (to be jitted
    by the caller with explicit shardings)."""
    schedule = warmup_cosine(train_cfg.lr, train_cfg.warmup_steps, train_cfg.total_steps)

    def train_step(state: TrainState, batch):
        mb = train_cfg.microbatches
        if mb <= 1:
            loss, metrics, grads = _grads_one(model, state.params, batch, train_cfg)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(mb, b // mb, *x.shape[1:])

            mbatches = jax.tree.map(split, batch)

            def body(carry, mb_batch):
                loss, metrics, grads = _grads_one(model, state.params, mb_batch, train_cfg)
                acc_loss, acc_grads = carry
                acc_grads = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                         acc_grads, grads)
                return (acc_loss + loss, acc_grads), metrics

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (loss, grads), ms = jax.lax.scan(body, (jnp.zeros(()), zero), mbatches)
            loss = loss / mb
            grads = jax.tree.map(lambda g: g / mb, grads)
            metrics = jax.tree.map(lambda x: x[-1], ms)

        lr = schedule(state.step)
        new_params, new_opt, opt_metrics = apply_optimizer(
            state.opt, state.params, grads, lr,
            weight_decay=train_cfg.weight_decay, grad_clip=train_cfg.grad_clip)
        metrics = {**metrics, **opt_metrics, "loss": loss, "lr": lr}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step


def state_pspecs(model: Model, train_cfg: TrainConfig, mesh, fsdp: bool = True):
    """PartitionSpec tree for TrainState (params + optimizer state + step)."""
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = param_pspecs(shapes, mesh, fsdp)
    opt_specs = opt_state_pspecs(train_cfg.optimizer, pspecs, shapes)
    return TrainState(params=pspecs, opt=opt_specs, step=P())


def init_train_state(model: Model, train_cfg: TrainConfig, key, mesh=None,
                     fsdp: bool = True) -> TrainState:
    """Initialize (optionally sharded) training state."""
    def make():
        params = model.init(key)
        opt = init_optimizer(train_cfg.optimizer, params)
        return TrainState(params, opt, jnp.zeros((), jnp.int32))

    if mesh is None:
        return make()
    specs = state_pspecs(model, train_cfg, mesh, fsdp)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                             is_leaf=lambda x: isinstance(x, P))
    with jax.set_mesh(mesh):
        return jax.jit(make, out_shardings=shardings)()

"""Losses.  The cross-entropy is chunked over the sequence so full
(B, S, V) logits are never materialized — at vocab 163840 × 1M tokens the
full tensor would be ~0.7 TB f32; chunking keeps the live slice at
(B, chunk, V_shard) per device."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..dist import constrain
from ..dist.api import BATCH


def _ce_chunk(hidden, head_w, targets, mask, z_coef):
    logits = jax.lax.dot_general(
        hidden.astype(jnp.bfloat16), head_w.astype(jnp.bfloat16),
        (((2,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    logits = constrain(logits, BATCH, None, "model")
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ce = (lse - gold) * mask
    z = jnp.square(lse) * mask
    return ce.sum(), z.sum() * z_coef


def chunked_cross_entropy(hidden, head_w, targets, mask, *, chunk: int = 512,
                          z_coef: float = 0.0):
    """hidden (B,S,D), head_w (D,V), targets (B,S) int32, mask (B,S).
    Returns (mean_ce + z_loss, metrics)."""
    b, s, d = hidden.shape
    mask = mask.astype(jnp.float32)
    chunk = min(chunk, s)
    if s % chunk:
        chunk = s  # fall back for odd smoke shapes
    nc = s // chunk

    if nc == 1:
        ce_sum, z_sum = _ce_chunk(hidden, head_w, targets, mask, z_coef)
    else:
        hs = hidden.reshape(b, nc, chunk, d).swapaxes(0, 1)
        ts = targets.reshape(b, nc, chunk).swapaxes(0, 1)
        ms = mask.reshape(b, nc, chunk).swapaxes(0, 1)

        # checkpoint: logits are recomputed in backward rather than stacked
        # across chunks (which would materialize the full (B,S,V) tensor)
        @jax.checkpoint
        def body(carry, xs):
            h, t, m = xs
            ce, z = _ce_chunk(h, head_w, t, m, z_coef)
            return (carry[0] + ce, carry[1] + z), None

        (ce_sum, z_sum), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (hs, ts, ms))

    denom = jnp.maximum(mask.sum(), 1.0)
    loss = ce_sum / denom + z_sum / denom
    return loss, {"ce": ce_sum / denom, "tokens": denom}

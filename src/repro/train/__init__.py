from .losses import chunked_cross_entropy  # noqa: F401
from .step import TrainState, build_train_step, init_train_state  # noqa: F401

"""Analyzer core: file index, findings schema, suppressions, baseline.

The analyzer is a plain ``ast`` walk — no imports of the code under
analysis, no jax tracing — so it runs in milliseconds and can lint a tree
that would not even import (a half-registered kernel, a missing oracle).

Two file populations:

* **targets** — the files findings are reported on (CLI paths, default:
  ``src``/``benchmarks``/``examples``/``tests`` under the repo root);
* **anchors** — files some rules need for cross-file context even when
  they are not targets (``kernels/dispatch.py`` for the role registry,
  ``obs/trace.py`` for ``EVENT_FIELDS``).  Anchors never produce findings
  unless they are also targets.

Suppressions are inline comments on the offending line or the line above::

    x = time.time()  # analyze: allow[wall-clock] informational stamp only

The token inside ``[...]`` is a rule family (``wall-clock``), a finding
code (``CLK001``), or ``*``.  Bulk grandfathering goes in the baseline
file (``.analyze-baseline.json`` at the repo root): a list of
``{"rule": ..., "path": ...}`` entries, ``path`` fnmatch-style, plus an
optional ``"message"`` prefix — ``--strict`` fails only on findings not
matched by either mechanism.
"""
from __future__ import annotations

import ast
import dataclasses
import fnmatch
import json
import re
from pathlib import Path

SKIP_DIRS = {"__pycache__", ".git", ".venv", "build", "dist", "node_modules",
             "analyze_fixtures"}

_ALLOW_RE = re.compile(r"#\s*analyze:\s*allow\[([A-Za-z0-9_*,\- ]+)\]")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation, anchored to a source location."""

    rule: str      # finding code, e.g. "SYNC001"
    family: str    # rule family, e.g. "host-sync" (the --rule / allow[] key)
    path: str      # repo-relative posix path
    line: int
    col: int
    message: str
    hint: str = ""

    def render(self, with_hint: bool = True) -> str:
        out = f"{self.path}:{self.line}:{self.col}: {self.rule} " \
              f"[{self.family}] {self.message}"
        if with_hint and self.hint:
            out += f"\n    hint: {self.hint}"
        return out

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class AnalyzeConfig:
    """Knobs shared by every rule (CLI flags map onto these)."""

    vmem_budget_bytes: int = 12 * 1024 * 1024  # matches kernels' own budget


class SourceFile:
    """One parsed python file: text, AST (with parent links), suppressions."""

    def __init__(self, root: Path, path: Path):
        self.abspath = path
        try:
            self.rel = path.relative_to(root).as_posix()
        except ValueError:  # explicit path outside the root
            self.rel = path.as_posix()
        self.text = path.read_text()
        self.lines = self.text.splitlines()
        self.parse_error: SyntaxError | None = None
        try:
            self.tree: ast.Module | None = ast.parse(self.text)
        except SyntaxError as e:
            self.tree = None
            self.parse_error = e
        if self.tree is not None:
            for node in ast.walk(self.tree):
                for child in ast.iter_child_nodes(node):
                    child._analyze_parent = node  # type: ignore[attr-defined]
        # line -> set of allow tokens ("family", "CODE", or "*")
        self.allow: dict[int, set[str]] = {}
        for i, line in enumerate(self.lines, start=1):
            m = _ALLOW_RE.search(line)
            if m:
                toks = {t.strip() for t in m.group(1).split(",") if t.strip()}
                self.allow[i] = toks

    def allowed(self, line: int, rule: str, family: str) -> bool:
        for ln in (line, line - 1):
            toks = self.allow.get(ln)
            if toks and ({rule, family, "*"} & toks):
                return True
        return False


def parent(node: ast.AST) -> ast.AST | None:
    return getattr(node, "_analyze_parent", None)


def enclosing_function(node: ast.AST):
    """Nearest enclosing FunctionDef/AsyncFunctionDef/Lambda, or None."""
    cur = parent(node)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            return cur
        cur = parent(cur)
    return None


def dotted_name(node: ast.AST) -> str:
    """'a.b.c' for nested Attribute/Name chains, '' when not a plain chain."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


class RepoIndex:
    """Parsed target + anchor files for one analysis run."""

    # cross-file context some rules need even on single-file runs
    ANCHOR_GLOBS = (
        "src/repro/kernels/*.py",
        "src/repro/obs/trace.py",
    )

    def __init__(self, root: Path, paths: list[Path]):
        self.root = Path(root).resolve()
        self.files: dict[str, SourceFile] = {}
        self.anchors: dict[str, SourceFile] = {}
        for p in paths:
            for f in _walk(p):
                sf = SourceFile(self.root, f)
                self.files[sf.rel] = sf
        for pattern in self.ANCHOR_GLOBS:
            for f in sorted(self.root.glob(pattern)):
                sf_rel = f.relative_to(self.root).as_posix()
                if sf_rel not in self.files and f.is_file():
                    self.anchors[sf_rel] = SourceFile(self.root, f)

    def get(self, rel: str) -> SourceFile | None:
        """Target if present, else anchor (cross-file context)."""
        return self.files.get(rel) or self.anchors.get(rel)

    def targets(self, pattern: str = "*") -> list[SourceFile]:
        return [sf for rel, sf in sorted(self.files.items())
                if fnmatch.fnmatch(rel, pattern)]

    def context(self, pattern: str) -> list[SourceFile]:
        """Targets *and* anchors matching a pattern (context reads)."""
        seen = dict(self.anchors)
        seen.update(self.files)
        return [sf for rel, sf in sorted(seen.items())
                if fnmatch.fnmatch(rel, pattern)]


def _walk(path: Path):
    path = Path(path)
    if path.is_file():
        if path.suffix == ".py":
            yield path
        return
    for p in sorted(path.rglob("*.py")):
        # skip dirs apply only *below* the requested path — an explicitly
        # passed path inside e.g. analyze_fixtures/ is analyzed on purpose
        if not any(part in SKIP_DIRS for part in p.relative_to(path).parts):
            yield p


# ---------------------------------------------------------------------------
# Baseline
# ---------------------------------------------------------------------------
BASELINE_NAME = ".analyze-baseline.json"


def load_baseline(path: Path) -> list[dict]:
    if not path.exists():
        return []
    data = json.loads(path.read_text())
    entries = data.get("findings", [])
    for e in entries:
        if "rule" not in e or "path" not in e:
            raise ValueError(f"baseline entry needs 'rule' and 'path': {e}")
    return entries


def baselined(finding: Finding, entries: list[dict]) -> bool:
    for e in entries:
        if e["rule"] not in (finding.rule, finding.family, "*"):
            continue
        if not fnmatch.fnmatch(finding.path, e["path"]):
            continue
        if "message" in e and not finding.message.startswith(e["message"]):
            continue
        return True
    return False


# ---------------------------------------------------------------------------
# Run
# ---------------------------------------------------------------------------
def run_analysis(index: RepoIndex, rules, config: AnalyzeConfig | None = None):
    """Run rule modules over the index.

    Returns ``(findings, suppressed)``: inline-``allow[]``-suppressed
    findings are split out (reported as counts, never failures).  Files
    that fail to parse produce a synthetic ``PARSE000`` finding — a tree
    the analyzer cannot read must fail loudly, not silently pass.
    """
    config = config or AnalyzeConfig()
    findings: list[Finding] = []
    suppressed: list[Finding] = []
    for sf in index.files.values():
        if sf.parse_error is not None:
            findings.append(Finding(
                "PARSE000", "parse", sf.rel,
                sf.parse_error.lineno or 0, sf.parse_error.offset or 0,
                f"syntax error: {sf.parse_error.msg}"))
    for mod in rules:
        for f in mod.check(index, config):
            sf = index.files.get(f.path)
            if sf is not None and sf.allowed(f.line, f.rule, mod.FAMILY):
                suppressed.append(f)
            else:
                findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings, suppressed

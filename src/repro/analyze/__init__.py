"""repro.analyze — repo-aware static analysis (the invariant linter).

Pure-``ast`` checks for the invariants this codebase's tests can only
probe dynamically and locally: dispatch-registry completeness, hot-path
host syncs, jit cache-key hygiene, Pallas legality, monotonic-clock
discipline, trace-schema conformance, deprecated-API creep — plus the
shared BENCH report schema checker.  CLI: ``python -m repro.analyze
[--strict] [--rule FAMILY] [--bench] [paths...]``.  See DESIGN.md §13.
"""
from .core import (
    AnalyzeConfig,
    Finding,
    RepoIndex,
    SourceFile,
    baselined,
    load_baseline,
    run_analysis,
)
from .rules import ALL_RULES, BY_FAMILY

__all__ = [
    "ALL_RULES",
    "AnalyzeConfig",
    "BY_FAMILY",
    "Finding",
    "RepoIndex",
    "SourceFile",
    "analyze_paths",
    "baselined",
    "load_baseline",
    "run_analysis",
]


def analyze_paths(paths, root, rules=None, config=None):
    """Convenience wrapper: index ``paths`` under ``root`` and run rules.

    Returns ``(findings, suppressed)`` like :func:`run_analysis`.
    """
    from pathlib import Path

    index = RepoIndex(Path(root), [Path(p) for p in paths])
    return run_analysis(index, rules or ALL_RULES, config)

"""CLI for the invariant linter: ``python -m repro.analyze``.

Default run lints ``src benchmarks examples tests`` under the repo root
and prints findings with fix hints.  Exit code:

* ``0`` — no findings (or, without ``--strict``, only baselined ones);
* ``1`` — findings (``--strict`` also fails on baselined findings being
  *stale*, i.e. baseline entries that no longer match anything).

``--bench`` instead validates the four ``BENCH_*.json`` reports against
the shared schema table (``repro.analyze.bench``).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from . import bench as bench_mod
from .core import (
    BASELINE_NAME,
    AnalyzeConfig,
    RepoIndex,
    baselined,
    load_baseline,
    run_analysis,
)
from .rules import ALL_RULES, BY_FAMILY

DEFAULT_PATHS = ("src", "benchmarks", "examples", "tests")


def _find_root(start: Path) -> Path:
    """Nearest ancestor holding pyproject.toml (else ``start`` itself)."""
    cur = start.resolve()
    for cand in (cur, *cur.parents):
        if (cand / "pyproject.toml").exists():
            return cand
    return cur


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analyze",
        description="repo-aware static analysis: kernel/dispatch/jit/obs "
                    "invariant linter (DESIGN.md §13)")
    ap.add_argument("paths", nargs="*",
                    help="files/dirs to lint (default: src benchmarks "
                         "examples tests under --root)")
    ap.add_argument("--root", type=Path, default=None,
                    help="repo root (default: nearest ancestor with "
                         "pyproject.toml)")
    ap.add_argument("--rule", action="append", dest="rules", metavar="FAMILY",
                    choices=sorted(BY_FAMILY),
                    help="run only this rule family (repeatable)")
    ap.add_argument("--strict", action="store_true",
                    help="fail on baselined findings' staleness too; this is "
                         "the CI gate")
    ap.add_argument("--baseline", type=Path, default=None,
                    help=f"baseline file (default: <root>/{BASELINE_NAME})")
    ap.add_argument("--vmem-budget", type=int,
                    default=AnalyzeConfig.vmem_budget_bytes, metavar="BYTES",
                    help="Pallas per-tile VMEM budget for PAL004")
    ap.add_argument("--bench", action="store_true",
                    help="validate BENCH_*.json reports instead of linting")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit findings as JSON (machine-readable)")
    args = ap.parse_args(argv)

    root = (args.root or _find_root(Path.cwd())).resolve()

    if args.list_rules:
        for mod in ALL_RULES:
            print(f"{mod.FAMILY}:")
            for code, desc in mod.CODES.items():
                print(f"  {code}  {desc}")
        return 0

    if args.bench:
        errors = bench_mod.check_all(root)
        for e in errors:
            print(f"  {e}", file=sys.stderr)
        return 1 if errors else 0

    rules = [BY_FAMILY[f] for f in args.rules] if args.rules else ALL_RULES
    paths = [Path(p) for p in args.paths] if args.paths else \
        [root / p for p in DEFAULT_PATHS if (root / p).exists()]
    config = AnalyzeConfig(vmem_budget_bytes=args.vmem_budget)
    index = RepoIndex(root, paths)
    findings, suppressed = run_analysis(index, rules, config)

    baseline_path = args.baseline or (root / BASELINE_NAME)
    entries = load_baseline(baseline_path)
    live = [f for f in findings if not baselined(f, entries)]
    grandfathered = [f for f in findings if baselined(f, entries)]
    stale = [e for e in entries
             if not any(baselined(f, [e]) for f in findings)]

    if args.as_json:
        print(json.dumps({
            "findings": [f.to_dict() for f in live],
            "baselined": [f.to_dict() for f in grandfathered],
            "suppressed": len(suppressed),
        }, indent=1))
    else:
        for f in live:
            print(f.render())
        summary = (f"{len(live)} finding(s), {len(grandfathered)} "
                   f"baselined, {len(suppressed)} inline-suppressed "
                   f"across {len(index.files)} files")
        print(("FAIL: " if live else "OK: ") + summary)
        if args.strict and stale:
            for e in stale:
                print(f"stale baseline entry (no longer matches anything): "
                      f"{e}", file=sys.stderr)

    if live:
        return 1
    if args.strict and stale:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())

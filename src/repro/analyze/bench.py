"""Shared schema checker for the four ``BENCH_*.json`` reports.

Before this module, ``benchmarks/traffic.py`` and
``benchmarks/compressed_serve.py`` each hand-rolled a ``--check-schema``
path while ``BENCH_kernels.json`` / ``BENCH_serve.json`` had none.  One
declarative table now describes all four acceptance shapes; the benchmark
``--check-schema`` flags delegate here and ``python -m repro.analyze
--bench`` validates every report in one CI step.

A schema is: required top-level keys, required per-row fields, percentile
blocks (``{count, mean, p50, p95, p99}`` with positive percentiles), row
diversity floors (e.g. >= 3 model families), and cross-field invariants
(goodput <= throughput; outcome counts partition the request count; kernel
parity error under tolerance).  Checks collect *all* errors instead of
stopping at the first assert, so a broken report shows its whole shape
diff at once.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, field
from pathlib import Path

PCT_FIELDS = ("count", "mean", "p50", "p95", "p99")

# max parity error a kernels report may carry — matches the interpret-mode
# parity gates in tests/test_kernels.py (f32 kernels sit ~1e-6)
KERNEL_REL_ERR_TOL = 1e-3


@dataclass(frozen=True)
class BenchSchema:
    """Declarative acceptance shape for one BENCH report."""

    name: str
    filename: str
    top_keys: tuple[str, ...]
    row_fields: tuple[str, ...]
    pct_blocks: tuple[str, ...] = ()
    # field -> minimum number of distinct values across rows
    diversity: dict = field(default_factory=dict)


def _num(x) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool) \
        and math.isfinite(x)


def _check_rows_common(schema: BenchSchema, rec: dict, errors: list[str]):
    rows = rec.get("rows")
    if not isinstance(rows, list) or not rows:
        errors.append(f"{schema.name}: 'rows' missing or empty")
        return []
    for i, r in enumerate(rows):
        ctx = f"{schema.name} row {i}"
        for key in schema.row_fields:
            if key not in r:
                errors.append(f"{ctx}: missing field {key!r}")
        for block in schema.pct_blocks:
            b = r.get(block)
            if not isinstance(b, dict):
                errors.append(f"{ctx}: {block} is not a percentile block")
                continue
            for f in ("p50", "p95", "p99"):
                if f in b and not (b[f] is not None and _num(b[f])
                                   and b[f] > 0):
                    errors.append(f"{ctx}: {block}.{f} not positive")
    for key, floor in schema.diversity.items():
        seen = {r.get(key) for r in rows if key in r}
        if len(seen) < floor:
            errors.append(f"{schema.name}: need >= {floor} distinct "
                          f"{key!r} values, got {len(seen)} ({sorted(map(str, seen))})")
    return rows


# ---------------------------------------------------------------------------
# Per-report cross-field invariants
# ---------------------------------------------------------------------------
def _invariants_traffic(rows, errors):
    for i, r in enumerate(rows):
        ctx = f"traffic row {i} ({r.get('family')}/{r.get('scenario')})"
        _pos(r, "wall_s", ctx, errors)
        _goodput_le_throughput(r, ctx, errors)
        if all(k in r for k in ("n_completed", "n_cancelled",
                                "n_deadline_missed", "n_requests")):
            if r["n_completed"] + r["n_cancelled"] + r["n_deadline_missed"] \
                    != r["n_requests"]:
                errors.append(f"{ctx}: outcome counts do not partition "
                              f"n_requests")
        if all(k in r for k in ("cancels", "n_cancelled",
                                "n_deadline_missed")):
            # obs-registry cancels cover client cancels + deadline expiry
            if r["cancels"] != r["n_cancelled"] + r["n_deadline_missed"]:
                errors.append(f"{ctx}: registry cancel count disagrees with "
                              f"outcomes")
        for block in ("ttft_s", "inter_token_s"):
            b = r.get(block)
            if isinstance(b, dict) and not (b.get("count") or 0) > 0:
                errors.append(f"{ctx}: empty {block} histogram")


def _invariants_serve(rows, errors):
    for i, r in enumerate(rows):
        ctx = f"serve row {i} ({r.get('family')}/{r.get('arch')})"
        _pos(r, "wall_s", ctx, errors)
        _pos(r, "tok_per_s", ctx, errors)
        _goodput_le_throughput(r, ctx, errors)


def _invariants_compressed(rows, errors):
    for i, r in enumerate(rows):
        ctx = f"compressed_serve row {i} ({r.get('arch')}/{r.get('variant')})"
        _pos(r, "tok_per_s", ctx, errors)
        cr = r.get("cr")
        if isinstance(cr, dict):
            for key in ("block", "network", "network_with_embed", "bits"):
                v = cr.get(key)
                if not (_num(v) and v >= 1.0):
                    errors.append(f"{ctx}: cr.{key} missing or < 1")
        else:
            errors.append(f"{ctx}: cr is not a dict")
        be = r.get("backends")
        if not (isinstance(be, dict) and be and all(
                isinstance(k, str) and isinstance(v, str)
                for k, v in be.items())):
            errors.append(f"{ctx}: backends must be a non-empty str->str map")


def _invariants_kernels(rows, errors):
    for i, r in enumerate(rows):
        ctx = f"kernels row {i} ({r.get('name')})"
        _pos(r, "ref_us", ctx, errors)
        _pos(r, "pallas_interpret_us", ctx, errors)
        err = r.get("max_rel_err")
        if not (_num(err) and 0 <= err <= KERNEL_REL_ERR_TOL):
            errors.append(f"{ctx}: max_rel_err {err!r} outside "
                          f"[0, {KERNEL_REL_ERR_TOL}] — kernel/oracle parity "
                          f"is the report's whole point")
        if r.get("timings_representative") is not False:
            errors.append(f"{ctx}: interpret-mode timings must be marked "
                          f"timings_representative=false")


def _pos(r, key, ctx, errors):
    if key in r and not (_num(r[key]) and r[key] > 0):
        errors.append(f"{ctx}: {key} not positive")


def _goodput_le_throughput(r, ctx, errors):
    if "goodput_tok_per_s" in r and "tok_per_s" in r and \
            _num(r["goodput_tok_per_s"]) and _num(r["tok_per_s"]):
        if r["goodput_tok_per_s"] > r["tok_per_s"] + 1e-9:
            errors.append(f"{ctx}: goodput exceeds throughput")


# ---------------------------------------------------------------------------
# The table
# ---------------------------------------------------------------------------
SCHEMAS: dict[str, BenchSchema] = {
    "kernels": BenchSchema(
        name="kernels", filename="BENCH_kernels.json",
        top_keys=("mode", "batch", "timings_note", "rows"),
        row_fields=("name", "kind", "n_in", "n_out", "batch", "ref_us",
                    "pallas_interpret_us", "max_rel_err",
                    "timings_representative"),
        diversity={"kind": 2},
    ),
    "serve": BenchSchema(
        name="serve", filename="BENCH_serve.json",
        top_keys=("workload", "note", "rows"),
        row_fields=("family", "arch", "slots", "prefill_attention_backend",
                    "recurrent_scan_backend", "wall_s", "tok_per_s",
                    "goodput_tok_per_s", "ttft_slo_s", "n_slo_attained",
                    "mean_first_token_s", "ttft_s", "inter_token_s",
                    "queue_s", "tokens", "decode_ticks", "preempts",
                    "cancels", "deadline_misses"),
        pct_blocks=("ttft_s", "inter_token_s", "queue_s"),
        diversity={"family": 3},
    ),
    "compressed_serve": BenchSchema(
        name="compressed_serve", filename="BENCH_compressed_serve.json",
        top_keys=("workload", "note", "rows"),
        row_fields=("arch", "variant", "cr", "backends", "tokens", "wall_s",
                    "tok_per_s", "mean_first_token_s", "ttft_s",
                    "inter_token_s"),
        pct_blocks=("ttft_s", "inter_token_s"),
        diversity={"variant": 3, "arch": 2},
    ),
    "traffic": BenchSchema(
        name="traffic", filename="BENCH_traffic.json",
        top_keys=("scenarios", "note", "rows"),
        row_fields=("family", "arch", "scenario", "workload", "n_requests",
                    "n_completed", "n_cancelled", "n_deadline_missed",
                    "wall_s", "tok_per_s", "goodput_tok_per_s", "ttft_s",
                    "inter_token_s", "tokens", "decode_ticks", "preempts",
                    "cancels", "deadline_misses"),
        pct_blocks=("ttft_s", "inter_token_s"),
        diversity={"family": 3, "scenario": 2},
    ),
}

_INVARIANTS = {
    "kernels": _invariants_kernels,
    "serve": _invariants_serve,
    "compressed_serve": _invariants_compressed,
    "traffic": _invariants_traffic,
}


def check_report(name: str, rec: dict) -> list[str]:
    """All schema errors for one parsed report (empty list == valid)."""
    schema = SCHEMAS[name]
    errors: list[str] = []
    for key in schema.top_keys:
        if key not in rec:
            errors.append(f"{name}: missing top-level key {key!r}")
    rows = _check_rows_common(schema, rec, errors)
    if rows:
        _INVARIANTS[name](rows, errors)
    return errors


def check_file(name: str, path: Path) -> list[str]:
    path = Path(path)
    if not path.exists():
        return [f"{name}: report file {path} does not exist"]
    try:
        rec = json.loads(path.read_text())
    except json.JSONDecodeError as e:
        return [f"{name}: {path} is not valid JSON: {e}"]
    return check_report(name, rec)


def check_all(root: Path, report=print) -> list[str]:
    """Validate every BENCH_*.json under ``root``; returns all errors."""
    root = Path(root)
    errors: list[str] = []
    for name, schema in SCHEMAS.items():
        errs = check_file(name, root / schema.filename)
        if errs:
            errors.extend(errs)
            report(f"bench {name}: FAIL ({len(errs)} errors)")
        else:
            rec = json.loads((root / schema.filename).read_text())
            report(f"bench {name}: OK ({len(rec['rows'])} rows)")
    return errors

"""pallas: static legality checks on ``pl.pallas_call`` sites.

The paper's GVSA dataflow works because tile shapes, DSP sharing and
schedules obey statically checkable design rules; the Pallas analog has the
same flavor of invariants, checked here to the extent the AST permits:

* **PAL001** — every ``pallas_call`` declares an explicit ``grid=``
  (implicit grids hide the tiling contract).
* **PAL002** — when the grid is a literal tuple, every ``BlockSpec``
  index-map lambda must take exactly ``len(grid)`` arguments (an arity
  mismatch is a guaranteed lowering failure, caught here without tracing).
* **PAL003** — kernel bodies are pure: no ``time.*`` / ``random.*`` /
  ``np.random.*`` / ``os.environ`` / ``print`` / ``open`` — Python-side
  effects run once at trace time and silently disappear from the compiled
  kernel.
* **PAL004** — when every ``BlockSpec`` block shape at a call site is
  statically sizeable (int literals or module-level int constants), the
  summed per-tile operand footprint must fit the VMEM budget
  (``--vmem-budget``, default 12 MiB to match the kernels' own headroom
  constant).  Symbolic shapes are skipped — the rule proves violations,
  never absence.
* **PAL005** — literal grid x literal block shape must tile the literal
  ``out_shape`` exactly (divisibility).

Dynamic shapes (the common case in real kernels) make PAL004/PAL005
best-effort by design; the fixture suite pins the literal cases.
"""
from __future__ import annotations

import ast

from ..core import Finding, dotted_name

FAMILY = "pallas"
CODES = {
    "PAL001": "pallas_call without an explicit grid",
    "PAL002": "BlockSpec index-map arity != grid rank",
    "PAL003": "Python-side effect call inside a kernel body",
    "PAL004": "statically-sized tile footprint exceeds the VMEM budget",
    "PAL005": "literal block shape does not divide the literal out_shape",
}

_EFFECT_PREFIXES = ("time.", "random.", "np.random.", "numpy.random.",
                    "os.environ", "os.getenv")
_EFFECT_NAMES = {"print", "open", "input", "time", "random"}


def _is_pallas_call(node: ast.Call) -> bool:
    name = dotted_name(node.func)
    return name == "pallas_call" or name.endswith(".pallas_call")


def _is_ctor(func: ast.AST, ctor: str) -> bool:
    name = dotted_name(func)
    return name == ctor or name.endswith("." + ctor)


def _kw(node: ast.Call, name: str):
    for kw in node.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _module_int_constants(tree: ast.Module) -> dict[str, int]:
    out: dict[str, int] = {}
    for stmt in tree.body:
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 and \
                isinstance(stmt.targets[0], ast.Name):
            try:
                v = ast.literal_eval(stmt.value)
            except (ValueError, SyntaxError):
                continue
            if isinstance(v, int) and not isinstance(v, bool):
                out[stmt.targets[0].id] = v
    return out


def _static_int(node: ast.AST, consts: dict[str, int]) -> int | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, int) and \
            not isinstance(node.value, bool):
        return node.value
    if isinstance(node, ast.Name):
        return consts.get(node.id)
    if isinstance(node, ast.BinOp) and isinstance(
            node.op, (ast.Mult, ast.Add, ast.Sub, ast.FloorDiv)):
        l = _static_int(node.left, consts)
        r = _static_int(node.right, consts)
        if l is None or r is None:
            return None
        if isinstance(node.op, ast.Mult):
            return l * r
        if isinstance(node.op, ast.Add):
            return l + r
        if isinstance(node.op, ast.Sub):
            return l - r
        return l // r if r else None
    return None


def _static_shape(node: ast.AST, consts) -> tuple[int, ...] | None:
    if not isinstance(node, (ast.Tuple, ast.List)):
        return None
    dims = [_static_int(e, consts) for e in node.elts]
    if any(d is None for d in dims):
        return None
    return tuple(dims)  # type: ignore[arg-type]


def _blockspecs_of(call: ast.Call) -> list[ast.Call]:
    """BlockSpec constructor calls lexically inside the pallas_call's
    in_specs/out_specs keyword values (the inline-literal pattern)."""
    out = []
    for name in ("in_specs", "out_specs"):
        v = _kw(call, name)
        if v is None:
            continue
        for sub in ast.walk(v):
            if isinstance(sub, ast.Call) and \
                    _is_ctor(sub.func, "BlockSpec"):
                out.append(sub)
    return out


def _spec_name_assignments(call: ast.Call, fn) -> list[ast.Call]:
    """Resolve ``in_specs=NAME`` through assignments/augments to NAME in the
    enclosing function — only when the function holds a single pallas_call
    (several calls would alias each other's specs)."""
    names = {v.id for v in (_kw(call, "in_specs"), _kw(call, "out_specs"))
             if isinstance(v, ast.Name)}
    if not names or fn is None:
        return []
    n_calls = sum(1 for n in ast.walk(fn)
                  if isinstance(n, ast.Call) and _is_pallas_call(n))
    if n_calls != 1:
        return []
    out = []
    for stmt in ast.walk(fn):
        value = None
        if isinstance(stmt, (ast.Assign, ast.AugAssign)):
            targets = stmt.targets if isinstance(stmt, ast.Assign) else [stmt.target]
            if any(isinstance(t, ast.Name) and t.id in names for t in targets):
                value = stmt.value
        elif isinstance(stmt, ast.Call) and \
                isinstance(stmt.func, ast.Attribute) and \
                stmt.func.attr == "append" and \
                isinstance(stmt.func.value, ast.Name) and \
                stmt.func.value.id in names:
            value = stmt.args[0] if stmt.args else None
        if value is not None:
            for sub in ast.walk(value):
                if isinstance(sub, ast.Call) and \
                        _is_ctor(sub.func, "BlockSpec"):
                    out.append(sub)
    return out


def _kernel_fn_name(call: ast.Call) -> str | None:
    """The kernel body's function name: first positional arg, possibly
    wrapped in functools.partial."""
    if not call.args:
        return None
    fn = call.args[0]
    if isinstance(fn, ast.Call) and dotted_name(fn.func) in (
            "functools.partial", "partial"):
        fn = fn.args[0] if fn.args else None
    if isinstance(fn, ast.Name):
        return fn.id
    return None


def _enclosing_fn(node, sf):
    from ..core import enclosing_function
    return enclosing_function(node)


def check(index, config):
    budget = config.vmem_budget_bytes
    for sf in index.targets():
        if sf.tree is None or "pallas" not in sf.text:
            continue
        consts = _module_int_constants(sf.tree)
        kernels_checked: set[str] = set()
        for node in ast.walk(sf.tree):
            if not (isinstance(node, ast.Call) and _is_pallas_call(node)):
                continue
            grid = _kw(node, "grid")
            if grid is None:
                yield Finding(
                    "PAL001", FAMILY, sf.rel, node.lineno, node.col_offset,
                    "pallas_call without an explicit grid=",
                    "declare the grid — implicit whole-array kernels hide "
                    "the tiling contract the dispatch layer relies on")
                continue
            fn = _enclosing_fn(node, sf)
            specs = _blockspecs_of(node) + _spec_name_assignments(node, fn)
            # PAL002: index-map arity vs literal grid rank
            if isinstance(grid, ast.Tuple):
                rank = len(grid.elts)
                for spec in specs:
                    lam = next((a for a in spec.args
                                if isinstance(a, ast.Lambda)), None)
                    if lam is None:
                        continue
                    arity = len(lam.args.args) + len(lam.args.posonlyargs)
                    n_default = len(lam.args.defaults)
                    # defaulted trailing params are capture helpers, not
                    # grid coordinates
                    if not (arity - n_default <= rank <= arity):
                        yield Finding(
                            "PAL002", FAMILY, sf.rel, spec.lineno,
                            spec.col_offset,
                            f"BlockSpec index map takes {arity} args but the "
                            f"grid has rank {rank}",
                            "the index map receives one program id per grid "
                            "axis — an arity mismatch fails at lowering")
            # PAL004: statically-sized tile footprint vs the VMEM budget
            tile_bytes = 0
            all_static = bool(specs)
            for spec in specs:
                shape = _static_shape(spec.args[0], consts) if spec.args else None
                if shape is None:
                    all_static = False
                    break
                n = 1
                for d in shape:
                    n *= d
                tile_bytes += n * 4  # f32 worst case per operand tile
            if all_static and tile_bytes > budget:
                yield Finding(
                    "PAL004", FAMILY, sf.rel, node.lineno, node.col_offset,
                    f"summed tile footprint ~{tile_bytes // 1024} KiB exceeds "
                    f"the VMEM budget ({budget // 1024} KiB)",
                    "shrink the block shapes or raise --vmem-budget if the "
                    "target really has more on-chip memory")
            # PAL005: literal grid x literal out block must tile out_shape
            yield from _check_divisibility(sf, node, grid, consts)
            # PAL003: kernel body purity
            kname = _kernel_fn_name(node)
            if kname and kname not in kernels_checked:
                kernels_checked.add(kname)
                yield from _check_kernel_purity(sf, kname)


def _check_divisibility(sf, node, grid, consts):
    out_shape = _kw(node, "out_shape")
    out_specs = _kw(node, "out_specs")
    if not isinstance(grid, ast.Tuple) or out_shape is None or \
            out_specs is None:
        return
    grid_dims = [_static_int(e, consts) for e in grid.elts]
    if any(d is None for d in grid_dims):
        return
    # single ShapeDtypeStruct + single BlockSpec only (the common literal
    # fixture shape); multi-output kernels are skipped
    if not (isinstance(out_shape, ast.Call) and
            _is_ctor(out_shape.func, "ShapeDtypeStruct")):
        return
    shape = _static_shape(out_shape.args[0], consts) if out_shape.args else None
    if shape is None:
        return
    spec = out_specs if isinstance(out_specs, ast.Call) else None
    if spec is None or not _is_ctor(spec.func, "BlockSpec"):
        return
    block = _static_shape(spec.args[0], consts) if spec.args else None
    if block is None or len(block) != len(shape):
        return
    for i, (b, s) in enumerate(zip(block, shape)):
        if b and s % b:
            yield Finding(
                "PAL005", FAMILY, sf.rel, spec.lineno, spec.col_offset,
                f"block dim {i} ({b}) does not divide out_shape dim "
                f"{i} ({s})",
                "pad the array to a block multiple (the repo's kernels pad "
                "then slice) or pick a dividing block shape")


def _check_kernel_purity(sf, kernel_name):
    for node in ast.walk(sf.tree):
        if not (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) and
                node.name == kernel_name):
            continue
        for sub in ast.walk(node):
            if not isinstance(sub, ast.Call):
                continue
            name = dotted_name(sub.func)
            bad = name in _EFFECT_NAMES or \
                any(name.startswith(p) for p in _EFFECT_PREFIXES)
            if bad:
                yield Finding(
                    "PAL003", FAMILY, sf.rel, sub.lineno, sub.col_offset,
                    f"kernel body {kernel_name}() calls {name}()",
                    "kernel bodies trace once and run on device — Python-"
                    "side RNG/time/IO executes at trace time and vanishes "
                    "from the compiled kernel")

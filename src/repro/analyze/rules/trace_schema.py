"""trace-schema: obs emit sites must match ``EVENT_FIELDS``.

``obs/trace.py``'s ``EVENT_FIELDS`` table is the single source of truth
for the scheduler trace schema — ``obs.export`` validates persisted JSONL
against it and the ordering-invariant tests replay it.  An emit site that
invents an event name or field silently produces records the exporter
rejects *later*, far from the bug.  This rule reads the table straight out
of the anchor file's AST (no import) and checks every
``<obs>.event("name", ...)`` / ``<trace>.emit("name", ...)`` call with a
literal event name:

* **TRACE001** — unknown event type;
* **TRACE002** — keyword not declared for that event (``t`` is part of the
  common envelope and always allowed);
* **TRACE003** — declared field missing at the call site (only when the
  call has no ``**kwargs`` expansion that could supply it).
"""
from __future__ import annotations

import ast

from ..core import Finding, dotted_name

FAMILY = "trace-schema"
CODES = {
    "TRACE001": "emit of an event type not declared in EVENT_FIELDS",
    "TRACE002": "emit passes a field not declared for the event type",
    "TRACE003": "emit omits a field EVENT_FIELDS declares for the event",
}

TRACE_PATH = "src/repro/obs/trace.py"

# receiver suffixes that mark a call as a scheduler-trace emit (plain
# ``.emit()`` on unrelated objects is out of scope)
_RECEIVERS = ("obs", "trace", "_trace", "tracer", "observer")


def _event_fields(index) -> dict[str, tuple[str, ...]] | None:
    sf = index.get(TRACE_PATH)
    if sf is None or sf.tree is None:
        return None
    for node in sf.tree.body:
        if isinstance(node, (ast.Assign, ast.AnnAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            if any(isinstance(t, ast.Name) and t.id == "EVENT_FIELDS"
                   for t in targets) and node.value is not None:
                try:
                    val = ast.literal_eval(node.value)
                except (ValueError, SyntaxError):
                    return None
                if isinstance(val, dict):
                    return {k: tuple(v) for k, v in val.items()}
    return None


def _is_emit_site(node: ast.Call) -> str | None:
    """Literal event name when ``node`` is a trace-emit call, else None."""
    if not (isinstance(node.func, ast.Attribute) and
            node.func.attr in ("event", "emit")):
        return None
    recv = dotted_name(node.func.value)
    if not recv or recv.rsplit(".", 1)[-1] not in _RECEIVERS:
        return None
    if node.args and isinstance(node.args[0], ast.Constant) and \
            isinstance(node.args[0].value, str):
        return node.args[0].value
    return None


def check(index, config):
    fields_by_event = _event_fields(index)
    if fields_by_event is None:
        return  # no anchor (fixture run outside the repo) — nothing to check
    for sf in index.targets():
        if sf.tree is None:
            continue
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            ev = _is_emit_site(node)
            if ev is None:
                continue
            declared = fields_by_event.get(ev)
            if declared is None:
                yield Finding(
                    "TRACE001", FAMILY, sf.rel, node.lineno, node.col_offset,
                    f"event type {ev!r} is not declared in "
                    f"obs.trace.EVENT_FIELDS",
                    "add the event + its field tuple to EVENT_FIELDS first — "
                    "the exporter and replay tests only know declared events")
                continue
            has_star = any(kw.arg is None for kw in node.keywords)
            passed = {kw.arg for kw in node.keywords if kw.arg is not None}
            for name in sorted(passed - set(declared) - {"t"}):
                yield Finding(
                    "TRACE002", FAMILY, sf.rel, node.lineno, node.col_offset,
                    f"field {name!r} is not declared for event {ev!r}",
                    f"declared fields: {', '.join(declared)} — extend "
                    f"EVENT_FIELDS if the event really grew a field")
            if not has_star:
                for name in sorted(set(declared) - passed):
                    yield Finding(
                        "TRACE003", FAMILY, sf.rel, node.lineno,
                        node.col_offset,
                        f"event {ev!r} omits declared field {name!r}",
                        "EVENT_FIELDS fields are required — the exporter "
                        "rejects records missing them")

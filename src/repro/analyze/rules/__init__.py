"""Rule-family registry.

Each module exposes ``FAMILY`` (the ``--rule`` / ``allow[]`` key),
``CODES`` (finding code -> one-line description), and
``check(index, config) -> Iterator[Finding]``.  Adding a family =
adding a module here + listing it in ``ALL_RULES`` (DESIGN.md §13).
"""
from . import (  # noqa: F401
    clock,
    deprecated,
    dispatch_registry,
    host_sync,
    jit_hygiene,
    pallas_legality,
    trace_schema,
)

ALL_RULES = (
    dispatch_registry,
    host_sync,
    jit_hygiene,
    pallas_legality,
    clock,
    trace_schema,
    deprecated,
)

BY_FAMILY = {mod.FAMILY: mod for mod in ALL_RULES}

"""wall-clock: ``time.time()`` is banned from duration math.

Every latency/duration stamp in this repo is ``time.perf_counter()``
(monotonic — a wall-clock step during a measurement corrupts a latency
forever; DESIGN.md §9).  ``time.time()`` survives only at explicitly
annotated informational wall-stamp sites (``Request.t_submit_wall``).
"""
from __future__ import annotations

import ast

from ..core import Finding, dotted_name

FAMILY = "wall-clock"
CODES = {
    "CLK001": "time.time() call (use time.perf_counter for durations)",
}

_HINT = ("use time.perf_counter() — monotonic, immune to wall-clock steps; "
         "a purely informational wall stamp may stay with "
         "`# analyze: allow[wall-clock] <reason>`")


def check(index, config):
    for sf in index.targets():
        if sf.tree is None:
            continue
        from_time = {
            a.asname or a.name
            for node in ast.walk(sf.tree) if isinstance(node, ast.ImportFrom)
            if node.module == "time"
            for a in node.names if a.name == "time"
        }
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            if name == "time.time" or (name and name in from_time):
                yield Finding("CLK001", FAMILY, sf.rel, node.lineno,
                              node.col_offset, "time.time() call in code "
                              "that must use the monotonic clock", _HINT)

"""deprecated-api: internal code must not use deprecated shims.

``PagedEngine`` (the pre-unification engine alias) and ``get_model`` (the
pre-``build_model`` constructor) survive only as ``DeprecationWarning``
shims for external callers.  Internal code — src, benchmarks, examples —
routes through ``serve.engine.Engine`` / ``models.api.build_model``; the
tests that pin the deprecation warnings themselves carry inline allows.
"""
from __future__ import annotations

import ast

from ..core import Finding

FAMILY = "deprecated-api"
CODES = {
    "DEP001": "use of a deprecated API (PagedEngine / get_model)",
}

# name -> (replacement, definition files where the shim itself lives)
DEPRECATED = {
    "PagedEngine": ("repro.serve.engine.Engine",
                    ("src/repro/serve/engine.py",)),
    "get_model": ("repro.models.api.build_model",
                  ("src/repro/models/api.py",)),
}


def check(index, config):
    for sf in index.targets():
        if sf.tree is None:
            continue
        for node in ast.walk(sf.tree):
            hits = []  # (name, lineno, col)
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue  # the shim's own definition
            if isinstance(node, ast.Name) and node.id in DEPRECATED:
                hits.append((node.id, node.lineno, node.col_offset))
            elif isinstance(node, ast.Attribute) and node.attr in DEPRECATED:
                hits.append((node.attr, node.lineno, node.col_offset))
            elif isinstance(node, ast.ImportFrom):
                for a in node.names:
                    if a.name in DEPRECATED:
                        hits.append((a.name, node.lineno, node.col_offset))
            for name, line, col in hits:
                repl, def_files = DEPRECATED[name]
                if sf.rel in def_files:
                    continue  # definition site
                yield Finding(
                    "DEP001", FAMILY, sf.rel, line, col,
                    f"deprecated API {name!r} (use {repl})",
                    "internal code must not grow new uses of deprecated "
                    "shims; a test pinning the DeprecationWarning itself "
                    "may annotate `# analyze: allow[deprecated-api] ...`")

"""dispatch-registry: every kernel role must be *fully* registered.

The serving stack's execution contract (DESIGN.md §2/§8/§10): a kernel
role ships with all four legs or it does not ship —

1. a pure-jnp **oracle** in ``kernels/ref.py`` (the parity gate),
2. a **Pallas kernel** body (``*_pallas``),
3. a **dispatch route** in ``kernels/dispatch.py`` resolving the backend
   policy chain (``resolve_backend``),
4. **obs wiring** (``_record_dispatch`` → per-(role, backend) counters).

The registry below is the analyzer's source of truth; the rule
cross-checks it against the actual tree so a new kernel (e.g. PR 11's
prefix-cache / speculative roles) cannot land half-registered: a new
dispatcher, kernel, or role string that the registry does not know is a
finding telling the author exactly which legs are missing.
"""
from __future__ import annotations

import ast

from ..core import Finding, dotted_name

FAMILY = "dispatch-registry"
CODES = {
    "DISP001": "registered dispatcher missing from kernels/dispatch.py",
    "DISP002": "dispatcher lacks obs wiring (_record_dispatch)",
    "DISP003": "dispatcher bypasses the backend policy chain (resolve_backend)",
    "DISP004": "registered oracle missing from kernels/ref.py",
    "DISP005": "registered Pallas kernel function missing under kernels/",
    "DISP006": "Pallas kernel (*_pallas) not routed through dispatch",
    "DISP007": "dispatcher not present in the analyzer registry",
    "DISP008": "unknown kernel role string at a dispatch call site",
}

DISPATCH_PATH = "src/repro/kernels/dispatch.py"
REF_PATH = "src/repro/kernels/ref.py"
KERNELS_GLOB = "src/repro/kernels/*.py"

# dispatcher function -> legs.  ``oracles`` are names that must exist in
# kernels/ref.py; ``kernel`` must be a top-level def under kernels/.
# ``xla_native`` dispatchers have no Pallas body on purpose (XLA's own
# matmul saturates the MXU) and skip the policy chain.
REGISTRY: dict[str, dict] = {
    "dense_linear": {"oracles": (), "kernel": None, "xla_native": True},
    "tt_linear": {"oracles": ("tt_linear_bn_res",),
                  "kernel": "tt_linear_pallas"},
    "tt_embed": {"oracles": ("tt_embedding",), "kernel": "tt_embed_pallas"},
    "int4_matmul": {"oracles": ("int4_matmul",),
                    "kernel": "int4_matmul_pallas"},
    "paged_attention": {"oracles": ("paged_attention",),
                        "kernel": "paged_attention_pallas"},
    "prefill_attention": {"oracles": ("paged_attention", "ring_attention"),
                          "kernel": "prefill_attention_pallas"},
    "rglru_scan": {"oracles": ("rglru_scan",), "kernel": "rglru_scan_pallas"},
    "wkv_scan": {"oracles": ("wkv_scan",), "kernel": "wkv_scan_pallas"},
}

# The role namespace is two-tier: *layer* roles (``LinearSpec.role`` —
# "attn_q", "mlp_up", ... an open set flowing through the linear
# dispatchers and ``resolve_backend`` for per-role env overrides) and
# *kernel-op* roles (the fixed per-op vocabulary below).  Only the latter
# is closed, so only calls to the closed-vocabulary dispatchers are
# checked for typos.
KNOWN_ROLES = {
    "attn_paged", "attn_prefill", "rglru_scan", "wkv_scan",
    "embed_lookup", "unembed",
}

_ROLE_CALL_TARGETS = {"paged_attention", "prefill_attention",
                      "rglru_scan", "wkv_scan", "tt_embed"}

_REG_HINT = ("register the role in repro/analyze/rules/dispatch_registry.py "
             "with its oracle + kernel legs — the registry is how the "
             "analyzer knows a kernel ships complete")


def _top_defs(sf) -> set[str]:
    if sf is None or sf.tree is None:
        return set()
    return {n.name for n in sf.tree.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


def _fn_calls(fn) -> set[str]:
    return {dotted_name(n.func) for n in ast.walk(fn)
            if isinstance(n, ast.Call)}


def check(index, config):
    dispatch = index.get(DISPATCH_PATH)
    ref = index.get(REF_PATH)

    # registry legs — only checkable when the anchor files parse
    if dispatch is not None and dispatch.tree is not None:
        yield from _check_registry(index, dispatch, ref)

    # DISP008: unknown role strings anywhere in the analyzed targets
    for sf in index.targets():
        if sf.tree is None:
            continue
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            callee = dotted_name(node.func)
            short = callee.rsplit(".", 1)[-1]
            if short not in _ROLE_CALL_TARGETS:
                continue
            for kw in node.keywords:
                if kw.arg == "role" and isinstance(kw.value, ast.Constant) \
                        and isinstance(kw.value.value, str) \
                        and kw.value.value not in KNOWN_ROLES:
                    yield Finding(
                        "DISP008", FAMILY, sf.rel, kw.value.lineno,
                        kw.value.col_offset,
                        f"unknown kernel role {kw.value.value!r} passed to "
                        f"{short}()", _REG_HINT)


def _check_registry(index, dispatch, ref):
    ref_defs = _top_defs(ref)
    kernel_defs: dict[str, str] = {}  # def name -> rel path
    for sf in index.context(KERNELS_GLOB):
        for name in _top_defs(sf):
            kernel_defs.setdefault(name, sf.rel)

    dispatchers = {n.name: n for n in dispatch.tree.body
                   if isinstance(n, ast.FunctionDef)
                   and not n.name.startswith("_")}

    for name, legs in REGISTRY.items():
        fn = dispatchers.get(name)
        if fn is None:
            yield Finding(
                "DISP001", FAMILY, dispatch.rel, 1, 0,
                f"registered dispatcher {name}() not defined in "
                f"kernels/dispatch.py",
                "every kernel role needs a dispatch route (DESIGN.md §2)")
            continue
        calls = _fn_calls(fn)
        if "_record_dispatch" not in {c.rsplit(".", 1)[-1] for c in calls}:
            yield Finding(
                "DISP002", FAMILY, dispatch.rel, fn.lineno, fn.col_offset,
                f"dispatcher {name}() never calls _record_dispatch()",
                "obs counter wiring is part of the role contract — "
                "benchmarks report the backend that actually traced "
                "(DESIGN.md §9)")
        if not legs.get("xla_native") and "resolve_backend" not in {
                c.rsplit(".", 1)[-1] for c in calls}:
            yield Finding(
                "DISP003", FAMILY, dispatch.rel, fn.lineno, fn.col_offset,
                f"dispatcher {name}() never calls resolve_backend()",
                "backends resolve through one policy chain "
                "(explicit > override > env > config > auto)")
        for oracle in legs["oracles"]:
            if oracle not in ref_defs:
                yield Finding(
                    "DISP004", FAMILY, dispatch.rel, fn.lineno, fn.col_offset,
                    f"oracle ref.{oracle}() for dispatcher {name}() not "
                    f"defined in kernels/ref.py",
                    "every kernel is parity-gated against a pure-jnp oracle")
        kern = legs.get("kernel")
        if kern and kern not in kernel_defs:
            yield Finding(
                "DISP005", FAMILY, dispatch.rel, fn.lineno, fn.col_offset,
                f"Pallas kernel {kern}() for dispatcher {name}() not "
                f"defined under src/repro/kernels/",
                "the kernel leg is missing — ship the Pallas body or mark "
                "the dispatcher xla_native in the registry")

    # DISP007: a dispatcher with obs wiring the registry does not know
    for name, fn in dispatchers.items():
        if name in REGISTRY:
            continue
        if "_record_dispatch" in {c.rsplit(".", 1)[-1] for c in _fn_calls(fn)}:
            yield Finding(
                "DISP007", FAMILY, dispatch.rel, fn.lineno, fn.col_offset,
                f"dispatcher {name}() is not in the analyzer registry",
                _REG_HINT)

    # DISP006: *_pallas kernels nobody routes
    dispatch_text = dispatch.text
    for name, rel in sorted(kernel_defs.items()):
        if not name.endswith("_pallas") or rel == dispatch.rel:
            continue
        if name not in dispatch_text:
            sf = index.get(rel)
            line = next((n.lineno for n in sf.tree.body
                         if isinstance(n, ast.FunctionDef)
                         and n.name == name), 1)
            yield Finding(
                "DISP006", FAMILY, rel, line, 0,
                f"Pallas kernel {name}() is never referenced from "
                f"kernels/dispatch.py",
                "kernels ship behind a dispatch role (ref | "
                "pallas-interpret | pallas), never called directly")

"""jit-hygiene: jit cache keys must cover everything a program bakes in.

Three bug shapes this repo has to guard against (DESIGN.md §13):

* **JIT001** — a jitted closure stored in a module-level memo/cache
  captures an enclosing-scope variable that is *not* part of the cache
  key: two calls with different values silently share one trace
  (``serve.steps.session_step_fns`` is the load-bearing example — its
  closures bind ``session``/``kernel_backend`` via default args and the
  key carries both).
* **JIT002** — ``static_argnums``/``static_argnames`` naming a parameter
  with a mutable (unhashable) default: the first call with the default
  raises ``TypeError: unhashable`` at dispatch time.
* **JIT003** — a module-level ``@jax.jit`` function reading module-level
  mutable state (list/dict/set): the trace bakes in the first value and
  never sees mutations.
"""
from __future__ import annotations

import ast

from ..core import Finding, dotted_name, enclosing_function, parent

FAMILY = "jit-hygiene"
CODES = {
    "JIT001": "jitted closure in a module-level cache captures a variable "
              "missing from the cache key",
    "JIT002": "static_argnums/static_argnames over a parameter with an "
              "unhashable default",
    "JIT003": "module-level jitted function closes over mutable module state",
}

_MUTABLE = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp,
            ast.SetComp)


def _is_jit_call(node: ast.Call) -> bool:
    return dotted_name(node.func) in ("jax.jit", "jit")


def _names_loaded(node: ast.AST) -> set[str]:
    return {n.id for n in ast.walk(node)
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)}


def _local_bindings(fn) -> set[str]:
    """Parameter names + default-arg bindings + local stores of ``fn``."""
    args = fn.args
    bound = {a.arg for a in (args.posonlyargs + args.args + args.kwonlyargs)}
    if args.vararg:
        bound.add(args.vararg.arg)
    if args.kwarg:
        bound.add(args.kwarg.arg)
    body = fn.body if isinstance(fn.body, list) else [fn.body]
    for stmt in body:
        for n in ast.walk(stmt):
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Store):
                bound.add(n.id)
            elif isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.ClassDef)):
                bound.add(n.name)
    return bound


def _captured_from(fn, outer) -> set[str]:
    """Names ``fn`` reads that are bound in enclosing function ``outer``."""
    body = fn.body if isinstance(fn.body, list) else [fn.body]
    loaded = set()
    for stmt in body:
        loaded |= _names_loaded(stmt)
    return (loaded - _local_bindings(fn)) & _local_bindings(outer)


def _module_mutables(tree: ast.Module) -> set[str]:
    out = set()
    for stmt in tree.body:
        targets = []
        if isinstance(stmt, ast.Assign):
            targets, value = stmt.targets, stmt.value
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            targets, value = [stmt.target], stmt.value
        else:
            continue
        if isinstance(value, _MUTABLE) or (
                isinstance(value, ast.Call) and
                dotted_name(value.func) in ("dict", "list", "set")):
            for t in targets:
                if isinstance(t, ast.Name):
                    out.add(t.id)
    return out


def _resolve_local_def(name: str, scope) -> ast.AST | None:
    for n in ast.walk(scope):
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)) and \
                n.name == name:
            return n
    return None


def _cache_store_key(node: ast.Call):
    """If ``node``'s value flows into ``CACHE[key] = ...`` (directly or via a
    container literal), return the key expression, else None."""
    cur: ast.AST = node
    p = parent(cur)
    while isinstance(p, (ast.Tuple, ast.List, ast.Dict)):
        cur, p = p, parent(p)
    if isinstance(p, ast.Assign) and len(p.targets) == 1 and \
            isinstance(p.targets[0], ast.Subscript):
        return p.targets[0].slice
    return None


def _key_names(key_expr: ast.AST, outer) -> set[str]:
    """Names reachable from the cache-key expression (one level of local
    assignment indirection: ``key = (...); CACHE[key] = ...``)."""
    names = _names_loaded(key_expr)
    if isinstance(key_expr, ast.Name):
        for stmt in ast.walk(outer):
            if isinstance(stmt, ast.Assign) and any(
                    isinstance(t, ast.Name) and t.id == key_expr.id
                    for t in stmt.targets):
                names |= _names_loaded(stmt.value)
    return names


def check(index, config):
    for sf in index.targets():
        if sf.tree is None:
            continue
        mod_mutables = _module_mutables(sf.tree)
        for node in ast.walk(sf.tree):
            # --- call form: jax.jit(f, ...) --------------------------------
            if isinstance(node, ast.Call) and _is_jit_call(node) and node.args:
                fn_arg = node.args[0]
                outer = enclosing_function(node)
                target = None
                if isinstance(fn_arg, ast.Lambda):
                    target = fn_arg
                elif isinstance(fn_arg, ast.Name) and outer is not None:
                    target = _resolve_local_def(fn_arg.id, outer)
                # JIT001: only when the jitted program lands in a cache
                key_expr = _cache_store_key(node)
                if target is not None and outer is not None and \
                        key_expr is not None:
                    captured = _captured_from(target, outer)
                    missing = captured - _key_names(key_expr, outer)
                    for name in sorted(missing):
                        yield Finding(
                            "JIT001", FAMILY, sf.rel, node.lineno,
                            node.col_offset,
                            f"jitted closure captures {name!r} from the "
                            f"enclosing scope but the cache key does not "
                            f"include it",
                            f"bind it via a default arg (`_x={name}`) and/or "
                            f"add it to the memo key — otherwise two "
                            f"configurations share one trace")
                # JIT002: unhashable static-arg defaults
                yield from _check_static_args(sf, node, target)
            # --- decorator form: @jax.jit on a module-level def ------------
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                jit_deco = any(
                    (isinstance(d, ast.Call) and _is_jit_call(d)) or
                    dotted_name(d) in ("jax.jit", "jit")
                    for d in node.decorator_list)
                if jit_deco and isinstance(parent(node), ast.Module):
                    reads = _names_loaded(node) & mod_mutables
                    for name in sorted(reads - _local_bindings(node)):
                        yield Finding(
                            "JIT003", FAMILY, sf.rel, node.lineno,
                            node.col_offset,
                            f"@jax.jit function {node.name}() reads mutable "
                            f"module state {name!r}",
                            "the trace bakes in the value at first call and "
                            "never sees mutations; pass it as an argument "
                            "or make it an immutable constant")


def _check_static_args(sf, node: ast.Call, target):
    static_names: set[str] = set()
    static_nums: set[int] = set()
    for kw in node.keywords:
        if kw.arg == "static_argnames":
            for sub in ast.walk(kw.value):
                if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
                    static_names.add(sub.value)
        elif kw.arg == "static_argnums":
            for sub in ast.walk(kw.value):
                if isinstance(sub, ast.Constant) and isinstance(sub.value, int):
                    static_nums.add(sub.value)
    if target is None or not isinstance(target, (ast.FunctionDef,
                                                 ast.AsyncFunctionDef)):
        return
    args = target.args.posonlyargs + target.args.args
    defaults = target.args.defaults
    # defaults align with the tail of the positional parameter list
    offset = len(args) - len(defaults)
    for i, a in enumerate(args):
        if a.arg not in static_names and i not in static_nums:
            continue
        d = defaults[i - offset] if i >= offset else None
        if d is not None and isinstance(d, _MUTABLE):
            yield Finding(
                "JIT002", FAMILY, sf.rel, node.lineno, node.col_offset,
                f"static argument {a.arg!r} has an unhashable default",
                "static args are hashed into the jit cache key; a "
                "list/dict/set default raises TypeError at dispatch")

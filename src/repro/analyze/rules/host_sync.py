"""host-sync: no un-annotated device syncs in the decode hot loop.

PR 6's overhead contract: the serving hot path (decode tick phases, the
async pump, the chunked-prefill driver, model decode bodies) adds **no**
device syncs beyond the acknowledged ones.  Until now one monkeypatch test
enforced this for one call site; this rule makes every sync-shaped call in
a hot region a finding unless it carries an inline
``# analyze: allow[host-sync] <why this sync is acknowledged>``.

Flagged in hot regions:

* ``jax.block_until_ready(...)`` / bare ``block_until_ready(...)``
* ``<expr>.item()``
* ``np.asarray(...)`` / ``np.array(...)`` / ``jax.device_get(...)`` —
  pulling a device array to host blocks on it
* ``float(...)`` / ``int(...)`` whose argument contains a ``jnp.*`` /
  ``jax.*`` call (coercing a device value forces a transfer)
"""
from __future__ import annotations

import ast
import fnmatch
import re

from ..core import Finding, dotted_name, enclosing_function

FAMILY = "host-sync"
CODES = {
    "SYNC001": "host-device sync in a decode hot-path region",
}

# (path glob, function-name regex) pairs marking hot regions
HOT_REGIONS = (
    ("src/repro/serve/engine.py",
     r"^(_decode_schedule|_decode_dispatch|_decode_collect|_plan_ahead"
     r"|_finish_tick|_sample|_emit)$"),
    ("src/repro/serve/frontend.py", r"^(_pump|_deliver|_apply_cancels)$"),
    ("src/repro/serve/steps.py",
     r"^(chunked_prefill|session_step_fns|greedy_tokens|_greedy_tokens)$"),
    # model decode bodies, wherever they live (sessions, families, fixtures)
    ("*.py", r"^(decode_step\w*|_decode\w*)$"),
)

_SYNC_CALLS = {"jax.block_until_ready", "block_until_ready",
               "np.asarray", "np.array", "numpy.asarray", "numpy.array",
               "jax.device_get"}
_HINT = ("the decode loop must stay async — dispatch returns while the "
         "device computes; an acknowledged sync needs "
         "`# analyze: allow[host-sync] <reason>` on its line")


def _hot_functions(sf):
    """FunctionDefs in ``sf`` whose (file, name) matches a hot region."""
    out = []
    if sf.tree is None:
        return out
    pats = [re.compile(rx) for glob, rx in HOT_REGIONS
            if fnmatch.fnmatch(sf.rel, glob)]
    if not pats:
        return out
    for node in ast.walk(sf.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) and \
                any(p.match(node.name) for p in pats):
            out.append(node)
    return out


def _device_coercion(call: ast.Call) -> bool:
    """float(x)/int(x) where x contains a jnp./jax. call."""
    if not (isinstance(call.func, ast.Name) and call.func.id in ("float", "int")):
        return False
    for arg in call.args:
        for sub in ast.walk(arg):
            if isinstance(sub, ast.Call):
                name = dotted_name(sub.func)
                if name.startswith(("jnp.", "jax.")):
                    return True
    return False


def check(index, config):
    for sf in index.targets():
        for fn in _hot_functions(sf):
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                # a nested def inside a hot fn is still hot; a hot fn found
                # by the wildcard pattern inside a non-hot one is handled by
                # its own entry in _hot_functions, so no double-reporting
                if enclosing_function(node) is None:
                    continue
                name = dotted_name(node.func)
                msg = None
                if name in _SYNC_CALLS:
                    msg = f"{name}() in hot-path function {fn.name}()"
                elif isinstance(node.func, ast.Attribute) and \
                        node.func.attr == "item" and not node.args:
                    msg = f".item() in hot-path function {fn.name}()"
                elif _device_coercion(node):
                    msg = (f"{node.func.id}() over a device value in "
                           f"hot-path function {fn.name}()")
                if msg:
                    yield Finding("SYNC001", FAMILY, sf.rel, node.lineno,
                                  node.col_offset, msg, _HINT)

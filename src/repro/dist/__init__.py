"""repro.dist — sharding & distributed execution.

Layering: models annotate with :func:`constrain` and the :data:`BATCH`
contract (api), launchers pick parameter layouts (sharding), pipeline/
compression/collectives are the execution primitives the integration
programs under ``tests/dist_progs/`` exercise on 8 fake devices and
``launch/dryrun.py`` lowers on 512.
"""
from .api import (  # noqa: F401
    BATCH,
    batch_axes,
    constrain,
    current_abstract_mesh,
)
from .collectives import expert_all_to_all, reshard, reshard_tree  # noqa: F401
from .compression import compressed_pmean, compressed_pmean_ef  # noqa: F401
from .pipeline import pipeline_apply  # noqa: F401
from .sharding import param_pspecs, param_shardings  # noqa: F401

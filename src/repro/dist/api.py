"""The axis-name contract between models and launchers.

Models never name concrete mesh axes for the batch dimension; they annotate
activations with the :data:`BATCH` sentinel and ``constrain`` resolves it
against whatever mesh is active:

  * no active mesh (unit tests, single device)   -> no-op
  * inside ``shard_map`` (mesh axes are manual)  -> no-op (data already local)
  * under ``jax.set_mesh(mesh)``                 -> ``with_sharding_constraint``
    with axes filtered to the ones the mesh actually has.

This is what lets the same model code run unchanged on 1 device, an 8-fake-
device test mesh, and the 512-chip production mesh.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from .._compat import active_mesh, manual_axis_names

#: mesh axes a batch dimension may shard over, outermost first.
BATCH = ("pod", "data")


def current_abstract_mesh():
    """Mesh made current by ``jax.set_mesh`` / ``with mesh:``, else None."""
    return active_mesh()


def batch_axes() -> tuple[str, ...]:
    """The BATCH contract filtered to the active mesh's axes."""
    mesh = active_mesh()
    if mesh is None:
        return BATCH
    return tuple(a for a in BATCH if a in mesh.axis_names)


def _resolve(entry, avail: set, used: set):
    """One PartitionSpec entry: sentinel tuple / axis name / None."""
    if entry is None:
        return None
    if isinstance(entry, (tuple, list)):
        picked = tuple(a for a in entry if a in avail and a not in used)
        used.update(picked)
        return picked if picked else None
    if entry in avail and entry not in used:
        used.add(entry)
        return entry
    return None


def constrain(x, *spec):
    """``with_sharding_constraint`` iff a mesh is active and we are not inside
    a manual (shard_map) region.  ``spec`` entries are per-dimension: an axis
    name, a tuple of axis names (e.g. :data:`BATCH`), or None.  A spec whose
    length doesn't match ``x.ndim`` (e.g. the same helper called under vmap)
    is a no-op rather than an error."""
    mesh = active_mesh()
    if mesh is None or len(spec) != x.ndim:
        return x
    manual = manual_axis_names()
    if manual & set(mesh.axis_names):
        return x  # inside shard_map: shards are already local arrays
    avail = set(mesh.axis_names)
    used: set = set()
    pspec = P(*[_resolve(e, avail, used) for e in spec])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, pspec))

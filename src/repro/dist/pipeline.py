"""GPipe-style pipeline parallelism over a mesh axis.

The stage stack is sharded over the pipeline axis (one stage per device);
microbatches stream through with activations handed to the next stage by
``ppermute``.  The schedule is the classic GPipe fill/steady/drain ramp:
``M + S - 1`` ticks for ``M`` microbatches over ``S`` stages.  Everything is
one ``lax.scan`` inside one ``shard_map``, so it jits, differentiates
(``ppermute`` transposes to the reverse permutation — backward is the same
pipeline run in reverse), and shows up in the dry-run HLO as exactly one
collective-permute per tick.

Bubble fraction is (S-1)/(M+S-1); callers pick M accordingly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def pipeline_apply(stage_fn, stage_params, x, mesh, axis_name: str | None = None):
    """Run ``x`` through ``S`` pipeline stages.

    stage_fn:     ``(stage_params_slice, h) -> h`` for ONE stage.
    stage_params: pytree whose leaves have leading dim ``S`` (stage-stacked);
                  sharded one-stage-per-device over ``axis_name``.
    x:            ``(M, MB, ...)`` — M microbatches, replicated.
    mesh:         mesh containing the pipeline axis.

    Returns the ``(M, MB, ...)`` outputs of the final stage (replicated).
    """
    axis_name = axis_name or mesh.axis_names[0]
    n_stages = mesh.shape[axis_name]
    n_micro = x.shape[0]
    if jax.tree_util.tree_leaves(stage_params)[0].shape[0] != n_stages:
        raise ValueError(
            f"stage_params leading dim must equal mesh axis size {n_stages}")

    fwd = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def island(w, xs):
        w_local = jax.tree.map(lambda a: a[0], w)  # this device's stage
        stage = jax.lax.axis_index(axis_name)
        state = jnp.zeros(xs.shape[1:], xs.dtype)  # activation in flight
        outs = jnp.zeros_like(xs)

        def tick(carry, t):
            state, outs = carry
            # stage 0 injects microbatch t; others consume the handed-off state
            mb = jnp.clip(t, 0, n_micro - 1)
            inp = jnp.where(stage == 0, xs[mb], state)
            out = stage_fn(w_local, inp)
            # last stage finished microbatch t-(S-1) this tick
            done = t - (n_stages - 1)
            write = (stage == n_stages - 1) & (done >= 0)
            outs = jnp.where(write, outs.at[jnp.clip(done, 0, n_micro - 1)].set(out), outs)
            state = jax.lax.ppermute(out, axis_name, fwd)
            return (state, outs), None

        (_, outs), _ = jax.lax.scan(
            tick, (state, outs), jnp.arange(n_micro + n_stages - 1))
        # results live on the last stage only; replicate them
        return jax.lax.psum(jnp.where(stage == n_stages - 1, outs, 0.0), axis_name)

    in_specs = (jax.tree.map(lambda _: P(axis_name), stage_params), P())
    return jax.shard_map(island, mesh=mesh, in_specs=in_specs, out_specs=P(),
                         check_vma=False)(stage_params, x)

"""Shared collective / resharding helpers.

``expert_all_to_all`` is the MoE dispatch primitive (tokens bucketed by
destination shard exchange over the ``model`` axis — see ``models/moe.py``);
``reshard`` is the elastic-checkpoint primitive (place a host tree onto an
arbitrary target sharding, growing or shrinking the mesh — see
``checkpoint/store.py``).
"""
from __future__ import annotations

from typing import Any

import jax


def expert_all_to_all(x: jax.Array, axis_name: str, *, split_axis: int = 0,
                      concat_axis: int = 0) -> jax.Array:
    """Tiled all-to-all over ``axis_name``: row-block i of this shard goes to
    shard i.  Shape is preserved; ``x.shape[split_axis]`` must divide by the
    axis size.  Must be called inside ``shard_map``."""
    return jax.lax.all_to_all(x, axis_name, split_axis=split_axis,
                              concat_axis=concat_axis, tiled=True)


def reshard(leaves: list, shardings: list | None) -> list:
    """Place host arrays onto target shardings (one device_put per leaf).

    ``shardings`` None (or a None entry) leaves that array on the default
    device.  This is the whole elasticity story: restoring onto a bigger or
    smaller mesh than the one that saved is just a different target here.
    """
    if shardings is None:
        return [jax.numpy.asarray(a) for a in leaves]
    return [jax.numpy.asarray(a) if s is None else jax.device_put(a, s)
            for a, s in zip(leaves, shardings)]


def reshard_tree(tree: Any, shardings: Any) -> Any:
    """Pytree convenience wrapper over :func:`reshard`."""
    flat, treedef = jax.tree_util.tree_flatten(tree)
    sh_flat = None if shardings is None else treedef.flatten_up_to(shardings)
    return jax.tree_util.tree_unflatten(treedef, reshard(flat, sh_flat))

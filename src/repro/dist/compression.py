"""Compressed gradient collectives: int8 all-reduce with stochastic rounding.

Data-parallel gradient sync is the bandwidth hog of sharded training; the
same insight the paper applies to weights (low-precision storage, full-
precision math) applies to the wire.  Each shard quantizes its local
gradient to symmetric int8 (mirroring ``core/quant.py``'s symmetric scheme,
8-bit instead of 4 because gradients are one-shot, not amortized), the
all-reduce moves int8, and the mean is decoded at full precision:

    scale = pmax(|g|) / 127          (shared: decoders must agree)
    q     = stoch_round(g / scale)   (unbiased: E[q] = g/scale)
    mean  = psum(q) * scale / N

Per-element error is bounded by one quantum (scale) and is zero-mean, so
SGD sees an unbiased gradient with ~4x less all-reduce traffic than f32.
An error-feedback variant re-injects each shard's local rounding residual
into its next contribution (Seide et al. 2014), making the *accumulated*
error bounded rather than a random walk.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

QMAX = 127  # symmetric int8


def _stochastic_round(y: jax.Array, key: jax.Array) -> jax.Array:
    lo = jnp.floor(y)
    return lo + (jax.random.uniform(key, y.shape) < (y - lo)).astype(y.dtype)


def _pmean_leaf(g, key, axis_name, n):
    gf = g.astype(jnp.float32)
    amax = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis_name)
    scale = jnp.maximum(amax, 1e-30) / QMAX
    q = jnp.clip(_stochastic_round(gf / scale, key), -QMAX, QMAX).astype(jnp.int8)
    mean = jax.lax.psum(q.astype(jnp.float32), axis_name) * (scale / n)
    return mean.astype(g.dtype), (gf - q.astype(jnp.float32) * scale).astype(g.dtype)


def compressed_pmean(tree, axis_name: str, key: jax.Array):
    """Mean of ``tree`` over ``axis_name`` via int8-quantized all-reduce.

    Call inside ``shard_map`` with ``tree`` holding this shard's local
    gradients.  Unbiased over ``key``; per-element error ≤ pmax(|g|)/127.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    n = jax.lax.psum(1, axis_name)
    out = [_pmean_leaf(g, jax.random.fold_in(key, i), axis_name, n)[0]
           for i, g in enumerate(leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)


def compressed_pmean_ef(tree, axis_name: str, key: jax.Array, error=None):
    """Error-feedback variant: returns ``(mean_tree, new_error_tree)``.

    ``error`` is the residual tree returned by the previous step (None on
    step 0); it is added to the local gradient before quantization so
    rounding error can't accumulate across steps.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    errs = ([jnp.zeros_like(g) for g in leaves] if error is None
            else jax.tree_util.tree_leaves(error))
    n = jax.lax.psum(1, axis_name)
    means, new_errs = [], []
    for i, (g, e) in enumerate(zip(leaves, errs)):
        m, r = _pmean_leaf(g + e, jax.random.fold_in(key, i), axis_name, n)
        means.append(m)
        new_errs.append(r)
    return (jax.tree_util.tree_unflatten(treedef, means),
            jax.tree_util.tree_unflatten(treedef, new_errs))

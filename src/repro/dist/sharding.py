"""Parameter PartitionSpecs over ``("data", "model")`` (optionally "pod") meshes.

Role-aware rules, derived from the pytree path (the same names ``models/``
uses when building params):

  * TT cores ``.../cores/k``: shard the **last** dim — the ``m_k · r_{k+1}``
    output dim of the matrix-layout core — over ``model``.  The staged
    contraction (and the Pallas ``tt_linear`` kernel) contracts over the
    *row* dim ``r_k · n_k``, so an output-dim shard computes its slice of
    every stage locally; no collective inside the TT segment.
  * embedding ``table``: vocab over ``model`` (GSPMD turns the masked
    lookup into local-gather + AllReduce).
  * column-parallel roles (wq/wk/wv/up/gate/router/head): out-features over
    ``model``; row-parallel roles (wo/down): in-features over ``model``
    (Megatron pairing — one AllReduce per block).
  * int4 ``qweight``/``scales``: out-features over ``model`` (the packed
    in-dim must stay whole for nibble unpacking).
  * stacked MoE ``experts``: expert dim over ``model`` (matches the
    ``shard_map`` in_specs of the EP path, so dispatch needs no reshard).
  * ``fsdp=True`` additionally shards one remaining dim over ``data``
    (ZeRO-3 flavored); the leading layer-stack dim of scanned segments is
    never sharded (scan slices it every iteration).

An axis is only assigned where the dim size divides the axis size — anything
else stays replicated, so every spec is always legal for ``device_put``.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

_COL_ROLES = {"wq", "wk", "wv", "up", "gate", "router", "head"}
_ROW_ROLES = {"wo", "down"}


def _path_parts(path) -> list[str]:
    out = []
    for p in path:
        out.append(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))))
    return out


def _model_dim(parts: list[str], shape) -> int | None:
    """Preferred dim to shard over `model` for this leaf, or None."""
    nd = len(shape)
    if nd == 0:
        return None
    if "experts" in parts and nd >= 3:
        # (E, ...) standalone or (L, E, ...) layer-stacked
        return 1 if nd >= 4 else 0
    if "cores" in parts:
        return nd - 1
    if "table" in parts:
        return 0
    if "qweight" in parts or "scales" in parts:
        return max(nd - 2, 0)
    leaf = parts[-1]
    role = parts[-2] if len(parts) >= 2 else ""
    if leaf == "w":
        if role in _ROW_ROLES:
            return nd - 2 if nd >= 2 else None
        if role in _COL_ROLES:
            return nd - 1
        return nd - 1 if nd >= 2 else None
    return None  # biases, norm scales, cache pos, ... stay model-replicated


def _leaf_pspec(parts: list[str], shape, msize: int, dsize: int, fsdp: bool) -> P:
    nd = len(shape)
    axes: list = [None] * nd
    stack_dims = {0} if nd >= 3 else set()  # scanned layer stacks stay whole

    md = _model_dim(parts, shape)
    if md is not None and msize > 1 and shape[md] % msize == 0 and md not in stack_dims:
        axes[md] = "model"
    if fsdp and dsize > 1:
        # largest remaining dim divisible by the data-axis size
        cands = [d for d in range(nd)
                 if axes[d] is None and d not in stack_dims and shape[d] % dsize == 0]
        if cands:
            axes[max(cands, key=lambda d: shape[d])] = "data"
    return P(*axes)


def param_pspecs(params, mesh, fsdp: bool = True):
    """PartitionSpec tree for a parameter pytree (arrays or ShapeDtypeStructs)."""
    msize = mesh.shape["model"] if "model" in mesh.axis_names else 1
    dsize = mesh.shape["data"] if "data" in mesh.axis_names else 1
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [_leaf_pspec(_path_parts(path), leaf.shape, msize, dsize, fsdp)
             for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(params, mesh, fsdp: bool = True):
    """Same tree as :func:`param_pspecs` but as NamedShardings on ``mesh``."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_pspecs(params, mesh, fsdp),
                        is_leaf=lambda x: isinstance(x, P))

"""w4a16 matmul Pallas TPU kernel (paper's FP16×INT4 DSP-shared PEs, §IV).

TPU adaptation: the DSP trick packs two INT4 weights through one 27×18
multiplier; the MXU has no sub-8-bit mode, so we keep the *intent* — halve
weight HBM traffic — by shipping weights as packed nibbles (uint8, 2/byte)
plus per-group scales, and unpacking + dequantizing *inside* the kernel after
the HBM->VMEM copy.  The dequantized tile lives only in VMEM; the matmul runs
at full bf16 MXU throughput.

Grid tiles (tokens × out-features); the contraction dim K is kept whole in
VMEM (our layer K ≤ 16384 at block sizes 128/256 stays under budget).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .epilogue import apply_epilogue


def _kernel(x_ref, qw_ref, sc_ref, *refs, group: int, has_scale: bool,
            has_bias: bool, has_res: bool, activation: str | None, out_dtype):
    x = x_ref[...]  # (bb, K)
    qw = qw_ref[...]  # (bm, K//2) uint8 packed
    sc = sc_ref[...]  # (bm, K//group)
    rest = list(refs[:-1])
    out_ref = refs[-1]
    bm, kh = qw.shape
    k = kh * 2
    lo = (qw & 0x0F).astype(jnp.int8)
    hi = ((qw >> 4) & 0x0F).astype(jnp.int8)
    lo = jnp.where(lo > 7, lo - 16, lo)
    hi = jnp.where(hi > 7, hi - 16, hi)
    w = jnp.stack([lo, hi], axis=-1).reshape(bm, k)  # interleave nibbles
    w = w.reshape(bm, k // group, group).astype(jnp.float32) * \
        sc[..., None].astype(jnp.float32)
    w = w.reshape(bm, k)
    y = jax.lax.dot_general(x.astype(jnp.float32), w,
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    i = 0
    ep_scale = ep_bias = ep_res = None
    if has_scale:
        ep_scale, i = rest[i][...], i + 1
    if has_bias:
        ep_bias, i = rest[i][...], i + 1
    if has_res:
        ep_res = rest[i][...]
    y = apply_epilogue(y, scale=ep_scale, bias=ep_bias, residual=ep_res,
                       activation=activation)
    out_ref[...] = y.astype(out_dtype)


def int4_matmul_pallas(x: jax.Array, qweight: jax.Array, scales: jax.Array, *,
                       group: int = 128, block_b: int = 128, block_m: int = 128,
                       scale: jax.Array | None = None,
                       bias: jax.Array | None = None,
                       residual: jax.Array | None = None,
                       activation: str | None = None,
                       interpret: bool = True) -> jax.Array:
    """y = act(x @ dequant(qweight)^T [* scale] [+ bias]) [+ residual].

    x: (B, K) -> (B, M).  The epilogue operands mirror the TT kernel's
    fused TTDLinear-BN(-Res) post-ops (scale/bias: (M,), residual: (B, M)).
    """
    b, k = x.shape
    m = qweight.shape[0]
    assert qweight.shape == (m, k // 2), (qweight.shape, (m, k // 2))
    assert scales.shape == (m, k // group)

    bb = min(block_b, _pow2_floor(b))
    bm = min(block_m, _pow2_floor(m))
    pad_b, pad_m = (-b) % bb, (-m) % bm
    if pad_b:
        x = jnp.pad(x, ((0, pad_b), (0, 0)))
        if residual is not None:
            residual = jnp.pad(residual, ((0, pad_b), (0, 0)))
    if pad_m:
        qweight = jnp.pad(qweight, ((0, pad_m), (0, 0)))
        scales = jnp.pad(scales, ((0, pad_m), (0, 0)))
        scale = jnp.pad(scale, (0, pad_m)) if scale is not None else None
        bias = jnp.pad(bias, (0, pad_m)) if bias is not None else None
        if residual is not None:
            residual = jnp.pad(residual, ((0, 0), (0, pad_m)))
    nb, nm = x.shape[0] // bb, qweight.shape[0] // bm

    in_specs = [
        pl.BlockSpec((bb, k), lambda i, j: (i, 0)),
        pl.BlockSpec((bm, k // 2), lambda i, j: (j, 0)),
        pl.BlockSpec((bm, k // group), lambda i, j: (j, 0)),
    ]
    extra = []
    for vec in (scale, bias):
        if vec is not None:
            extra.append(vec)
            in_specs.append(pl.BlockSpec((bm,), lambda i, j: (j,)))
    if residual is not None:
        extra.append(residual)
        in_specs.append(pl.BlockSpec((bb, bm), lambda i, j: (i, j)))

    out = pl.pallas_call(
        functools.partial(_kernel, group=group, has_scale=scale is not None,
                          has_bias=bias is not None, has_res=residual is not None,
                          activation=activation, out_dtype=x.dtype),
        grid=(nb, nm),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bb, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], qweight.shape[0]), x.dtype),
        interpret=interpret,
    )(x, qweight, scales, *extra)
    return out[:b, :m] if (pad_b or pad_m) else out


def _pow2_floor(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p

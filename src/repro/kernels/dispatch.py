"""Backend-dispatching linear execution layer.

Every linear in the model zoo — dense, Tensor-Train (paper §II), int4 w4a16
(paper §IV) — routes through this module, which picks an execution backend
and carries the fused epilogue operands (scale, bias, residual, activation —
the paper's TTDLinear-BN(-Res) operator fusion, §III.A) all the way into the
kernel instead of applying them as separate HBM round-trips.

Backends
--------
``ref``              pure-JAX staged contraction / dequant matmul (CPU, and
                     the oracle every kernel is tested against)
``pallas-interpret`` the Pallas kernels executed by the Pallas interpreter
                     (CPU validation of the exact kernel body)
``pallas``           the Pallas kernels lowered via Mosaic (real TPU)
``auto``             ``pallas`` when ``jax.default_backend() == "tpu"``,
                     else ``ref``

Resolution order (first non-empty wins; ``auto`` then resolves per device):

    explicit call arg > ``backend_override()`` context > per-role env
    (``REPRO_KERNEL_BACKEND_<ROLE>``) > ``REPRO_KERNEL_BACKEND`` env >
    ``ModelConfig.kernel_backend`` (carried on ``LinearSpec.backend``) > auto

Resolution happens at trace time (backends are static), so a jitted step
bakes in whatever policy was active when it was first traced.

The dense kind has no Pallas kernel on purpose: XLA's native matmul already
saturates the MXU, and the epilogue below fuses into it; the backend argument
is accepted for uniformity and ignored.
"""
from __future__ import annotations

import contextlib
import contextvars
import os
import re
import time

import jax
import jax.numpy as jnp

from ..core.ttd import TTSpec
from ..obs import ENV_KERNEL_TIMING, MetricsRegistry
from . import ref
from .epilogue import apply_epilogue
from .int4_matmul import int4_matmul_pallas
from .paged_attention import paged_attention_pallas
from .prefill_attention import prefill_attention_pallas
from .scan_rglru import rglru_scan_pallas
from .scan_wkv import wkv_scan_pallas
from .tt_embed import tt_embed_pallas
from .tt_linear import tt_linear_pallas

BACKENDS = ("ref", "pallas-interpret", "pallas")
ENV_VAR = "REPRO_KERNEL_BACKEND"

_override: contextvars.ContextVar[str | None] = contextvars.ContextVar(
    "repro_kernel_backend_override", default=None)


def _check(backend: str) -> str:
    if backend not in BACKENDS + ("auto",):
        raise ValueError(f"unknown kernel backend {backend!r}; "
                         f"expected one of {BACKENDS + ('auto',)}")
    return backend


@contextlib.contextmanager
def backend_override(backend: str | None):
    """Force a backend for everything traced inside the context."""
    if backend is None:
        yield
        return
    token = _override.set(_check(backend))
    try:
        yield
    finally:
        _override.reset(token)


def _role_env(role: str) -> str | None:
    if not role:
        return None
    return os.environ.get(f"{ENV_VAR}_{re.sub(r'[^A-Za-z0-9]', '_', role).upper()}")


def resolve_backend(explicit: str | None = None, *, role: str = "",
                    preferred: str = "") -> str:
    """Resolve the policy chain to a concrete backend name."""
    for cand in (explicit, _override.get(), _role_env(role),
                 os.environ.get(ENV_VAR), preferred or None):
        if cand:
            cand = _check(cand)
            if cand != "auto":
                return cand
            break  # an explicit "auto" stops the chain and resolves by device
    return "pallas" if jax.default_backend() == "tpu" else "ref"


# ---------------------------------------------------------------------------
# Dispatch observability (DESIGN.md §9).  ``resolve_backend`` runs at trace
# time, so a "dispatch" here means one trace-time resolution (or one eager
# call) — NOT one executed device launch of a cached jitted program.  That is
# exactly what the consumers need: ``resolved_backend(role)`` answers "which
# backend did the program that actually traced in this process bake in?",
# replacing benchmark self-reports of the *requested* backend.  Counters and
# (opt-in) wall-time histograms live in a module-local zero-dep registry so
# recording costs a dict lookup + float add and never touches the device;
# the ``REPRO_OBS_KERNEL_TIMING=1`` fence only ever fires on *eager* calls —
# under a jit trace the inputs are Tracers and the fence is skipped, keeping
# the no-device-syncs overhead contract.
# ---------------------------------------------------------------------------
_METRICS = MetricsRegistry()
_LAST_RESOLVED: dict[str, str] = {}


def kernel_metrics() -> MetricsRegistry:
    """Registry holding ``kernel_dispatch_total{role,backend}`` counters and
    (with ``REPRO_OBS_KERNEL_TIMING=1``) ``kernel_wall_seconds`` histograms."""
    return _METRICS


def resolved_backend(role: str) -> str | None:
    """Backend most recently resolved for ``role`` in this process (what a
    traced program actually baked in), or ``None`` if never dispatched."""
    return _LAST_RESOLVED.get(role)


def dispatch_counts() -> dict[tuple[str, str], int]:
    """{(role, resolved backend): trace-time dispatch count}."""
    return {(lab["role"], lab["backend"]): int(m.value)
            for name, lab, m in _METRICS.collect()
            if name == "kernel_dispatch_total"}


def reset_dispatch_metrics() -> None:
    _METRICS.reset()
    _LAST_RESOLVED.clear()


def _timing_t0(x):
    """perf_counter start stamp, or None when timing is off / under a trace."""
    if not os.environ.get(ENV_KERNEL_TIMING, "") or \
            os.environ.get(ENV_KERNEL_TIMING) in ("0", "false", "no", "off"):
        return None
    if isinstance(x, jax.core.Tracer):
        return None
    return time.perf_counter()


def _record_dispatch(role: str, backend: str, out, t0):
    """Count the (role, backend) dispatch; fence + time it when requested."""
    _LAST_RESOLVED[role] = backend
    _METRICS.counter("kernel_dispatch_total", role=role, backend=backend).inc()
    if t0 is not None:
        jax.block_until_ready(out)
        _METRICS.histogram("kernel_wall_seconds", role=role,
                           backend=backend).observe(time.perf_counter() - t0)
    return out


# ---------------------------------------------------------------------------
# Dispatched ops.  All accept (..., N) inputs (leading dims flattened for the
# kernel grids) and the full epilogue operand set; all return x.dtype.
# ---------------------------------------------------------------------------
def dense_linear(x, w, *, scale=None, bias=None, residual=None,
                 activation: str | None = None, backend: str | None = None,
                 role: str = ""):
    """y = act(x W [* scale] [+ b]) [+ residual];  (…, N) @ (N, M).

    Epilogue runs on the f32 accumulator (XLA fuses it into the matmul);
    ``backend`` is ignored — see module docstring (the dispatch counter
    records the honest ``xla`` label).
    """
    del backend
    t0 = _timing_t0(x)
    y = jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    y = apply_epilogue(y, scale=scale, bias=bias, residual=residual,
                       activation=activation)
    return _record_dispatch(role or "dense", "xla", y.astype(x.dtype), t0)


def tt_linear(x, cores, spec: TTSpec, *, scale=None, bias=None, residual=None,
              activation: str | None = None, backend: str | None = None,
              block_b: int | None = None, role: str = ""):
    """(…, N) -> (…, M) through the staged TT contraction + fused epilogue."""
    backend = resolve_backend(backend, role=role)
    t0 = _timing_t0(x)
    if backend == "ref":
        # keep leading dims intact: activation sharding (batch→data,
        # seq→model) propagates untouched through the stages (DESIGN.md §4)
        y = ref.tt_linear_bn_res(x, cores, spec, scale=scale, bias=bias,
                                 residual=residual, activation=activation)
    else:
        lead = x.shape[:-1]
        xf = x.reshape(-1, spec.n_in)
        rf = residual.reshape(-1, spec.n_out) if residual is not None else None
        y = tt_linear_pallas(xf, cores, spec, scale=scale, bias=bias,
                             residual=rf, activation=activation,
                             block_b=block_b,
                             interpret=(backend == "pallas-interpret"))
        y = y.reshape(*lead, spec.n_out)
    return _record_dispatch(role or "tt", backend, y, t0)


def tt_embed(ids, cores, spec: TTSpec, *, backend: str | None = None,
             role: str = "embed_lookup"):
    """Row gather of a TT-compressed embedding table (TensorGPT layout).

    ids: int32 of any shape (padding ids resolve like the dense
    ``jnp.take`` path: negative wrap once, then clamp into range);
    returns (…, D) f32 rows of the (V, D) table
    the cores describe — ``spec`` has M = V, N = D.  ``ref`` runs the
    digit-indexed chain in ``kernels/ref.py``; the Pallas backends the
    one-hot-gather tile kernel (``kernels/tt_embed.py``).
    """
    backend = resolve_backend(backend, role=role)
    t0 = _timing_t0(ids)
    if backend == "ref":
        y = ref.tt_embedding(ids, cores, spec)
    else:
        lead = ids.shape
        flat = jnp.asarray(ids, jnp.int32).reshape(-1)
        y = tt_embed_pallas(flat, cores, spec,
                            interpret=(backend == "pallas-interpret"))
        y = y.reshape(*lead, spec.n_in)
    return _record_dispatch(role, backend, y, t0)


def paged_attention(q, cache, block_tables, qpos, *, sm_scale=None,
                    backend: str | None = None, role: str = "attn_paged"):
    """Decode attention through a paged KV cache's block table.

    q: (B, H, Dh) — one query token per sequence; qpos: (B,) absolute
    positions (-1 = inactive row → zeros).  ``ref`` gathers the context and
    runs the masked-softmax oracle; the Pallas backends run the fused
    online-softmax kernel (``kernels/paged_attention.py``).  Chunked prefill
    (Sq > 1) goes through :func:`prefill_attention` instead.
    """
    backend = resolve_backend(backend, role=role)
    t0 = _timing_t0(q)
    if backend == "ref":
        y = ref.paged_attention(q[:, None], cache, block_tables,
                                qpos[:, None], sm_scale=sm_scale)[:, 0]
    else:
        y = paged_attention_pallas(q, cache, block_tables, qpos,
                                   sm_scale=sm_scale,
                                   interpret=(backend == "pallas-interpret"))
    return _record_dispatch(role, backend, y, t0)


def prefill_attention(q, qpos, *, cache=None, block_tables=None, k=None,
                      v=None, kpos=None, window: int = 0, sm_scale=None,
                      k_scale=None, v_scale=None,
                      backend: str | None = None, role: str = "attn_prefill"):
    """Ragged chunked-prefill attention over a paged pool or per-slot rings.

    q: (B, Sq, H, Dh); qpos: (B, Sq) absolute positions (``-1`` = padding
    row → zeros).  Pass either ``cache`` + ``block_tables`` (paged layout)
    or ``k``/``v`` + ``kpos`` (ring layout — ``kpos`` ``-1`` = empty entry).
    ``ref`` runs the gather/masked-softmax oracles in ``kernels/ref.py``;
    the Pallas backends run the fused streaming kernel
    (``kernels/prefill_attention.py``) — same policy chain as
    ``paged_attention``, resolved at trace time.

    Ring layout optionally carries int8 ``k``/``v`` with per-entry-per-head
    f32 ``k_scale``/``v_scale`` (B, Wr, Hkv) tables; dequantization is fused
    into the kernel's tile loads.
    """
    backend = resolve_backend(backend, role=role)
    paged = cache is not None or block_tables is not None
    ring = k is not None or v is not None or kpos is not None
    if paged == ring:
        raise ValueError("prefill_attention takes exactly one layout: "
                         "cache+block_tables (paged) or k/v/kpos (ring)")
    if paged and (cache is None or block_tables is None):
        raise ValueError("paged layout needs both cache and block_tables")
    if ring and (k is None or v is None or kpos is None):
        raise ValueError("ring layout needs all of k, v and kpos")
    if (k_scale is None) != (v_scale is None):
        raise ValueError("k_scale and v_scale must be passed together")
    if k_scale is not None and not ring:
        raise ValueError("k_scale/v_scale are ring-layout only")
    t0 = _timing_t0(q)
    if paged:
        if backend == "ref":
            y = ref.paged_attention(q, cache, block_tables, qpos,
                                    sm_scale=sm_scale, window=window)
        else:
            y = prefill_attention_pallas(
                q, qpos, cache=cache, block_tables=block_tables, window=window,
                sm_scale=sm_scale, interpret=(backend == "pallas-interpret"))
    elif backend == "ref":
        y = ref.ring_attention(q, k, v, qpos, kpos, window=window,
                               sm_scale=sm_scale, k_scale=k_scale,
                               v_scale=v_scale)
    else:
        y = prefill_attention_pallas(
            q, qpos, k=k, v=v, kpos=kpos, window=window, sm_scale=sm_scale,
            k_scale=k_scale, v_scale=v_scale,
            interpret=(backend == "pallas-interpret"))
    return _record_dispatch(role, backend, y, t0)


def rglru_scan(log_a, gx, h0, pos=None, *, scan_dtype=None,
               backend: str | None = None, role: str = "rglru_scan"):
    """Fused RG-LRU recurrence ``h_t = a h_{t-1} + sqrt(1-a²)(i ⊙ u)``.

    log_a/gx: (B, S, W) pre-gate log-decay and gated input; h0: (B, W) f32
    carried state; pos: (B, S) absolute positions (``-1`` = padding step →
    exact state passthrough; a fully ``-1`` row keeps ``h0`` bitwise).
    Returns ``(h (B, S, W) scan_dtype, h_last (B, W) f32)``.  ``ref`` runs
    the ``associative_scan`` oracle; the Pallas backends keep the state
    resident on-chip (``kernels/scan_rglru.py``) — S == 1 takes the fused
    masked decode-step kernel batching all slots.
    """
    if log_a.shape != gx.shape or log_a.ndim != 3:
        raise ValueError(f"log_a/gx must both be (B, S, W); got "
                         f"{log_a.shape} vs {gx.shape}")
    if h0.shape != (log_a.shape[0], log_a.shape[2]):
        raise ValueError(f"h0 must be (B, W) = {(log_a.shape[0], log_a.shape[2])}; "
                         f"got {h0.shape}")
    backend = resolve_backend(backend, role=role)
    t0 = _timing_t0(log_a)
    if backend == "ref":
        out = ref.rglru_scan(log_a, gx, h0, pos, scan_dtype=scan_dtype)
    else:
        out = rglru_scan_pallas(log_a, gx, h0, pos, scan_dtype=scan_dtype,
                                interpret=(backend == "pallas-interpret"))
    return _record_dispatch(role, backend, out, t0)


def wkv_scan(r, k, v, w, u, state0, pos=None, *, state_scale=None,
             backend: str | None = None, role: str = "wkv_scan"):
    """Fused RWKV6 wkv recurrence over per-(slot, head) matrix state.

    r/k/v/w: (B, S, H, hd); u: (H, hd); state0: (B, H, hd, hd) f32 — or int8
    with per-(slot, head) f32 ``state_scale`` (B, H) fused into the kernel's
    state load/store; pos: (B, S) absolute positions (``-1`` = padding →
    identity step; a fully ``-1`` row keeps state *and* scale bitwise).
    Returns ``(y (B, S, H, hd) f32, new_state, new_scale-or-None)``.  S > 1
    takes the chunked-parallel matmul form (short prompts are padded to a
    chunk multiple, so a single chunk qualifies too); S == 1 the fused
    masked decode step.
    """
    if r.shape != k.shape or r.shape != v.shape or r.shape != w.shape \
            or r.ndim != 4:
        raise ValueError("r/k/v/w must share one (B, S, H, hd) shape; got "
                         f"{r.shape}/{k.shape}/{v.shape}/{w.shape}")
    if state0.shape != (r.shape[0], r.shape[2], r.shape[3], r.shape[3]):
        raise ValueError(f"state0 must be (B, H, hd, hd); got {state0.shape}")
    if (state_scale is None) != (state0.dtype != jnp.int8):
        raise ValueError("int8 state0 requires state_scale (and vice versa); "
                         f"got state0 {state0.dtype} with state_scale "
                         f"{'set' if state_scale is not None else 'None'}")
    backend = resolve_backend(backend, role=role)
    t0 = _timing_t0(r)
    if backend == "ref":
        out = ref.wkv_scan(r, k, v, w, u, state0, pos,
                           state_scale=state_scale)
    else:
        out = wkv_scan_pallas(r, k, v, w, u, state0, pos,
                              state_scale=state_scale,
                              interpret=(backend == "pallas-interpret"))
    return _record_dispatch(role, backend, out, t0)


def int4_matmul(x, qweight, scales, *, group: int = 128, scale=None, bias=None,
                residual=None, activation: str | None = None,
                backend: str | None = None, role: str = ""):
    """(…, K) -> (…, M) through the w4a16 kernel + fused epilogue."""
    backend = resolve_backend(backend, role=role)
    t0 = _timing_t0(x)
    if backend == "ref":
        y = ref.int4_matmul(x, qweight, scales, group=group, scale=scale,
                            bias=bias, residual=residual,
                            activation=activation)
    else:
        lead = x.shape[:-1]
        xf = x.reshape(-1, x.shape[-1])
        rf = (residual.reshape(-1, qweight.shape[0])
              if residual is not None else None)
        y = int4_matmul_pallas(xf, qweight, scales, group=group, scale=scale,
                               bias=bias, residual=rf, activation=activation,
                               interpret=(backend == "pallas-interpret"))
        y = y.reshape(*lead, qweight.shape[0])
    return _record_dispatch(role or "int4", backend, y, t0)

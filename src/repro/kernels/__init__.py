"""Pallas TPU kernels + the backend-dispatching linear execution layer.

``dispatch`` is the public entry: every model linear (dense | tt | int4)
routes through it with fused epilogue operands; ``tt_linear``/``int4_matmul``
hold the kernel bodies, ``ref`` the pure-jnp oracles, ``epilogue`` the shared
post-op semantics.
"""
from .dispatch import (  # noqa: F401
    BACKENDS,
    ENV_VAR,
    backend_override,
    dense_linear,
    dispatch_counts,
    int4_matmul,
    kernel_metrics,
    reset_dispatch_metrics,
    resolve_backend,
    resolved_backend,
    tt_linear,
)

"""Pure-jnp oracles for the Pallas kernels.

Two independent references for the TT kernel:
  * ``tt_linear_staged``  — the staged Eq.-4 contraction (shared with the
    model's pure-JAX path).
  * ``tt_linear_dense``   — reconstruct the dense W from the cores and do a
    plain matmul (the ground truth the staged algorithm itself is tested
    against in tests/test_ttd.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.quant import dequantize_int4
from ..core.tt_linear import tt_linear_apply
from ..core.ttd import TTSpec, matrices_to_cores, tt_reconstruct


def tt_linear_staged(x: jax.Array, cores: list[jax.Array], spec: TTSpec) -> jax.Array:
    return tt_linear_apply({"cores": cores}, x, spec)


def tt_linear_dense(x: jax.Array, cores: list[jax.Array], spec: TTSpec) -> jax.Array:
    w = tt_reconstruct(matrices_to_cores([np.asarray(c, np.float64) for c in cores], spec), spec)
    return (np.asarray(x, np.float64) @ w.T).astype(np.asarray(x).dtype)


def tt_linear_bn_res(x, cores, spec, scale=None, bias=None, residual=None):
    y = tt_linear_staged(x, cores, spec).astype(jnp.float32)
    if scale is not None:
        y = y * scale.astype(jnp.float32) + (bias.astype(jnp.float32) if bias is not None else 0.0)
    if residual is not None:
        y = y + residual.astype(jnp.float32)
    return y.astype(x.dtype)


def int4_matmul(x: jax.Array, qweight: jax.Array, scales: jax.Array,
                group: int = 128) -> jax.Array:
    w = dequantize_int4({"qweight": qweight, "scales": scales}, dtype=jnp.float32)
    return jax.lax.dot_general(
        x.astype(jnp.float32), w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)

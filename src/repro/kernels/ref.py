"""Pure-jnp oracles for the Pallas kernels.

Two independent references for the TT kernel:
  * ``tt_linear_staged``  — the staged Eq.-4 contraction (shared with the
    model's pure-JAX path).
  * ``tt_linear_dense``   — reconstruct the dense W from the cores and do a
    plain matmul (the ground truth the staged algorithm itself is tested
    against in tests/test_ttd.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.quant import dequantize_int4
from ..core.tt_linear import tt_linear_apply
from ..core.ttd import TTSpec, matrices_to_cores, tt_reconstruct
from .epilogue import apply_epilogue


def tt_linear_staged(x: jax.Array, cores: list[jax.Array], spec: TTSpec) -> jax.Array:
    return tt_linear_apply({"cores": cores}, x, spec)


def tt_linear_dense(x: jax.Array, cores: list[jax.Array], spec: TTSpec) -> jax.Array:
    w = tt_reconstruct(matrices_to_cores([np.asarray(c, np.float64) for c in cores], spec), spec)
    return (np.asarray(x, np.float64) @ w.T).astype(np.asarray(x).dtype)


def tt_linear_bn_res(x, cores, spec, scale=None, bias=None, residual=None,
                     activation=None):
    y = tt_linear_staged(x, cores, spec)
    y = apply_epilogue(y, scale=scale, bias=bias, residual=residual,
                       activation=activation)
    return y.astype(x.dtype)


def int4_matmul(x: jax.Array, qweight: jax.Array, scales: jax.Array,
                group: int = 128, *, scale=None, bias=None, residual=None,
                activation=None) -> jax.Array:
    w = dequantize_int4({"qweight": qweight, "scales": scales}, dtype=jnp.float32)
    y = jax.lax.dot_general(
        x.astype(jnp.float32), w, (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    y = apply_epilogue(y, scale=scale, bias=bias, residual=residual,
                       activation=activation)
    return y.astype(x.dtype)

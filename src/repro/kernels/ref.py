"""Pure-jnp oracles for the Pallas kernels.

Two independent references for the TT kernel:
  * ``tt_linear_staged``  — the staged Eq.-4 contraction (shared with the
    model's pure-JAX path).
  * ``tt_linear_dense``   — reconstruct the dense W from the cores and do a
    plain matmul (the ground truth the staged algorithm itself is tested
    against in tests/test_ttd.py).

The recurrent-scan oracles (``rglru_scan`` for griffin's RG-LRU,
``wkv_scan`` for RWKV6's wkv recurrence) also live here: they are the exact
jnp math the model families used to carry inline, demoted to oracle status
now that ``kernels/scan_rglru.py`` / ``kernels/scan_wkv.py`` provide the
fused Pallas paths.  Both follow the serving position convention — ``pos``
(B, S) int32 per-sequence absolute positions with ``-1`` = padding (state
passes through untouched) — and both speak the int8 scale-table state format
(per-row / per-(slot, head) f32 scales, quantize at store, dequantize at
load; DESIGN.md §10).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from ..core.quant import dequantize_int4
from ..core.tt_linear import tt_linear_apply
from ..core.ttd import TTSpec, matrices_to_cores, tt_reconstruct
from .epilogue import apply_epilogue


def tt_linear_staged(x: jax.Array, cores: list[jax.Array], spec: TTSpec) -> jax.Array:
    return tt_linear_apply({"cores": cores}, x, spec)


def tt_linear_dense(x: jax.Array, cores: list[jax.Array], spec: TTSpec) -> jax.Array:
    w = tt_reconstruct(matrices_to_cores([np.asarray(c, np.float64) for c in cores], spec), spec)
    return (np.asarray(x, np.float64) @ w.T).astype(np.asarray(x).dtype)


def tt_linear_bn_res(x, cores, spec, scale=None, bias=None, residual=None,
                     activation=None):
    y = tt_linear_staged(x, cores, spec)
    y = apply_epilogue(y, scale=scale, bias=bias, residual=residual,
                       activation=activation)
    return y.astype(x.dtype)


def tt_embedding(ids: jax.Array, cores: list[jax.Array], spec: TTSpec) -> jax.Array:
    """Gathered-row TT embedding oracle (TensorGPT-style vocab-axis TT).

    The (V, D) table is the TT's (M, N) weight with M = V: row ``i`` of the
    table is row ``i`` of W, so a gather never reconstructs the table —
    each token id is split into its big-endian ``out_modes`` digits
    ``(i_1..i_d)``, digit ``i_k`` selects the ``(r0, n_k, r1)`` slice of
    core matrix ``C_k`` (columns are m-major), and the per-token slices are
    chained left-to-right exactly like ``tt_linear``'s stage contraction.
    ids: int32 of any shape; padding ids follow the dense path's
    ``jnp.take`` semantics — negative ids wrap once (``-1`` is row
    ``V - 1``), anything else clamps into range.  Returns (..., D) f32 rows.
    """
    lead = ids.shape
    flat = jnp.asarray(ids, jnp.int32).reshape(-1)
    flat = jnp.clip(jnp.where(flat < 0, flat + spec.n_out, flat),
                    0, spec.n_out - 1)
    t = flat.shape[0]
    m = spec.out_modes
    p = None
    for k in range(spec.d):
        stride = math.prod(m[k + 1:])
        digit = (flat // stride) % m[k]
        r0, r1 = spec.ranks[k], spec.ranks[k + 1]
        n_k = spec.in_modes[k]
        c = jnp.asarray(cores[k], jnp.float32).reshape(r0, n_k, m[k], r1)
        sel = jnp.moveaxis(jnp.take(c, digit, axis=2), 2, 0)  # (T, r0, n_k, r1)
        if p is None:
            p = sel.reshape(t, n_k, r1)  # r0 == 1 on the first core
        else:
            p = jnp.einsum("txr,trjs->txjs", p, sel).reshape(t, -1, r1)
    return p.reshape(*lead, spec.n_in)


NEG_INF = -1e30


def gather_paged_kv(cache: dict, block_tables: jax.Array):
    """Gather a sequence-major K/V view out of the paged block pool.

    cache: ``{"k","v": (NB, BS, Hkv, Dh)}`` (+ ``k_scale``/``v_scale``
    ``(NB, BS, Hkv)`` for the int8 cache dtype, dequantized here);
    block_tables: (B, W) int32 ordered logical→physical block ids.
    Returns k, v of shape (B, W*BS, Hkv, Dh) in f32, where gathered index
    ``i`` holds the sequence's absolute position ``i``.
    """
    k = cache["k"][block_tables].astype(jnp.float32)  # (B, W, BS, Hkv, Dh)
    v = cache["v"][block_tables].astype(jnp.float32)
    if "k_scale" in cache:
        k = k * cache["k_scale"][block_tables][..., None]
        v = v * cache["v_scale"][block_tables][..., None]
    b, w, bs, hkv, dh = k.shape
    return k.reshape(b, w * bs, hkv, dh), v.reshape(b, w * bs, hkv, dh)


def paged_attention(q: jax.Array, cache: dict, block_tables: jax.Array,
                    qpos: jax.Array, *, sm_scale: float | None = None,
                    window: int = 0) -> jax.Array:
    """Causal attention of per-sequence queries against a paged KV cache.

    q: (B, Sq, H, Dh) — Sq == 1 is the decode shape, Sq > 1 a prefill chunk.
    qpos: (B, Sq) absolute position of each query token; ``-1`` marks
    padding (output zeros).  Query ``p`` attends to cache positions
    ``0..p`` inclusive (the current token's K/V must already be written),
    further clipped to the last ``window`` positions when ``window > 0``.
    Per-sequence masking makes this the oracle for ragged decode batches —
    unlike ``models.modules.attention_dense`` whose positions are shared
    across the batch.
    """
    b, sq, h, dh = q.shape
    hkv = cache["k"].shape[2]
    g = h // hkv
    sm_scale = sm_scale or (1.0 / math.sqrt(dh))
    k, v = gather_paged_kv(cache, block_tables)  # (B, K, Hkv, Dh) f32
    qh = q.reshape(b, sq, hkv, g, dh).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qh, k) * sm_scale
    kpos = jnp.arange(k.shape[1], dtype=jnp.int32)
    mask = (kpos[None, None, :] <= qpos[:, :, None]) & (qpos >= 0)[:, :, None]
    if window > 0:
        mask &= qpos[:, :, None] - kpos[None, None, :] < window
    maskb = mask[:, None, None]  # (B, 1, 1, Sq, K)
    s = jnp.where(maskb, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m) * maskb  # masked rows: exp(0)=1 zeroed by the mask
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v)
    o = jnp.where(l > 0, o / jnp.maximum(l, 1e-30), 0.0)
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dh).astype(q.dtype)


def ring_attention(q: jax.Array, k: jax.Array, v: jax.Array, qpos: jax.Array,
                   kpos: jax.Array, *, window: int = 0,
                   sm_scale: float | None = None, k_scale=None,
                   v_scale=None) -> jax.Array:
    """Causal attention against per-slot ring caches (the ring-layout oracle).

    q: (B, Sq, H, Dh); k, v: (B, Skv, Hkv, Dh); qpos: (B, Sq) / kpos:
    (B, Skv) per-sequence absolute positions (``-1`` = padding query → zero
    output / empty ring entry → never attended).  ``k_scale``/``v_scale``
    (B, Skv, Hkv) f32 dequantize int8 rings per-(entry, head).  Causal,
    optionally sliding-window — the per-sequence counterpart of
    ``models.modules.attention_dense``, which the tests tie it back to.
    """
    b, sq, h, dh = q.shape
    hkv = k.shape[2]
    g = h // hkv
    sm_scale = sm_scale or (1.0 / math.sqrt(dh))
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    if k_scale is not None:
        k = k * k_scale[..., None]
        v = v * v_scale[..., None]
    qh = q.reshape(b, sq, hkv, g, dh).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qh, k) * sm_scale
    mask = (kpos[:, None, :] >= 0) & (qpos[:, :, None] >= 0) \
        & (kpos[:, None, :] <= qpos[:, :, None])
    if window > 0:
        mask &= qpos[:, :, None] - kpos[:, None, :] < window
    maskb = mask[:, None, None]  # (B, 1, 1, Sq, Skv)
    s = jnp.where(maskb, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m) * maskb
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v)
    o = jnp.where(l > 0, o / jnp.maximum(l, 1e-30), 0.0)
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# RG-LRU gated recurrence (griffin).  Demoted from models/griffin.py:rg_lru —
# the gate linears stay in the model; this is the scan itself.
# ---------------------------------------------------------------------------
def rglru_scan(log_a: jax.Array, gx: jax.Array, h0: jax.Array, pos=None,
               *, scan_dtype=None):
    """Gated linear recurrence ``h_t = a_t h_{t-1} + sqrt(1-a_t²) gx_t``.

    log_a, gx: (B, S, W) f32 — pre-mask log decay (``-c·softplus(Λ)·r``) and
    gated input (``i ⊙ u``); h0: (B, W) f32.  ``pos`` (B, S) int32 marks
    padding steps with ``-1``: a masked step has a = 1 and no input
    contribution, so the state passes through untouched; rows with no real
    step return ``h0`` bitwise.  The scan carries ``scan_dtype`` operands
    (default f32; griffin trains with the compute dtype — halves the scan's
    memory traffic).  Returns (h (B, S, W) scan_dtype, h_last (B, W) f32).
    """
    f32 = jnp.float32
    scan_dtype = scan_dtype or f32
    log_a = log_a.astype(f32)
    gx = gx.astype(f32)
    h0 = h0.astype(f32)
    if pos is not None:
        m = (pos >= 0).astype(f32)[:, :, None]
        log_a = log_a * m  # pads: log a = 0 -> a = 1
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gx
    if pos is not None:
        gated = gated * m  # pads contribute nothing
    gated = gated.at[:, 0].add(a[:, 0] * h0)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(
        combine, (a.astype(scan_dtype), gated.astype(scan_dtype)), axis=1)
    h_last = h[:, -1].astype(f32)
    if pos is not None:
        idle = (pos < 0).all(axis=1)  # fully-idle rows keep h0 bitwise
        h_last = jnp.where(idle[:, None], h0, h_last)
    return h, h_last


# ---------------------------------------------------------------------------
# RWKV6 wkv recurrence.  ``wkv_scan_sequential`` / ``wkv_chunked`` are the
# exact forms demoted from models/rwkv.py; ``wkv_scan`` is the dispatch-facing
# oracle that adds the masking / pad-to-chunk / int8 scale-table contract.
# ---------------------------------------------------------------------------
WKV_CHUNK = 16  # chunked-parallel wkv: scan steps drop S -> ceil(S/WKV_CHUNK).
# 16 keeps the within-chunk cumulative log-decay range <= 16*4.9 < 88 (f32
# exp range) together with the decay floor below.
WKV_LOG_DECAY_FLOOR = -4.9  # w >= 0.0075/step; state is ~0 within 3 steps
# at the floor anyway, so the approximation is practically invisible.


def wkv_scan_sequential(r, k, v, w, u, state0):
    """Sequential recurrence over time (the ground-truth wkv form).

    r,k,v,w: (B,S,H,hd);  u: (H,hd);  state0: (B,H,hd,hd) f32.
    Returns y (B,S,H,hd) f32 and final state.
    """
    def step(s, inp):
        r_t, k_t, v_t, w_t = inp  # (B,H,hd)
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None] [..., None] * kv)
        s_new = w_t[..., None] * s + kv
        return s_new, y

    rs, ks, vs, ws = (jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (r, k, v, w))
    state, ys = jax.lax.scan(step, state0, (rs, ks, vs, ws))
    return jnp.moveaxis(ys, 0, 1), state


def wkv_chunked(r, k, v, w, u, state0, chunk=WKV_CHUNK):
    """Chunked-parallel form of the wkv recurrence (Finch/GLA-style).

    Within a chunk of length C, with per-channel cumulative log-decay
    ``la_t = Σ_{τ≤t} log w_τ`` (la over *preceding* steps inside the chunk):

        y_t = (r_t ⊙ e^{la_t}) S_chunk0
              + Σ_{τ<t} [(r_t ⊙ e^{la_t}) · (k_τ ⊙ e^{-la_{τ+1}})] v_τ
              + (r_t · (u ⊙ k_t)) v_t
        S' = e^{la_C} ⊙ S + Σ_τ (k_τ ⊙ e^{la_C - la_{τ+1}})^T v_τ

    turning S sequential steps into S/C scan steps of batched matmuls (MXU
    work instead of a latency-bound loop).  Exact vs the sequential scan
    (tests/test_rwkv_chunked.py); all math in f32.  ``S`` must be a multiple
    of ``chunk`` — ``wkv_scan`` below pads ragged tails with identity steps.
    """
    b, s, h, hd = r.shape
    nc = s // chunk
    f32 = jnp.float32

    def cshape(t):
        return t.astype(f32).reshape(b, nc, chunk, h, hd)

    rc, kc, vc = cshape(r), cshape(k), cshape(v)
    lw = jnp.clip(jnp.log(jnp.maximum(cshape(w), 1e-38)), WKV_LOG_DECAY_FLOOR, 0.0)
    la_inc = jnp.cumsum(lw, axis=2)  # la_{τ+1}: includes step τ's decay
    la_exc = la_inc - lw  # la_t: decay accumulated before step t
    la_end = la_inc[:, :, -1]  # (b, nc, h, hd)

    r_tld = rc * jnp.exp(la_exc)
    k_tld = kc * jnp.exp(-la_inc)
    k_end = kc * jnp.exp(la_end[:, :, None] - la_inc)  # bounded (<= k)

    scores = jnp.einsum("bnthd,bnshd->bnhts", r_tld, k_tld)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    scores = jnp.where(tri[None, None, None], scores, 0.0)
    diag = jnp.einsum("bnthd,hd,bnthd->bnth", rc, u.astype(f32), kc)
    intra = jnp.einsum("bnhts,bnshd->bnthd", scores, vc) + diag[..., None] * vc

    def chunk_step(s_c, inp):
        r_t, ke, vcc, lae = inp  # (b,chunk,h,hd) x3, (b,h,hd)
        y_inter = jnp.einsum("bthk,bhkv->bthv", r_t, s_c)
        s_new = s_c * jnp.exp(lae)[..., None] + jnp.einsum("bthk,bthv->bhkv", ke, vcc)
        return s_new, y_inter

    xs = (jnp.moveaxis(r_tld, 1, 0), jnp.moveaxis(k_end, 1, 0),
          jnp.moveaxis(vc, 1, 0), jnp.moveaxis(la_end, 1, 0))
    state, y_inter = jax.lax.scan(chunk_step, state0.astype(f32), xs)
    y = intra + jnp.moveaxis(y_inter, 0, 1)
    return y.reshape(b, s, h, hd), state


def quantize_state(state: jax.Array, axes=(-2, -1), eps: float = 1e-8):
    """amax/127 int8 quantization of a recurrent state over ``axes``.

    Returns (q int8, scale f32) with the scale shaped like ``state`` minus
    the reduced axes — the scale-table format every scan backend shares
    (DESIGN.md §10).
    """
    sc = jnp.maximum(jnp.max(jnp.abs(state), axis=axes), eps) / 127.0
    q = jnp.round(state / jnp.expand_dims(sc, axes)).astype(jnp.int8)
    return q, sc


def wkv_scan(r, k, v, w, u, state0, pos=None, *, state_scale=None,
             chunk: int = WKV_CHUNK):
    """Masked wkv recurrence over one chunk call (the dispatch-facing oracle).

    r,k,v,w: (B,S,H,hd); u: (H,hd); state0: (B,H,hd,hd) f32, or int8 with
    ``state_scale`` (B,H) f32 (dequantized at load, requantized at store).
    ``pos`` (B,S) int32 marks padding with ``-1`` — a masked step has
    k = 0 / w = 1, so the state passes through untouched; fully-idle rows
    keep their stored int8 state (and scale) bitwise.  ``S > 1`` runs the
    chunked-parallel form, padding ragged tails up to a ``chunk`` multiple
    with identity steps (so a one-chunk prompt takes the matmul form instead
    of the sequential scan); ``S == 1`` is the exact one-step decode update.
    Returns (y (B,S,H,hd) f32, new_state, new_scale-or-None).
    """
    b, s, h, hd = r.shape
    f32 = jnp.float32
    if pos is not None:
        m3 = (pos >= 0)[:, :, None, None]
        k = jnp.where(m3, k, 0.0)  # pads write nothing into the state
        w = jnp.where(m3, w, 1.0)  # ...and decay nothing away
    s0 = state0.astype(f32)
    if state_scale is not None:
        s0 = s0 * state_scale[..., None, None]
    if s == 1:
        y, st = wkv_scan_sequential(r, k, v, w, u, s0)
    else:
        pad = (-s) % chunk
        if pad:
            ext = ((0, 0), (0, pad), (0, 0), (0, 0))
            r, k, v = (jnp.pad(t, ext) for t in (r, k, v))
            w = jnp.pad(w, ext, constant_values=1.0)  # identity steps
        y, st = wkv_chunked(r, k, v, w, u, s0, chunk=chunk)
        y = y[:, :s]
    if state_scale is None:
        return y, st, None
    q, sc = quantize_state(st)
    if pos is not None:
        idle = (pos < 0).all(axis=1)  # (B,)
        q = jnp.where(idle[:, None, None, None], state0, q)
        sc = jnp.where(idle[:, None], state_scale, sc)
    return y, q, sc


def int4_matmul(x: jax.Array, qweight: jax.Array, scales: jax.Array,
                group: int = 128, *, scale=None, bias=None, residual=None,
                activation=None) -> jax.Array:
    w = dequantize_int4({"qweight": qweight, "scales": scales}, dtype=jnp.float32)
    y = jax.lax.dot_general(
        x.astype(jnp.float32), w, (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    y = apply_epilogue(y, scale=scale, bias=bias, residual=residual,
                       activation=activation)
    return y.astype(x.dtype)

"""Pure-jnp oracles for the Pallas kernels.

Two independent references for the TT kernel:
  * ``tt_linear_staged``  — the staged Eq.-4 contraction (shared with the
    model's pure-JAX path).
  * ``tt_linear_dense``   — reconstruct the dense W from the cores and do a
    plain matmul (the ground truth the staged algorithm itself is tested
    against in tests/test_ttd.py).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from ..core.quant import dequantize_int4
from ..core.tt_linear import tt_linear_apply
from ..core.ttd import TTSpec, matrices_to_cores, tt_reconstruct
from .epilogue import apply_epilogue


def tt_linear_staged(x: jax.Array, cores: list[jax.Array], spec: TTSpec) -> jax.Array:
    return tt_linear_apply({"cores": cores}, x, spec)


def tt_linear_dense(x: jax.Array, cores: list[jax.Array], spec: TTSpec) -> jax.Array:
    w = tt_reconstruct(matrices_to_cores([np.asarray(c, np.float64) for c in cores], spec), spec)
    return (np.asarray(x, np.float64) @ w.T).astype(np.asarray(x).dtype)


def tt_linear_bn_res(x, cores, spec, scale=None, bias=None, residual=None,
                     activation=None):
    y = tt_linear_staged(x, cores, spec)
    y = apply_epilogue(y, scale=scale, bias=bias, residual=residual,
                       activation=activation)
    return y.astype(x.dtype)


NEG_INF = -1e30


def gather_paged_kv(cache: dict, block_tables: jax.Array):
    """Gather a sequence-major K/V view out of the paged block pool.

    cache: ``{"k","v": (NB, BS, Hkv, Dh)}`` (+ ``k_scale``/``v_scale``
    ``(NB, BS, Hkv)`` for the int8 cache dtype, dequantized here);
    block_tables: (B, W) int32 ordered logical→physical block ids.
    Returns k, v of shape (B, W*BS, Hkv, Dh) in f32, where gathered index
    ``i`` holds the sequence's absolute position ``i``.
    """
    k = cache["k"][block_tables].astype(jnp.float32)  # (B, W, BS, Hkv, Dh)
    v = cache["v"][block_tables].astype(jnp.float32)
    if "k_scale" in cache:
        k = k * cache["k_scale"][block_tables][..., None]
        v = v * cache["v_scale"][block_tables][..., None]
    b, w, bs, hkv, dh = k.shape
    return k.reshape(b, w * bs, hkv, dh), v.reshape(b, w * bs, hkv, dh)


def paged_attention(q: jax.Array, cache: dict, block_tables: jax.Array,
                    qpos: jax.Array, *, sm_scale: float | None = None,
                    window: int = 0) -> jax.Array:
    """Causal attention of per-sequence queries against a paged KV cache.

    q: (B, Sq, H, Dh) — Sq == 1 is the decode shape, Sq > 1 a prefill chunk.
    qpos: (B, Sq) absolute position of each query token; ``-1`` marks
    padding (output zeros).  Query ``p`` attends to cache positions
    ``0..p`` inclusive (the current token's K/V must already be written),
    further clipped to the last ``window`` positions when ``window > 0``.
    Per-sequence masking makes this the oracle for ragged decode batches —
    unlike ``models.modules.attention_dense`` whose positions are shared
    across the batch.
    """
    b, sq, h, dh = q.shape
    hkv = cache["k"].shape[2]
    g = h // hkv
    sm_scale = sm_scale or (1.0 / math.sqrt(dh))
    k, v = gather_paged_kv(cache, block_tables)  # (B, K, Hkv, Dh) f32
    qh = q.reshape(b, sq, hkv, g, dh).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qh, k) * sm_scale
    kpos = jnp.arange(k.shape[1], dtype=jnp.int32)
    mask = (kpos[None, None, :] <= qpos[:, :, None]) & (qpos >= 0)[:, :, None]
    if window > 0:
        mask &= qpos[:, :, None] - kpos[None, None, :] < window
    maskb = mask[:, None, None]  # (B, 1, 1, Sq, K)
    s = jnp.where(maskb, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m) * maskb  # masked rows: exp(0)=1 zeroed by the mask
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v)
    o = jnp.where(l > 0, o / jnp.maximum(l, 1e-30), 0.0)
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dh).astype(q.dtype)


def ring_attention(q: jax.Array, k: jax.Array, v: jax.Array, qpos: jax.Array,
                   kpos: jax.Array, *, window: int = 0,
                   sm_scale: float | None = None) -> jax.Array:
    """Causal attention against per-slot ring caches (the ring-layout oracle).

    q: (B, Sq, H, Dh); k, v: (B, Skv, Hkv, Dh); qpos: (B, Sq) / kpos:
    (B, Skv) per-sequence absolute positions (``-1`` = padding query → zero
    output / empty ring entry → never attended).  Causal, optionally
    sliding-window — the per-sequence counterpart of
    ``models.modules.attention_dense``, which the tests tie it back to.
    """
    b, sq, h, dh = q.shape
    hkv = k.shape[2]
    g = h // hkv
    sm_scale = sm_scale or (1.0 / math.sqrt(dh))
    qh = q.reshape(b, sq, hkv, g, dh).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qh, k.astype(jnp.float32)) * sm_scale
    mask = (kpos[:, None, :] >= 0) & (qpos[:, :, None] >= 0) \
        & (kpos[:, None, :] <= qpos[:, :, None])
    if window > 0:
        mask &= qpos[:, :, None] - kpos[:, None, :] < window
    maskb = mask[:, None, None]  # (B, 1, 1, Sq, Skv)
    s = jnp.where(maskb, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m) * maskb
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    o = jnp.where(l > 0, o / jnp.maximum(l, 1e-30), 0.0)
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dh).astype(q.dtype)


def int4_matmul(x: jax.Array, qweight: jax.Array, scales: jax.Array,
                group: int = 128, *, scale=None, bias=None, residual=None,
                activation=None) -> jax.Array:
    w = dequantize_int4({"qweight": qweight, "scales": scales}, dtype=jnp.float32)
    y = jax.lax.dot_general(
        x.astype(jnp.float32), w, (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    y = apply_epilogue(y, scale=scale, bias=bias, residual=residual,
                       activation=activation)
    return y.astype(x.dtype)

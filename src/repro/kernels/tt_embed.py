"""Fused gathered-row TT embedding kernel (TensorGPT-style vocab-axis TT).

The embedding table (V, D) is stored as a TT whose (M, N) weight has the
vocab on the output axis (M = V), so looking a token up never reconstructs
the table.  Per token-id the kernel:

  1. splits the id into its big-endian ``out_modes`` digits (i_1..i_d);
  2. gathers digit i_k's ``(r0, n_k, r1)`` column block of core matrix C_k
     for the whole token tile with one one-hot matmul (MXU-friendly — no
     dynamic gather inside the kernel body);
  3. chains the per-token slices left-to-right with batched dot_generals,
     exactly the ``tt_linear`` stage contraction restricted to one row.

Grid is 1-D over token tiles; all cores are pinned whole in VMEM (they are
the compressed representation — a few KB).  Ids follow the dense path's
``jnp.take`` semantics for padding: negative ids wrap once (``-1`` is row
``V - 1``), anything else clamps into range.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.ttd import TTSpec

VMEM_BUDGET_BYTES = 12 * 1024 * 1024


def pick_block_t(spec: TTSpec, n_tokens: int, dtype_bytes: int = 4) -> int:
    """Largest power-of-two token tile whose working set fits the budget."""
    per_token = (
        spec.n_in * max(spec.ranks)  # widest running row chunk
        + max(spec.ranks[k] * spec.in_modes[k] * spec.ranks[k + 1]
              for k in range(spec.d))  # largest per-core selection
        + max(spec.out_modes)  # one-hot row
    ) * dtype_bytes
    cores_bytes = spec.n_params() * dtype_bytes
    bt = 8
    while bt * 2 <= n_tokens and (bt * 2) * per_token + cores_bytes <= VMEM_BUDGET_BYTES:
        bt *= 2
    return bt


def _kernel(ids_ref, *refs, spec: TTSpec, block_t: int):
    cores = [refs[k][...] for k in range(spec.d)]
    out_ref = refs[-1]
    ids = ids_ref[...].reshape(block_t)
    ids = jnp.clip(jnp.where(ids < 0, ids + spec.n_out, ids), 0, spec.n_out - 1)
    m = spec.out_modes
    p = None
    for k in range(spec.d):
        stride = math.prod(m[k + 1:])
        digit = (ids // stride) % m[k]  # (T,)
        r0, r1 = spec.ranks[k], spec.ranks[k + 1]
        n_k = spec.in_modes[k]
        onehot = (digit[:, None] == jax.lax.broadcasted_iota(
            jnp.int32, (block_t, m[k]), 1)).astype(jnp.float32)
        # C_k rows are (r0, n_k), columns (m_k, r1): one matmul gathers the
        # digit's (r0, n_k, r1) column block for every token in the tile
        c = cores[k].astype(jnp.float32).reshape(r0, n_k, m[k], r1)
        c = c.transpose(2, 0, 1, 3).reshape(m[k], r0 * n_k * r1)
        sel = jax.lax.dot_general(onehot, c, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        sel = sel.reshape(block_t, r0, n_k * r1)
        if p is None:
            p = sel.reshape(block_t, n_k, r1)  # r0 == 1 on the first core
        else:
            # (T, X, r0) x (T, r0, n_k*r1) batched over the token tile
            p = jax.lax.dot_general(p, sel, (((2,), (1,)), ((0,), (0,))),
                                    preferred_element_type=jnp.float32)
            p = p.reshape(block_t, -1, r1)
    out_ref[...] = p.reshape(block_t, spec.n_in)


def tt_embed_pallas(ids: jax.Array, cores: list[jax.Array], spec: TTSpec, *,
                    block_t: int | None = None,
                    interpret: bool = True) -> jax.Array:
    """ids (T,) int32 -> (T, D) f32 rows of the TT-described (V, D) table."""
    (t,) = ids.shape
    bt = block_t or pick_block_t(spec, max(t, 8))
    pad = (-t) % bt
    ids32 = jnp.asarray(ids, jnp.int32)
    if pad:
        ids32 = jnp.pad(ids32, (0, pad))
    in_specs = [pl.BlockSpec((bt,), lambda i: (i,))]
    in_specs += [pl.BlockSpec(c.shape, lambda i, nd=c.ndim: tuple([0] * nd))
                 for c in cores]
    out = pl.pallas_call(
        functools.partial(_kernel, spec=spec, block_t=bt),
        grid=(ids32.shape[0] // bt,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bt, spec.n_in), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((ids32.shape[0], spec.n_in), jnp.float32),
        interpret=interpret,
    )(ids32, *cores)
    return out[:t] if pad else out

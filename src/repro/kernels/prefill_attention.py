"""Ragged chunked-prefill flash-attention Pallas kernel.

The paper's headline serving number is *first-token delay*, which is decided
by the prefill path.  PR 3 fused decode (one query per sequence), but chunked
prefill still ran the pure-jnp gather oracle: every layer materialized the
whole ``(B, W*BS, Hkv, Dh)`` f32 gathered context in HBM and computed dense
``(Sq × K)`` scores including idle rows.  This kernel closes that gap — the
last fork between "kernel-accelerated decode" and "oracle-math prefill".

Grid: ``(seq, q-tile)`` — one program per (sequence, tile of query tokens).
Each program streams K/V tiles through the flash online-softmax recurrence,
with GQA head grouping and causal + sliding-window masking driven by
per-sequence absolute positions (``-1`` = padding → zero output).  Two cache
layouts share the kernel body:

* **paged** — K/V live in shared block pools addressed through a per-sequence
  block table; K positions are implicit (gathered index *i* holds absolute
  position *i*), tiles are the ``block_size``-wide blocks, and the loop trip
  count is the tile's max query position rounded up to blocks, so a program
  never reads beyond the blocks its sequence actually occupies (all-idle
  tiles run zero iterations).  int8 pools dequantize per-(block-slot, head)
  scales in-tile, fused with the score matmul.
* **ring** — K/V are per-slot rings with an explicit ``kpos`` operand
  (``-1`` = empty entry); tiles stream over the ring width, and the mask is
  position-driven (causal, ``kpos >= 0``, sliding window), so SWA families
  (mixtral, griffin's attention layers) prefill through the same kernel.

Like ``kernels/paged_attention.py``, the pools/rings are handed to the kernel
whole and sliced per tile — correct under the interpreter and for Mosaic
while they fit VMEM; a production TPU build would prefetch the block table as
a scalar argument (``pltpu.PrefetchScalarGridSpec``) and DMA one tile per
grid step from HBM, changing only this file, not the dispatch contract.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, qpos_ref, *refs, paged: bool, kv_tile: int, n_kv_tiles: int,
            n_kv_heads: int, window: int, sm_scale: float, quantized: bool,
            out_dtype):
    out_ref = refs[-1]
    if paged:
        bt_ref, k_ref, v_ref = refs[0], refs[1], refs[2]
        ks_ref, vs_ref = (refs[3], refs[4]) if quantized else (None, None)
        kpos_ref = None
    else:
        kpos_ref, k_ref, v_ref = refs[0], refs[1], refs[2]
        ks_ref, vs_ref = (refs[3], refs[4]) if quantized else (None, None)
        bt_ref = None
    q = q_ref[0]  # (QT, H, Dh)
    qt, h, dh = q.shape
    g = h // n_kv_heads
    qh = q.reshape(qt, n_kv_heads, g, dh).astype(jnp.float32) * sm_scale
    qpos = qpos_ref[0]  # (QT,) int32; -1 = padding row
    if paged:
        # walk only the blocks this tile's queries can see (0 when all-idle)
        qmax = jnp.max(qpos)
        n_tiles = (jnp.maximum(qmax + 1, 0) + kv_tile - 1) // kv_tile
        ring_k = ring_v = ring_pos = None
    else:
        n_tiles = n_kv_tiles  # static: ring width is fixed per call
        ring_k = k_ref[0]     # (WR, Hkv, Dh) — already VMEM-resident
        ring_v = v_ref[0]
        ring_pos = kpos_ref[0]

    def body(j, carry):
        m, l, acc = carry
        if paged:
            blk = bt_ref[0, j]
            kb = k_ref[pl.ds(blk, 1)][0].astype(jnp.float32)  # (KT, Hkv, Dh)
            vb = v_ref[pl.ds(blk, 1)][0].astype(jnp.float32)
            if quantized:
                kb = kb * ks_ref[pl.ds(blk, 1)][0][..., None]
                vb = vb * vs_ref[pl.ds(blk, 1)][0][..., None]
            kpos = j * kv_tile + jnp.arange(kv_tile, dtype=jnp.int32)
            valid = kpos[None, :] <= qpos[:, None]  # causal + ragged block
        else:
            kb = jax.lax.dynamic_slice_in_dim(ring_k, j * kv_tile, kv_tile
                                              ).astype(jnp.float32)
            vb = jax.lax.dynamic_slice_in_dim(ring_v, j * kv_tile, kv_tile
                                              ).astype(jnp.float32)
            if quantized:
                kb = kb * jax.lax.dynamic_slice_in_dim(
                    ks_ref[0], j * kv_tile, kv_tile)[..., None]
                vb = vb * jax.lax.dynamic_slice_in_dim(
                    vs_ref[0], j * kv_tile, kv_tile)[..., None]
            kpos = jax.lax.dynamic_slice_in_dim(ring_pos, j * kv_tile, kv_tile)
            valid = (kpos[None, :] >= 0) & (kpos[None, :] <= qpos[:, None])
        valid &= qpos[:, None] >= 0
        if window > 0:
            valid &= qpos[:, None] - kpos[None, :] < window
        s = jnp.einsum("qhgd,khd->hgqk", qh, kb)  # (Hkv, G, QT, KT)
        s = jnp.where(valid[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None]) * valid[None, None]
        l_new = l * corr + p.sum(-1)
        pv = jnp.einsum("hgqk,khd->hgqd", p, vb)
        acc_new = acc * corr[..., None] + pv
        return m_new, l_new, acc_new

    m0 = jnp.full((n_kv_heads, g, qt), NEG_INF, jnp.float32)
    l0 = jnp.zeros((n_kv_heads, g, qt), jnp.float32)
    a0 = jnp.zeros((n_kv_heads, g, qt, dh), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_tiles, body, (m0, l0, a0))
    out = jnp.where(l[..., None] > 0, acc / jnp.maximum(l, 1e-30)[..., None], 0.0)
    out_ref[0] = out.transpose(2, 0, 1, 3).reshape(qt, h, dh).astype(out_dtype)


def prefill_attention_pallas(q: jax.Array, qpos: jax.Array, *,
                             cache: dict | None = None,
                             block_tables: jax.Array | None = None,
                             k: jax.Array | None = None,
                             v: jax.Array | None = None,
                             kpos: jax.Array | None = None,
                             window: int = 0, sm_scale: float | None = None,
                             k_scale: jax.Array | None = None,
                             v_scale: jax.Array | None = None,
                             q_tile: int = 64, kv_tile: int = 128,
                             interpret: bool = True) -> jax.Array:
    """Chunked-prefill attention over a paged pool or per-slot rings.

    q: (B, Sq, H, Dh); qpos: (B, Sq) int32 absolute query positions (``-1`` =
    padding row → zero output).  Exactly one layout:

    * paged — ``cache``: ``{"k","v": (NB, BS, Hkv, Dh)}`` plus
      ``k_scale``/``v_scale`` ``(NB, BS, Hkv)`` for int8 pools;
      ``block_tables``: (B, W) int32 ordered logical→physical ids.
    * ring — ``k``/``v``: (B, WR, Hkv, Dh); ``kpos``: (B, WR) int32 absolute
      key positions, ``-1`` = empty entry; int8 rings carry per-entry-per-head
      f32 ``k_scale``/``v_scale`` (B, WR, Hkv) dequantized in-tile.

    The chunk's own K/V must already be written (write-then-attend, as both
    ``paged_kv_update`` and ``ring_kv_update`` guarantee).  Returns
    (B, Sq, H, Dh) in ``q.dtype``.  ``interpret`` defaults True like the
    other ``*_pallas`` kernels; production callers go through
    ``kernels.dispatch.prefill_attention``.
    """
    paged = cache is not None
    b, sq, h, dh = q.shape
    sm_scale = sm_scale or (1.0 / math.sqrt(dh))
    qt = min(q_tile, sq)
    pad_q = (-sq) % qt
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        qpos = jnp.pad(qpos, ((0, 0), (0, pad_q)), constant_values=-1)
    nqt = q.shape[1] // qt
    grid = (b, nqt)

    in_specs = [
        pl.BlockSpec((1, qt, h, dh), lambda i, j: (i, j, 0, 0)),
        pl.BlockSpec((1, qt), lambda i, j: (i, j)),
    ]
    args = [q, qpos.astype(jnp.int32)]

    if paged:
        nb, bs, hkv, _ = cache["k"].shape
        w = block_tables.shape[1]
        quantized = "k_scale" in cache
        kv_t, n_kv_tiles = bs, 0  # trip count is data-dependent (block walk)
        in_specs += [
            pl.BlockSpec((1, w), lambda i, j: (i, 0)),
            pl.BlockSpec((nb, bs, hkv, dh), lambda i, j: (0, 0, 0, 0)),
            pl.BlockSpec((nb, bs, hkv, dh), lambda i, j: (0, 0, 0, 0)),
        ]
        args += [block_tables.astype(jnp.int32), cache["k"], cache["v"]]
        if quantized:
            for nm in ("k_scale", "v_scale"):
                in_specs.append(pl.BlockSpec((nb, bs, hkv), lambda i, j: (0, 0, 0)))
                args.append(cache[nm].astype(jnp.float32))
    else:
        if k is None or v is None or kpos is None:
            raise ValueError("ring layout needs k, v and kpos")
        skv, hkv = k.shape[1], k.shape[2]
        quantized = k_scale is not None
        kv_t = min(kv_tile, skv)
        pad_k = (-skv) % kv_t
        if pad_k:
            k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
            kpos = jnp.pad(kpos, ((0, 0), (0, pad_k)), constant_values=-1)
            if quantized:
                k_scale = jnp.pad(k_scale, ((0, 0), (0, pad_k), (0, 0)))
                v_scale = jnp.pad(v_scale, ((0, 0), (0, pad_k), (0, 0)))
        n_kv_tiles = k.shape[1] // kv_t
        wr = k.shape[1]
        in_specs += [
            pl.BlockSpec((1, wr), lambda i, j: (i, 0)),
            pl.BlockSpec((1, wr, hkv, dh), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((1, wr, hkv, dh), lambda i, j: (i, 0, 0, 0)),
        ]
        args += [kpos.astype(jnp.int32), k, v]
        if quantized:
            in_specs += [
                pl.BlockSpec((1, wr, hkv), lambda i, j: (i, 0, 0)),
                pl.BlockSpec((1, wr, hkv), lambda i, j: (i, 0, 0)),
            ]
            args += [k_scale.astype(jnp.float32), v_scale.astype(jnp.float32)]

    out = pl.pallas_call(
        functools.partial(_kernel, paged=paged, kv_tile=kv_t,
                          n_kv_tiles=n_kv_tiles, n_kv_heads=hkv,
                          window=window, sm_scale=sm_scale,
                          quantized=quantized, out_dtype=q.dtype),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, qt, h, dh), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(*args)
    return out[:, :sq] if pad_q else out

"""Fused RG-LRU recurrent-scan Pallas kernel (griffin / recurrentgemma).

The RG-LRU recurrence ``h_t = a_t h_{t-1} + sqrt(1 - a_t²) (i_t ⊙ u_t)`` is
diagonal over the LRU width, so the natural kernel decomposition is
``(sequence, width-tile)``: each program owns one slot's slice of the state
and streams the chunk's token tiles through it, keeping ``h`` resident
on-chip for the whole call instead of round-tripping (B, S, W) operands per
scan step.

* **prefill** (S > 1) — grid ``(B, W/Wt)``.  Each program loads its
  (S, Wt) ``log_a``/``gx`` panes once, applies the position mask (``-1`` =
  padding → a = 1, input 0: the state passes through *bitwise* in the f32
  carry), folds ``h0`` in, then walks token tiles of width ``TT`` with a
  log-depth Hillis–Steele scan inside each tile and a serial f32 carry
  between tiles — the same chunked associative-scan structure as the ref
  oracle's ``associative_scan``, with the state never leaving VMEM.
* **decode** (S == 1) — grid ``(W/Wt,)``: one fused masked step batching
  *all* slots' single-token updates (decay, gate, ``sqrt(1-a²)``
  normalizer, output write in one kernel).  Inactive rows select their
  stored state bitwise via ``jnp.where`` — no cast, no recompute.

Gate linears stay in the model (they are already dispatched TT/int4
matmuls); production callers go through ``kernels.dispatch.rglru_scan``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scan_tile(a, b):
    """Inclusive Hillis–Steele scan of ``h_t = a_t h_{t-1} + b_t`` (axis 0).

    Static log-depth: combine (a1,b1)⊕(a2,b2) = (a1·a2, a2·b1 + b2) with
    shifted operands (identity pad a=1, b=0).  Returns the prefix (A, B)
    arrays: ``h_t = A_t h_in + B_t``.
    """
    t = a.shape[0]
    d = 1
    while d < t:
        a_sh = jnp.concatenate(
            [jnp.ones((d,) + a.shape[1:], a.dtype), a[:-d]], axis=0)
        b_sh = jnp.concatenate(
            [jnp.zeros((d,) + b.shape[1:], b.dtype), b[:-d]], axis=0)
        a, b = a_sh * a, a * b_sh + b
        d *= 2
    return a, b


def _prefill_kernel(la_ref, gx_ref, h0_ref, pos_ref, h_ref, hlast_ref, *,
                    token_tile: int, n_tiles: int, out_dtype):
    la = la_ref[0].astype(jnp.float32)  # (S, Wt)
    gx = gx_ref[0].astype(jnp.float32)
    m = (pos_ref[0] >= 0).astype(jnp.float32)[:, None]  # (S, 1)
    la = la * m  # pads: log a = 0 -> a = 1
    a = jnp.exp(la)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * la), 1e-12)) * gx * m

    def body(t, h):
        a_t = jax.lax.dynamic_slice_in_dim(a, t * token_tile, token_tile)
        b_t = jax.lax.dynamic_slice_in_dim(b, t * token_tile, token_tile)
        pa, pb = _scan_tile(a_t, b_t)
        h_tile = pa * h[None, :] + pb
        h_ref[0, pl.ds(t * token_tile, token_tile)] = h_tile.astype(out_dtype)
        return h_tile[-1]

    h_last = jax.lax.fori_loop(0, n_tiles, body, h0_ref[0].astype(jnp.float32))
    hlast_ref[0] = h_last


def _decode_kernel(la_ref, gx_ref, h0_ref, pos_ref, h_ref, hlast_ref, *,
                   out_dtype):
    la = la_ref[:, 0].astype(jnp.float32)  # (B, Wt)
    gx = gx_ref[:, 0].astype(jnp.float32)
    h0 = h0_ref[...].astype(jnp.float32)
    active = (pos_ref[:, 0] >= 0)[:, None]
    a = jnp.exp(la)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * la), 1e-12)) * gx
    h = jnp.where(active, a * h0 + b, h0)  # inactive rows: bitwise h0
    h_ref[:, 0] = h.astype(out_dtype)
    hlast_ref[...] = h


def rglru_scan_pallas(log_a, gx, h0, pos=None, *, scan_dtype=None,
                      token_tile: int = 16, width_tile: int = 128,
                      interpret: bool = True):
    """Fused RG-LRU scan.  Same contract as ``kernels.ref.rglru_scan``:
    log_a/gx (B,S,W), h0 (B,W) f32, pos (B,S) int32 (``-1`` = padding) or
    None (all steps real).  Returns (h (B,S,W) scan_dtype, h_last (B,W) f32).
    """
    b, s, w = log_a.shape
    out_dtype = jnp.dtype(scan_dtype or jnp.float32)
    f32 = jnp.float32
    log_a, gx, h0 = log_a.astype(f32), gx.astype(f32), h0.astype(f32)
    pos = (jnp.zeros((b, s), jnp.int32) if pos is None
           else pos.astype(jnp.int32))

    wt = min(width_tile, w)
    pad_w = (-w) % wt
    if pad_w:  # zero-pad width: a = 1, b = 0, h0 = 0 -> pad lanes stay 0
        pad3 = ((0, 0), (0, 0), (0, pad_w))
        log_a, gx = jnp.pad(log_a, pad3), jnp.pad(gx, pad3)
        h0 = jnp.pad(h0, ((0, 0), (0, pad_w)))
    nwt = (w + pad_w) // wt

    if s == 1:
        h, h_last = pl.pallas_call(
            functools.partial(_decode_kernel, out_dtype=out_dtype),
            grid=(nwt,),
            in_specs=[
                pl.BlockSpec((b, 1, wt), lambda j: (0, 0, j)),
                pl.BlockSpec((b, 1, wt), lambda j: (0, 0, j)),
                pl.BlockSpec((b, wt), lambda j: (0, j)),
                pl.BlockSpec((b, 1), lambda j: (0, 0)),
            ],
            out_specs=[
                pl.BlockSpec((b, 1, wt), lambda j: (0, 0, j)),
                pl.BlockSpec((b, wt), lambda j: (0, j)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct(log_a.shape, out_dtype),
                jax.ShapeDtypeStruct(h0.shape, f32),
            ],
            interpret=interpret,
        )(log_a, gx, h0, pos)
        return h[:, :, :w] if pad_w else h, h_last[:, :w] if pad_w else h_last

    tt = min(token_tile, s)
    pad_s = (-s) % tt
    if pad_s:  # pad steps ride at position -1: exact state passthrough
        ext = ((0, 0), (0, pad_s), (0, 0))
        log_a, gx = jnp.pad(log_a, ext), jnp.pad(gx, ext)
        pos = jnp.pad(pos, ((0, 0), (0, pad_s)), constant_values=-1)
    sp = s + pad_s

    h, h_last = pl.pallas_call(
        functools.partial(_prefill_kernel, token_tile=tt, n_tiles=sp // tt,
                          out_dtype=out_dtype),
        grid=(b, nwt),
        in_specs=[
            pl.BlockSpec((1, sp, wt), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, sp, wt), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, wt), lambda i, j: (i, j)),
            pl.BlockSpec((1, sp), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, sp, wt), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, wt), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, sp, w + pad_w), out_dtype),
            jax.ShapeDtypeStruct((b, w + pad_w), f32),
        ],
        interpret=interpret,
    )(log_a, gx, h0, pos)
    return h[:, :s, :w], h_last[:, :w]

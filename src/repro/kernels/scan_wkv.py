"""Fused RWKV6 wkv recurrent-scan Pallas kernel.

The wkv recurrence keeps a per-(slot, head) matrix state
``S ∈ R^{hd×hd}``:

    S_t = diag(w_t) S_{t-1} + k_tᵀ v_t;   y_t = r_t (S_{t-1} + diag(u) k_tᵀ v_t)

Grid: ``(slot, head)`` — one program per state matrix, which stays resident
on-chip for the whole call while the chunk axis streams through it:

* **prefill** (S > 1) — the program walks ``S/C`` chunks of the
  chunked-parallel (Finch/GLA) form: per chunk, two (C×hd)·(hd×·) matmuls
  for the intra-chunk scores/output plus a rank-C state update — the same
  math as ``kernels.ref.wkv_chunked``, generalized from a host-side
  ``lax.scan`` into an in-kernel loop over the chunk grid axis.  Ragged
  tails are padded to a chunk multiple with identity steps (k = 0, w = 1),
  so a one-chunk prompt takes the matmul form too.
* **decode** (S == 1) — one fused masked step: decay, bonus ``u``, state
  update and output in one kernel, batching all slots via the grid.  The
  step uses ``w`` directly (no log-decay flooring), matching the sequential
  oracle exactly.

Masking follows the serving convention (``pos`` ``-1`` = padding → k = 0,
w = 1: the f32 state passes through bitwise).  int8 state rides per-(slot,
head) f32 scale tables fused into the kernel's load/store: dequantize at
entry, amax/127 requantize at exit, with fully-idle rows bitwise-preserving
their stored int8 values *and* scale.  Production callers go through
``kernels.dispatch.wkv_scan``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import WKV_CHUNK, WKV_LOG_DECAY_FLOOR


def _kernel(r_ref, k_ref, v_ref, w_ref, pos_ref, u_ref, s0_ref, *refs,
            chunk: int, n_chunks: int, quantized: bool, decode: bool):
    if quantized:
        scale_ref, y_ref, sout_ref, scout_ref = refs
    else:
        scale_ref, (y_ref, sout_ref) = None, refs
    f32 = jnp.float32
    pos = pos_ref[0]  # (S,)
    m = (pos >= 0)[:, None]
    r = r_ref[0, :, 0].astype(f32)  # (S, hd)
    k = jnp.where(m, k_ref[0, :, 0].astype(f32), 0.0)
    w = jnp.where(m, w_ref[0, :, 0].astype(f32), 1.0)
    v = v_ref[0, :, 0].astype(f32)
    u = u_ref[0].astype(f32)  # (hd,)
    s0 = s0_ref[0, 0].astype(f32)  # (hd, hd)
    if quantized:
        s0 = s0 * scale_ref[0, 0]

    if decode:  # exact one-step update (no log-decay flooring)
        kv = k[0][:, None] * v[0][None, :]
        y = jnp.dot(r[0], s0 + u[:, None] * kv, preferred_element_type=f32)
        y_ref[0, 0, 0] = y
        s_fin = w[0][:, None] * s0 + kv
    else:
        lw = jnp.clip(jnp.log(jnp.maximum(w, 1e-38)), WKV_LOG_DECAY_FLOOR, 0.0)
        tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) > \
            jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)

        def body(c, s_c):
            rc = jax.lax.dynamic_slice_in_dim(r, c * chunk, chunk)
            kc = jax.lax.dynamic_slice_in_dim(k, c * chunk, chunk)
            vc = jax.lax.dynamic_slice_in_dim(v, c * chunk, chunk)
            lwc = jax.lax.dynamic_slice_in_dim(lw, c * chunk, chunk)
            la_inc = jnp.cumsum(lwc, axis=0)  # includes step τ's decay
            la_exc = la_inc - lwc             # decay before step t
            la_end = la_inc[-1]
            r_tld = rc * jnp.exp(la_exc)
            k_tld = kc * jnp.exp(-la_inc)
            k_end = kc * jnp.exp(la_end[None] - la_inc)
            scores = jnp.dot(r_tld, k_tld.T, preferred_element_type=f32)
            scores = jnp.where(tri, scores, 0.0)
            diag = jnp.sum(rc * u[None] * kc, axis=-1)  # (C,)
            y = jnp.dot(scores, vc, preferred_element_type=f32) \
                + diag[:, None] * vc \
                + jnp.dot(r_tld, s_c, preferred_element_type=f32)
            y_ref[0, pl.ds(c * chunk, chunk), 0] = y
            return s_c * jnp.exp(la_end)[:, None] \
                + jnp.dot(k_end.T, vc, preferred_element_type=f32)

        s_fin = jax.lax.fori_loop(0, n_chunks, body, s0)

    if quantized:
        idle = jnp.all(pos < 0)  # this slot saw no real step this call
        sc = jnp.maximum(jnp.max(jnp.abs(s_fin)), 1e-8) / 127.0
        q = jnp.round(s_fin / sc).astype(jnp.int8)
        sout_ref[0, 0] = jnp.where(idle, s0_ref[0, 0], q)
        scout_ref[0, 0] = jnp.where(idle, scale_ref[0, 0], sc)
    else:
        sout_ref[0, 0] = s_fin


def wkv_scan_pallas(r, k, v, w, u, state0, pos=None, *, state_scale=None,
                    chunk: int = WKV_CHUNK, interpret: bool = True):
    """Fused wkv scan.  Same contract as ``kernels.ref.wkv_scan``:
    r/k/v/w (B,S,H,hd), u (H,hd), state0 (B,H,hd,hd) f32 — or int8 with
    ``state_scale`` (B,H) f32 — pos (B,S) int32 (``-1`` = padding) or None.
    Returns (y (B,S,H,hd) f32, new_state, new_scale-or-None).
    """
    b, s, h, hd = r.shape
    f32 = jnp.float32
    quantized = state_scale is not None
    decode = s == 1
    pos = (jnp.zeros((b, s), jnp.int32) if pos is None
           else pos.astype(jnp.int32))

    c = 1 if decode else min(chunk, max(s, 2))
    pad_s = (-s) % c
    if pad_s:  # identity steps: k = 0, w = 1 (and pos = -1 for the mask)
        ext = ((0, 0), (0, pad_s), (0, 0), (0, 0))
        r, k, v = (jnp.pad(t, ext) for t in (r, k, v))
        w = jnp.pad(w, ext, constant_values=1.0)
        pos = jnp.pad(pos, ((0, 0), (0, pad_s)), constant_values=-1)
    sp = s + pad_s

    in_specs = [
        pl.BlockSpec((1, sp, 1, hd), lambda i, j: (i, 0, j, 0)),  # r
        pl.BlockSpec((1, sp, 1, hd), lambda i, j: (i, 0, j, 0)),  # k
        pl.BlockSpec((1, sp, 1, hd), lambda i, j: (i, 0, j, 0)),  # v
        pl.BlockSpec((1, sp, 1, hd), lambda i, j: (i, 0, j, 0)),  # w
        pl.BlockSpec((1, sp), lambda i, j: (i, 0)),               # pos
        pl.BlockSpec((1, hd), lambda i, j: (j, 0)),               # u
        pl.BlockSpec((1, 1, hd, hd), lambda i, j: (i, j, 0, 0)),  # state0
    ]
    args = [r, k, v, w.astype(f32), pos, u.astype(f32), state0]
    out_specs = [
        pl.BlockSpec((1, sp, 1, hd), lambda i, j: (i, 0, j, 0)),
        pl.BlockSpec((1, 1, hd, hd), lambda i, j: (i, j, 0, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((b, sp, h, hd), f32),
        jax.ShapeDtypeStruct((b, h, hd, hd), state0.dtype),
    ]
    if quantized:
        in_specs.append(pl.BlockSpec((1, 1), lambda i, j: (i, j)))
        args.append(state_scale.astype(f32))
        out_specs.append(pl.BlockSpec((1, 1), lambda i, j: (i, j)))
        out_shape.append(jax.ShapeDtypeStruct((b, h), f32))

    out = pl.pallas_call(
        functools.partial(_kernel, chunk=c, n_chunks=sp // c,
                          quantized=quantized, decode=decode),
        grid=(b, h),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*args)
    if quantized:
        y, st, sc = out
        return y[:, :s], st, sc
    y, st = out
    return y[:, :s], st, None

"""Shared epilogue math for the linear kernels and their references.

One definition of the paper's TTDLinear-BN(-Res) post-processing (§III.A),
used by the Pallas kernel bodies, the pure-jnp oracles, and the dispatch
layer, so every backend applies bit-identical epilogue semantics:

    y -> y * scale -> y + bias -> activation(y) -> y + residual

All epilogue math runs in f32 regardless of the matmul/store dtype; callers
cast the result back to their compute dtype once, at the end.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

# Names are config-level strings (hashable, usable as static jit args).
ACTIVATIONS = {
    "gelu": partial(jax.nn.gelu, approximate=True),
    "gelu_exact": partial(jax.nn.gelu, approximate=False),
    "silu": jax.nn.silu,
    "relu": jax.nn.relu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
    "sigmoid": jax.nn.sigmoid,
    "tanh": jnp.tanh,
}


def apply_epilogue(y, *, scale=None, bias=None, residual=None,
                   activation: str | None = None) -> jax.Array:
    """Fused post-ops on a matmul accumulator; returns f32."""
    y = y.astype(jnp.float32)
    if scale is not None:
        y = y * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    if activation is not None:
        y = ACTIVATIONS[activation](y)
    if residual is not None:
        y = y + residual.astype(jnp.float32)
    return y

"""Fused multi-stage TT-linear Pallas TPU kernel.

TPU adaptation of the paper's GVSA TTD dataflow (§III.C):

  * All d TT cores are pinned in VMEM for the kernel's lifetime (they total
    ~35-45 KB per layer after compression — the whole point of TTD).  This is
    the analogue of GVSA's weight-stationary PEs.
  * The staged contraction P_0 -> P_1 -> … -> P_d (paper Eq. 4) runs entirely
    in VMEM/VREGs; the inter-stage *reorder* (paper: hidden in the ping-pong
    buffer write/read pattern) is a register-level reshape/transpose here —
    intermediates never touch HBM.
  * Per-token HBM traffic is exactly N + M elements (input + output) plus the
    one-time core fetch: the memory-bound linear layer becomes bandwidth-
    optimal (paper's roofline argument, §I).
  * Optional fused epilogue: ``act(y*scale + bias) (+ residual)`` — the
    paper's TTDLinear-BN(-Res) operator fusion; every operand is independent
    (bias-only gives the plain biased linear).  Shared semantics live in
    ``repro.kernels.epilogue``.

The grid tiles the token dimension; ``block_b`` is chosen so the largest
intermediate fits a VMEM budget.  Matmul shapes per stage are
(block_b·T_k, r·n_k) × (r·n_k, m_k·r′): the contraction dims for the paper's
Table-I factorizations are 128-aligned (r·n = 16·8), matching the MXU.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.ttd import TTSpec
from .epilogue import apply_epilogue

VMEM_BUDGET_BYTES = 12 * 1024 * 1024  # leave headroom below ~16 MiB/core


def pick_block_b(spec: TTSpec, batch: int, dtype_bytes: int = 4) -> int:
    """Largest power-of-two token block whose working set fits VMEM."""
    per_token = (spec.n_in + spec.n_out + 2 * spec.max_intermediate()) * dtype_bytes
    cores = spec.n_params() * dtype_bytes
    bb = 1
    while bb * 2 <= batch and (bb * 2) * per_token + cores <= VMEM_BUDGET_BYTES:
        bb *= 2
    return bb


def _stage_contract(p, cores, spec: TTSpec, block_b: int):
    """The Eq.-4 staged contraction on a (block_b, N) tile, all in VMEM."""
    n, m, d = spec.in_modes, spec.out_modes, spec.d
    b = block_b
    p = p.reshape(b, n[0], math.prod(n[1:]))
    p = jnp.swapaxes(p, 1, 2)  # (b, T_0, r0*n1)
    m_prod = 1
    for k in range(d):
        c_k = cores[k].astype(jnp.float32)
        p = jax.lax.dot_general(p.astype(jnp.float32), c_k,
                                (((2,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if k < d - 1:
            nr = math.prod(n[k + 2:])
            p = p.reshape(b, n[k + 1], nr, m_prod, m[k], spec.ranks[k + 1])
            p = p.transpose(0, 2, 3, 4, 5, 1)  # the "ping-pong reorder"
            m_prod *= m[k]
            p = p.reshape(b, nr * m_prod, spec.ranks[k + 1] * n[k + 1])
    return p.reshape(b, spec.n_out)


def _kernel(x_ref, *refs, spec: TTSpec, block_b: int, has_scale: bool,
            has_bias: bool, has_res: bool, activation: str | None, out_dtype):
    d = spec.d
    cores = [refs[k][...] for k in range(d)]
    rest = list(refs[d:-1])
    out_ref = refs[-1]
    y = _stage_contract(x_ref[...], cores, spec, block_b)
    i = 0
    scale = bias = res = None
    if has_scale:
        scale, i = rest[i][...], i + 1
    if has_bias:
        bias, i = rest[i][...], i + 1
    if has_res:
        res = rest[i][...]
    y = apply_epilogue(y, scale=scale, bias=bias, residual=res,
                       activation=activation)
    out_ref[...] = y.astype(out_dtype)


def tt_linear_pallas(x: jax.Array, cores: list[jax.Array], spec: TTSpec, *,
                     scale: jax.Array | None = None,
                     bias: jax.Array | None = None,
                     residual: jax.Array | None = None,
                     activation: str | None = None,
                     block_b: int | None = None,
                     interpret: bool = True) -> jax.Array:
    """y = act(TTLinear(x) [* scale] [+ bias]) [+ residual];  (B, N) -> (B, M).

    Any epilogue operand may be passed independently (bias without scale is
    the plain ``y + b`` linear; scale+bias is the paper's TTDLinear-BN).
    ``interpret=True`` executes the kernel body on CPU (this container);
    ``interpret=False`` lowers via Mosaic for a real TPU.
    """
    b, n_in = x.shape
    assert n_in == spec.n_in, (n_in, spec)

    bb = block_b or pick_block_b(spec, b)
    pad = (-b) % bb
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        if residual is not None:
            residual = jnp.pad(residual, ((0, pad), (0, 0)))
    nb = x.shape[0] // bb

    in_specs = [pl.BlockSpec((bb, spec.n_in), lambda i: (i, 0))]
    in_specs += [pl.BlockSpec(c.shape, lambda i, _nd=c.ndim: (0,) * _nd)
                 for c in cores]
    extra = []
    for vec in (scale, bias):
        if vec is not None:
            extra.append(vec)
            in_specs.append(pl.BlockSpec((spec.n_out,), lambda i: (0,)))
    if residual is not None:
        extra.append(residual)
        in_specs.append(pl.BlockSpec((bb, spec.n_out), lambda i: (i, 0)))

    out = pl.pallas_call(
        functools.partial(_kernel, spec=spec, block_b=bb,
                          has_scale=scale is not None, has_bias=bias is not None,
                          has_res=residual is not None, activation=activation,
                          out_dtype=x.dtype),
        grid=(nb,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bb, spec.n_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], spec.n_out), x.dtype),
        interpret=interpret,
    )(x, *cores, *extra)
    return out[:b] if pad else out

"""Paged decode-attention Pallas kernel (one query token per sequence).

Serving decode is the shape the paper optimizes first-token-onward latency
for: every active sequence contributes exactly one query token per tick, and
its K/V context lives scattered across fixed-size blocks owned via a block
table (see ``serve/kv_cache.py``).  This kernel fuses the whole per-sequence
attention — block-table indirection, optional int8 dequant, online softmax,
GQA head grouping — into a single pass, so decode never materializes a
gathered (B, S, Hkv, Dh) context in HBM the way the pure-JAX reference
(``kernels/ref.py::paged_attention``) does.

Grid: one program per sequence.  The program walks only the blocks its
sequence actually occupies (``fori_loop`` with a data-dependent trip count),
streaming one (block_size, Hkv, Dh) K/V tile at a time through the flash
online-softmax recurrence; the running (m, l, acc) state is O(heads) and the
ragged last block / empty sequence cases fall out of the position mask.

The K/V pools are handed to the kernel whole (index-mapped to block (0,…))
and sliced per block id with ``pl.ds`` — correct under the interpreter and
for Mosaic as long as the pool fits VMEM.  A production TPU build would
instead prefetch the block table as a scalar argument
(``pltpu.PrefetchScalarGridSpec``) and let the BlockSpec index_map DMA one
block per grid step from HBM; that variant changes only this file, not the
dispatch contract.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, bt_ref, qpos_ref, k_ref, v_ref, *refs,
            block_size: int, n_kv_heads: int, sm_scale: float,
            quantized: bool, out_dtype):
    out_ref = refs[-1]
    ks_ref, vs_ref = (refs[0], refs[1]) if quantized else (None, None)
    q = q_ref[0]  # (H, Dh)
    h, dh = q.shape
    g = h // n_kv_heads
    qh = q.reshape(n_kv_heads, g, dh).astype(jnp.float32) * sm_scale
    qpos = qpos_ref[0]  # scalar int32; -1 = inactive sequence
    n_blocks = (jnp.maximum(qpos + 1, 0) + block_size - 1) // block_size

    def body(j, carry):
        m, l, acc = carry
        blk = bt_ref[0, j]
        kb = k_ref[pl.ds(blk, 1)][0].astype(jnp.float32)  # (BS, Hkv, Dh)
        vb = v_ref[pl.ds(blk, 1)][0].astype(jnp.float32)
        if quantized:
            kb = kb * ks_ref[pl.ds(blk, 1)][0][..., None]
            vb = vb * vs_ref[pl.ds(blk, 1)][0][..., None]
        s = jnp.einsum("hgd,khd->hgk", qh, kb)  # (Hkv, G, BS)
        kpos = j * block_size + jnp.arange(block_size, dtype=jnp.int32)
        valid = kpos <= qpos  # causal + ragged-last-block mask
        s = jnp.where(valid[None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None]) * valid[None, None, :]
        l_new = l * corr + p.sum(-1)
        pv = jnp.einsum("hgk,khd->hgd", p, vb)
        acc_new = acc * corr[..., None] + pv
        return m_new, l_new, acc_new

    m0 = jnp.full((n_kv_heads, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((n_kv_heads, g), jnp.float32)
    a0 = jnp.zeros((n_kv_heads, g, dh), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, a0))
    out = jnp.where(l[..., None] > 0, acc / jnp.maximum(l, 1e-30)[..., None], 0.0)
    out_ref[0] = out.reshape(h, dh).astype(out_dtype)


def paged_attention_pallas(q: jax.Array, cache: dict, block_tables: jax.Array,
                           qpos: jax.Array, *, sm_scale: float | None = None,
                           interpret: bool = True) -> jax.Array:
    """Decode attention through a block table; one query token per sequence.

    q: (B, H, Dh); cache: ``{"k","v": (NB, BS, Hkv, Dh)}`` plus
    ``k_scale``/``v_scale`` ``(NB, BS, Hkv)`` when the cache dtype is int8;
    block_tables: (B, W) int32; qpos: (B,) int32 absolute position of each
    new token (its K/V already written), ``-1`` for inactive rows (output
    zeros).  Returns (B, H, Dh) in ``q.dtype``.

    ``interpret`` defaults True like the other ``*_pallas`` kernels (this
    repo's tests run on CPU); production callers go through
    ``kernels.dispatch.paged_attention``, which sets it from the backend
    policy (``pallas`` → compiled via Mosaic).
    """
    b, h, dh = q.shape
    nb, bs, hkv, _ = cache["k"].shape
    w = block_tables.shape[1]
    quantized = "k_scale" in cache
    sm_scale = sm_scale or (1.0 / math.sqrt(dh))

    in_specs = [
        pl.BlockSpec((1, h, dh), lambda i: (i, 0, 0)),
        pl.BlockSpec((1, w), lambda i: (i, 0)),
        pl.BlockSpec((1,), lambda i: (i,)),
        pl.BlockSpec((nb, bs, hkv, dh), lambda i: (0, 0, 0, 0)),
        pl.BlockSpec((nb, bs, hkv, dh), lambda i: (0, 0, 0, 0)),
    ]
    args = [q, block_tables.astype(jnp.int32), qpos.astype(jnp.int32),
            cache["k"], cache["v"]]
    if quantized:
        for nm in ("k_scale", "v_scale"):
            in_specs.append(pl.BlockSpec((nb, bs, hkv), lambda i: (0, 0, 0)))
            args.append(cache[nm].astype(jnp.float32))

    return pl.pallas_call(
        functools.partial(_kernel, block_size=bs, n_kv_heads=hkv,
                          sm_scale=sm_scale, quantized=quantized,
                          out_dtype=q.dtype),
        grid=(b,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, h, dh), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, dh), q.dtype),
        interpret=interpret,
    )(*args)

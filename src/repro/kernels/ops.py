"""jit'd public wrappers that dispatch kernel vs pure-JAX reference.

``use_pallas`` policy: the Pallas kernels target TPU (validated here in
interpret mode); the dry-run / CPU paths use the mathematically identical
pure-JAX implementations.  On a real TPU deployment, flip
``repro.kernels.ops.USE_PALLAS = True`` (or set cfg) and the model's linear
dispatch routes through the fused kernels.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..core.ttd import TTSpec
from . import ref
from .int4_matmul import int4_matmul_pallas
from .tt_linear import tt_linear_pallas

USE_PALLAS = False  # module-level switch (True on real TPU)
INTERPRET = True  # interpret mode for CPU validation


def tt_linear(x, cores, spec: TTSpec, *, scale=None, bias=None, residual=None,
              use_pallas: bool | None = None):
    """(…, N) -> (…, M); flattens leading dims for the kernel grid."""
    use_pallas = USE_PALLAS if use_pallas is None else use_pallas
    lead = x.shape[:-1]
    xf = x.reshape(-1, spec.n_in)
    rf = residual.reshape(-1, spec.n_out) if residual is not None else None
    if use_pallas:
        y = tt_linear_pallas(xf, cores, spec, scale=scale, bias=bias,
                             residual=rf, interpret=INTERPRET)
    else:
        y = ref.tt_linear_bn_res(xf, cores, spec, scale=scale, bias=bias, residual=rf)
    return y.reshape(*lead, spec.n_out)


def int4_matmul(x, qweight, scales, *, group: int = 128,
                use_pallas: bool | None = None):
    use_pallas = USE_PALLAS if use_pallas is None else use_pallas
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    if use_pallas:
        y = int4_matmul_pallas(xf, qweight, scales, group=group, interpret=INTERPRET)
    else:
        y = ref.int4_matmul(xf, qweight, scales, group=group)
    return y.reshape(*lead, qweight.shape[0])

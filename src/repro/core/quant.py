"""INT4 weight quantization (paper: "Wt: INT4, Act: FP16", w4a16).

Symmetric per-group quantization along the contraction (input) dimension.
Weights are stored packed two-nibbles-per-byte (uint8) + per-group scales, the
same layout the ``repro.kernels.int4_matmul`` Pallas kernel consumes; the
pure-JAX path here unpacks + dequantizes inline (XLA fuses it into the
matmul epilogue on CPU; on TPU the Pallas kernel keeps weights int4 all the
way into VMEM — the DSP-sharing analogue, see DESIGN.md §2).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "quantize_int4",
    "dequantize_int4",
    "int4_matmul_ref",
    "pack_int4",
    "unpack_int4",
    "fake_quant_int4",
]

QMAX = 7  # symmetric int4: [-8, 7], scale on |max| -> 7


def pack_int4(q: np.ndarray | jax.Array) -> jax.Array:
    """(…, K) int8 in [-8,7] -> (…, K//2) uint8, low nibble = even index."""
    q = jnp.asarray(q, dtype=jnp.int8)
    if q.shape[-1] % 2:
        raise ValueError("last dim must be even to pack int4 pairs")
    lo = (q[..., 0::2] & 0x0F).astype(jnp.uint8)
    hi = (q[..., 1::2] & 0x0F).astype(jnp.uint8)
    return lo | (hi << 4)


def unpack_int4(p: jax.Array) -> jax.Array:
    """(…, K//2) uint8 -> (…, K) int8 in [-8, 7]."""
    lo = (p & 0x0F).astype(jnp.int8)
    hi = ((p >> 4) & 0x0F).astype(jnp.int8)
    # sign-extend nibbles
    lo = jnp.where(lo > 7, lo - 16, lo)
    hi = jnp.where(hi > 7, hi - 16, hi)
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*p.shape[:-1], p.shape[-1] * 2)


def quantize_int4(
    w: np.ndarray | jax.Array,
    group_size: int = 128,
    scale_dtype=jnp.bfloat16,
) -> dict[str, Any]:
    """Quantize (out, in) weight -> {"qweight": packed uint8 (out, in//2),
    "scales": (out, in//group_size)} symmetric per-group."""
    w = jnp.asarray(w, dtype=jnp.float32)
    out_f, in_f = w.shape
    if in_f % group_size:
        raise ValueError(f"in_features {in_f} not divisible by group {group_size}")
    g = w.reshape(out_f, in_f // group_size, group_size)
    amax = jnp.max(jnp.abs(g), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / QMAX, 1.0)
    q = jnp.clip(jnp.round(g / scale), -8, 7).astype(jnp.int8)
    return {
        "qweight": pack_int4(q.reshape(out_f, in_f)),
        "scales": scale[..., 0].astype(scale_dtype),
    }


def dequantize_int4(qparams: dict[str, Any], dtype=jnp.bfloat16) -> jax.Array:
    """Packed int4 -> dense (out, in) weight."""
    q = unpack_int4(qparams["qweight"])  # (out, in) int8
    out_f, in_f = q.shape
    scales = qparams["scales"].astype(jnp.float32)  # (out, groups)
    group = in_f // scales.shape[1]
    w = q.reshape(out_f, scales.shape[1], group).astype(jnp.float32) * scales[..., None]
    return w.reshape(out_f, in_f).astype(dtype)


def int4_matmul_ref(x: jax.Array, qparams: dict[str, Any]) -> jax.Array:
    """y = x @ W^T with int4-packed W (pure-JAX reference / CPU fallback)."""
    w = dequantize_int4(qparams, dtype=jnp.bfloat16)
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(x.dtype)


def fake_quant_int4(w: jax.Array, group_size: int = 128) -> jax.Array:
    """Quantize-dequantize roundtrip in float (for accuracy-delta evals)."""
    return dequantize_int4(quantize_int4(w, group_size), dtype=w.dtype)

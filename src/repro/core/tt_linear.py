"""TT-compressed linear layer: staged-contraction inference (paper Eq. 4).

The layer never reconstructs the dense weight.  The tensorized input is
contracted through the cores one mode at a time; between stages the data is
*reordered* exactly as the paper's ping-pong buffers do — here the reorder is
a reshape/transpose that XLA keeps on-chip (and that the Pallas kernel in
``repro.kernels.tt_linear`` keeps in VMEM scratch).

Stage k (paper Eq. 4):

    P̄_k[t_{k-1}, (j_k, r_k)] = Σ_{(r_{k-1}, i_k)} C_k[(r_{k-1},i_k), (j_k,r_k)]
                                                  · P_{k-1}[t_{k-1}, (r_{k-1},i_k)]

with t_{k-1} = (i_{k+1}, …, i_d, j_1, …, j_{k-1});  P_0 = tensorized x,
P̄_d = tensorized y.

Params layout: ``{"cores": [C_1, …, C_d]}`` with C_k of shape
``(r_{k-1}·n_k, m_k·r_k)`` — see ``repro.core.ttd`` for conventions.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .ttd import TTSpec, cores_to_matrices, tt_svd

__all__ = ["tt_linear_apply", "init_tt_linear", "tt_linear_from_dense", "tt_stage_shapes"]


def tt_stage_shapes(spec: TTSpec, batch: int) -> list[tuple[int, int, int]]:
    """(rows, contract, cols) of each stage's matmul for a given token count."""
    shapes = []
    m_prod = 1
    for k in range(spec.d):
        t_dim = math.prod(spec.in_modes[k + 1 :]) * m_prod
        shapes.append(
            (
                batch * t_dim,
                spec.ranks[k] * spec.in_modes[k],
                spec.out_modes[k] * spec.ranks[k + 1],
            )
        )
        m_prod *= spec.out_modes[k]
    return shapes


def _tt_apply(cores, p, spec: TTSpec, accum_dtype) -> jax.Array:
    """Staged contraction keeping ALL leading dims intact: (*L, N) -> (*L, M).

    Never merging the (batch, seq) leading dims means the activation
    sharding (batch→data, seq→model) propagates untouched through every
    stage — no resharding inside the TT segment (DESIGN.md §4 SP-for-TT).
    """
    lead = p.shape[:-1]
    nl = len(lead)
    n, m, d = spec.in_modes, spec.out_modes, spec.d
    # store inter-stage tensors in the input dtype (bf16 halves the live
    # intermediate footprint); every contraction still accumulates in f32
    store_dtype = p.dtype if p.dtype != jnp.float64 else jnp.float32

    p = p.reshape(*lead, n[0], math.prod(n[1:]))
    p = jnp.swapaxes(p, nl, nl + 1)  # (*L, T_0, r_0*n_1)

    m_prod = 1
    for k in range(d):
        c_k = cores[k].astype(store_dtype)
        p = p.astype(store_dtype)
        # (*L, T, r_k*n_k) @ (r_k*n_k, m_k*r_{k+1})
        p = jax.lax.dot_general(
            p, c_k, (((nl + 1,), (0,)), ((), ())),
            preferred_element_type=accum_dtype,
        ).astype(store_dtype)
        if k < d - 1:
            # reorder (paper's ping-pong): (*L, n_{k+1}, NR, MP, m_k, r_k)
            #                           -> (*L, NR, MP*m_k, r_k, n_{k+1})
            nr = math.prod(n[k + 2 :])
            p = p.reshape(*lead, n[k + 1], nr, m_prod, m[k], spec.ranks[k + 1])
            perm = tuple(range(nl)) + (nl + 1, nl + 2, nl + 3, nl + 4, nl)
            p = p.transpose(perm)
            m_prod *= m[k]
            p = p.reshape(*lead, nr * m_prod, spec.ranks[k + 1] * n[k + 1])
    return p.reshape(*lead, spec.n_out)


def tt_linear_apply(
    params: dict[str, Any],
    x: jax.Array,
    spec: TTSpec,
    *,
    accum_dtype=jnp.float32,
) -> jax.Array:
    """Apply the TT linear to ``x`` of shape (..., N) -> (..., M)."""
    cores = params["cores"]
    out_dtype = x.dtype
    if x.ndim == 1:
        return _tt_apply(cores, x[None], spec, accum_dtype)[0].astype(out_dtype)
    return _tt_apply(cores, x, spec, accum_dtype).astype(out_dtype)


def init_tt_linear(
    key: jax.Array,
    spec: TTSpec,
    dtype=jnp.float32,
    *,
    scale: float | None = None,
) -> dict[str, Any]:
    """Random init whose implied dense weight matches fan-in variance.

    Var(W_ij) = (Π_{k=1..d-1} r_k) · Π_k σ_k²  ⇒  σ_k = (σ_W²/R)^(1/2d),
    with target σ_W² = scale²/N (default scale=1, i.e. LeCun/fan-in).
    """
    scale = 1.0 if scale is None else scale
    var_w = scale**2 / spec.n_in
    r_interior = math.prod(spec.ranks[1:-1]) or 1
    sigma_k = (var_w / r_interior) ** (1.0 / (2 * spec.d))
    cores = []
    for k, shp in enumerate(spec.core_matrix_shapes()):
        key, sub = jax.random.split(key)
        cores.append(jax.random.normal(sub, shp, dtype=jnp.float32).astype(dtype) * sigma_k)
    return {"cores": cores}


def tt_linear_from_dense(
    w: np.ndarray,
    spec: TTSpec,
    dtype=jnp.float32,
    method: str = "auto",
) -> dict[str, Any]:
    """TT-SVD a dense (M, N) weight into matrix-layout cores (paper Alg. 1)."""
    cores3d = tt_svd(np.asarray(w), spec, method=method)
    mats = cores_to_matrices(cores3d, spec)
    return {"cores": [jnp.asarray(c, dtype=dtype) for c in mats]}

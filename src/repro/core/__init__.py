"""Core of the reproduction: Tensor-Train decomposition of LLM linear layers
(paper SII) + staged-contraction inference (paper SIII) + INT4 quantization
and the whole-model compression pipeline (paper SV.A)."""

from .ttd import (  # noqa: F401
    TTSpec,
    factorize,
    tt_svd,
    tt_reconstruct,
    tt_params,
    compression_ratio,
    cores_to_matrices,
    matrices_to_cores,
    tensorize_weight,
    untensorize_weight,
)
from .tt_linear import (  # noqa: F401
    tt_linear_apply,
    init_tt_linear,
    tt_linear_from_dense,
    tt_stage_shapes,
)
from .quant import (  # noqa: F401
    quantize_int4,
    dequantize_int4,
    int4_matmul_ref,
    fake_quant_int4,
    pack_int4,
    unpack_int4,
)

"""Automatic rank / factorization search for TT compression.

The paper fixes d=4, rank=16 by hand (Table I).  For the assigned
architectures we need TT specs for arbitrary (M, N); this module searches
(d, factorization, rank) either analytically (target CR, no weight needed)
or empirically (relative Frobenius error budget on a given weight), in the
spirit of RankSearch [16].
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .ttd import TTSpec, factorize, tt_reconstruct, tt_svd

__all__ = ["RankChoice", "search_spec", "spec_for_layer", "tt_error"]


@dataclass(frozen=True)
class RankChoice:
    spec: TTSpec
    cr: float
    rel_error: float | None = None


def tt_error(w: np.ndarray, spec: TTSpec, method: str = "auto") -> float:
    """Relative Frobenius reconstruction error of TT-SVD at this spec."""
    cores = tt_svd(w, spec, method=method)
    w_hat = tt_reconstruct(cores, spec)
    denom = float(np.linalg.norm(w)) or 1.0
    return float(np.linalg.norm(w - np.asarray(w_hat, w.dtype))) / denom


def search_spec(
    n_in: int,
    n_out: int,
    *,
    target_cr: float | None = None,
    max_error: float | None = None,
    weight: np.ndarray | None = None,
    ds: tuple[int, ...] = (3, 4, 5),
    ranks: tuple[int, ...] = (4, 8, 16, 32, 64),
) -> RankChoice:
    """Pick (d, balanced factorization, uniform rank).

    - ``target_cr`` given: return the highest-rank spec whose CR >= target
      (ties broken by lower error when a weight is supplied).
    - ``max_error`` given (requires ``weight``): return the highest-CR spec
      with rel_error <= max_error.
    - neither: return the max-CR spec at the paper's defaults (d=4, r=16 when
      attainable).
    """
    candidates: list[RankChoice] = []
    for d in ds:
        in_m = factorize(n_in, d)
        out_m = factorize(n_out, d)
        if 1 in in_m or 1 in out_m:  # degenerate factorization, skip
            continue
        for r in ranks:
            spec = TTSpec.make(n_in, n_out, r, d=d, in_modes=in_m, out_modes=out_m)
            cr = spec.compression_ratio()
            if cr <= 1.0:
                continue
            err = tt_error(weight, spec) if weight is not None else None
            candidates.append(RankChoice(spec, cr, err))
    if not candidates:
        raise ValueError(f"no valid TT spec for ({n_out}x{n_in})")

    if max_error is not None:
        ok = [c for c in candidates if c.rel_error is not None and c.rel_error <= max_error]
        pool = ok or candidates
        return max(pool, key=lambda c: c.cr)
    if target_cr is not None:
        ok = [c for c in candidates if c.cr >= target_cr]
        pool = ok or candidates
        # most expressive (lowest CR above target = highest rank budget);
        # equal-CR candidates are distinguished by reconstruction error when
        # a weight was supplied (unmeasured candidates sort last)
        return min(pool, key=lambda c: (
            c.cr, c.rel_error if c.rel_error is not None else float("inf")))
    # paper default: d=4, r=16 if attainable
    for c in candidates:
        if c.spec.d == 4 and max(c.spec.ranks) == 16:
            return c
    return max(candidates, key=lambda c: c.cr)


def spec_for_layer(
    n_in: int,
    n_out: int,
    rank: int = 16,
    d: int = 4,
    in_modes=None,
    out_modes=None,
) -> TTSpec:
    """Paper-style spec: explicit modes when given (Table I), else balanced."""
    return TTSpec.make(n_in, n_out, rank, d=d, in_modes=in_modes, out_modes=out_modes)

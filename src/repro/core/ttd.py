"""Tensor-Train decomposition of linear-layer weights (paper §II, Algorithm 1).

Conventions
-----------
A linear layer computes ``y = W @ x`` with ``W ∈ R^{M×N}``, ``M = Π m_k``,
``N = Π n_k``.  The weight is *tensorized* into a d-mode tensor with mode
sizes ``v_k = m_k · n_k`` (m-major within each mode):

    T[μ_1, …, μ_d] = W[flat(i_1…i_d), flat(j_1…j_d)],   μ_k = i_k·n_k + j_k

TT-SVD (Oseledets 2011; paper Algorithm 1) factorizes T into cores

    G_k ∈ R^{r_{k-1} × v_k × r_k},   r_0 = r_d = 1.

For inference we keep each core in **matrix layout**

    C_k ∈ R^{(r_{k-1}·n_k) × (m_k·r_k)}    (rows r-major, cols m-major)

which is the shape the staged contraction (paper Eq. 4) and the Pallas
kernel consume directly.

Compression ratio (paper Eq. 2):  CR = Π v_k / Σ v_k·r_{k-1}·r_k.

The decomposition itself is an *offline* step and runs in numpy (float64 by
default for numerical headroom); inference paths are jax (see tt_linear.py
and kernels/).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence

import numpy as np

__all__ = [
    "TTSpec",
    "factorize",
    "tensorize_weight",
    "untensorize_weight",
    "tt_svd",
    "tt_reconstruct",
    "tt_params",
    "compression_ratio",
    "cores_to_matrices",
    "matrices_to_cores",
]


# ---------------------------------------------------------------------------
# Spec
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TTSpec:
    """Static description of one TT-compressed linear layer.

    ``in_modes``  = (n_1, …, n_d)   with Π n_k = N (input features)
    ``out_modes`` = (m_1, …, m_d)   with Π m_k = M (output features)
    ``ranks``     = (r_0, r_1, …, r_d) with r_0 = r_d = 1.
    """

    in_modes: tuple[int, ...]
    out_modes: tuple[int, ...]
    ranks: tuple[int, ...]

    def __post_init__(self):
        if len(self.in_modes) != len(self.out_modes):
            raise ValueError("in_modes and out_modes must have equal length")
        if len(self.ranks) != len(self.in_modes) + 1:
            raise ValueError("ranks must have length d+1")
        if self.ranks[0] != 1 or self.ranks[-1] != 1:
            raise ValueError("boundary ranks must be 1")

    @property
    def d(self) -> int:
        return len(self.in_modes)

    @property
    def n_in(self) -> int:
        return math.prod(self.in_modes)

    @property
    def n_out(self) -> int:
        return math.prod(self.out_modes)

    @property
    def mode_sizes(self) -> tuple[int, ...]:
        return tuple(m * n for m, n in zip(self.out_modes, self.in_modes))

    def core_matrix_shapes(self) -> list[tuple[int, int]]:
        """Shapes of the matrix-layout cores C_k."""
        return [
            (self.ranks[k] * self.in_modes[k], self.out_modes[k] * self.ranks[k + 1])
            for k in range(self.d)
        ]

    def n_params(self) -> int:
        return sum(r * c for r, c in self.core_matrix_shapes())

    def compression_ratio(self) -> float:
        return (self.n_in * self.n_out) / self.n_params()

    def flops_per_token(self) -> int:
        """MAC*2 count of the staged contraction for one input vector."""
        total = 0
        rest_n = list(self.in_modes)
        m_prod = 1
        for k in range(self.d):
            contract = self.ranks[k] * self.in_modes[k]
            out_cols = self.out_modes[k] * self.ranks[k + 1]
            t_dim = math.prod(rest_n[k + 1 :]) * m_prod
            total += 2 * t_dim * contract * out_cols
            m_prod *= self.out_modes[k]
        return total

    def max_intermediate(self) -> int:
        """Largest per-token intermediate element count across stages."""
        best = self.n_in
        m_prod = 1
        for k in range(self.d):
            m_prod *= self.out_modes[k]
            sz = math.prod(self.in_modes[k + 1 :]) * m_prod * self.ranks[k + 1]
            best = max(best, sz)
        return best

    @staticmethod
    def make(
        n_in: int,
        n_out: int,
        rank: int | Sequence[int],
        d: int = 4,
        in_modes: Sequence[int] | None = None,
        out_modes: Sequence[int] | None = None,
    ) -> "TTSpec":
        """Build a spec, auto-factorizing dims unless modes are given
        (paper Algorithm 1 lines 1-2)."""
        in_modes = tuple(in_modes) if in_modes is not None else factorize(n_in, d)
        out_modes = tuple(out_modes) if out_modes is not None else factorize(n_out, d)
        d = len(in_modes)
        if isinstance(rank, int):
            ranks = [1] + [rank] * (d - 1) + [1]
        else:
            ranks = list(rank)
            if len(ranks) == d - 1:  # interior ranks only
                ranks = [1] + ranks + [1]
        # clamp ranks to the maximal attainable TT-ranks
        v = [m * n for m, n in zip(out_modes, in_modes)]
        for k in range(1, d):
            left = math.prod(v[:k])
            right = math.prod(v[k:])
            ranks[k] = min(ranks[k], left, right)
        return TTSpec(tuple(in_modes), tuple(out_modes), tuple(ranks))


# ---------------------------------------------------------------------------
# Factorization helper (Algorithm 1, lines 1-2)
# ---------------------------------------------------------------------------
def _prime_factors(n: int) -> list[int]:
    out = []
    f = 2
    while f * f <= n:
        while n % f == 0:
            out.append(f)
            n //= f
        f += 1
    if n > 1:
        out.append(n)
    return out


def factorize(n: int, d: int) -> tuple[int, ...]:
    """Split ``n`` into ``d`` factors, as balanced as possible.

    Greedy: repeatedly multiply the largest remaining prime into the
    currently-smallest bucket.  Deterministic; returns factors sorted
    descending (matching the paper's convention, e.g. 13696 -> (107,8,4,4)).
    """
    if n <= 0:
        raise ValueError("n must be positive")
    primes = sorted(_prime_factors(n), reverse=True)
    buckets = [1] * d
    for p in primes:
        buckets[int(np.argmin(buckets))] *= p
    return tuple(sorted(buckets, reverse=True))


# ---------------------------------------------------------------------------
# Tensorization (paper §II.B)
# ---------------------------------------------------------------------------
def tensorize_weight(w: np.ndarray, spec: TTSpec) -> np.ndarray:
    """(M, N) weight -> (v_1, …, v_d) tensor with μ_k = i_k·n_k + j_k."""
    m, n = spec.out_modes, spec.in_modes
    d = spec.d
    if w.shape != (spec.n_out, spec.n_in):
        raise ValueError(f"weight shape {w.shape} != ({spec.n_out},{spec.n_in})")
    t = w.reshape(*m, *n)
    perm = [x for k in range(d) for x in (k, d + k)]  # interleave (m_k, n_k)
    t = t.transpose(perm)
    return t.reshape(spec.mode_sizes)


def untensorize_weight(t: np.ndarray, spec: TTSpec) -> np.ndarray:
    """Inverse of :func:`tensorize_weight`."""
    m, n = spec.out_modes, spec.in_modes
    d = spec.d
    t = t.reshape([x for k in range(d) for x in (m[k], n[k])])
    perm = [2 * k for k in range(d)] + [2 * k + 1 for k in range(d)]
    return t.transpose(perm).reshape(spec.n_out, spec.n_in)


# ---------------------------------------------------------------------------
# TT-SVD (paper Algorithm 1, lines 7-18)
# ---------------------------------------------------------------------------
def _truncated_left_factor(c: np.ndarray, rank: int, method: str):
    """Return (U_r, rest) with c ≈ U_r @ rest, U_r orthonormal columns.

    method 'svd'  : exact thin SVD (reference path).
    method 'gram' : eigendecomposition of c @ c.T — O(rows²·cols), exact for
                    the retained subspace, much faster when rows ≪ cols
                    (always true for our layer shapes: rows = r·v_k ≲ 4k).
    """
    rows = c.shape[0]
    r = min(rank, rows, c.shape[1])
    if method == "auto":
        method = "gram" if c.shape[1] > 4 * rows and rows > 64 else "svd"
    if method == "svd":
        u, s, vt = np.linalg.svd(c, full_matrices=False)
        return u[:, :r], s[:r, None] * vt[:r]
    elif method == "gram":
        g = c @ c.T
        w, v = np.linalg.eigh(g)  # ascending
        idx = np.argsort(w)[::-1][:r]
        u = v[:, idx]
        return u, u.T @ c
    raise ValueError(f"unknown method {method}")


def tt_svd(
    w: np.ndarray,
    spec: TTSpec,
    method: str = "auto",
    dtype=np.float64,
) -> list[np.ndarray]:
    """TT-SVD of a (M, N) weight; returns 3D cores G_k (r_{k-1}, v_k, r_k)."""
    c = tensorize_weight(np.asarray(w, dtype=dtype), spec)
    v = spec.mode_sizes
    d = spec.d
    cores: list[np.ndarray] = []
    r_prev = 1
    c = c.reshape(r_prev * v[0], -1)
    for k in range(d - 1):
        u, rest = _truncated_left_factor(c, spec.ranks[k + 1], method)
        r_k = u.shape[1]
        if r_k != spec.ranks[k + 1]:
            raise ValueError(
                f"attained rank {r_k} < requested {spec.ranks[k + 1]} at core {k}; "
                "clamp ranks via TTSpec.make"
            )
        cores.append(u.reshape(r_prev, v[k], r_k))
        r_prev = r_k
        c = rest.reshape(r_prev * v[k + 1], -1)
    cores.append(c.reshape(r_prev, v[d - 1], 1))
    return cores


def tt_reconstruct(cores: list[np.ndarray], spec: TTSpec) -> np.ndarray:
    """Contract cores back to the dense (M, N) weight (for validation)."""
    t = cores[0]  # (1, v_1, r_1)
    for g in cores[1:]:
        t = np.tensordot(t, g, axes=([-1], [0]))  # (..., v_k, r_k)
    t = t.reshape(spec.mode_sizes)
    return untensorize_weight(t, spec)


# ---------------------------------------------------------------------------
# Layout conversion: 3D cores <-> matrix cores
# ---------------------------------------------------------------------------
def cores_to_matrices(cores: list[np.ndarray], spec: TTSpec) -> list[np.ndarray]:
    """G_k (r_{k-1}, v_k, r_k) -> C_k ((r_{k-1}·n_k), (m_k·r_k)).

    Mode index is m-major (μ = i·n + j) so the 3D core reshapes to
    (r_{k-1}, m_k, n_k, r_k); the matrix layout wants rows (r_{k-1}, n_k)
    and cols (m_k, r_k).
    """
    out = []
    for k, g in enumerate(cores):
        r0, v, r1 = g.shape
        m_k, n_k = spec.out_modes[k], spec.in_modes[k]
        g4 = g.reshape(r0, m_k, n_k, r1)
        c = g4.transpose(0, 2, 1, 3).reshape(r0 * n_k, m_k * r1)
        out.append(np.ascontiguousarray(c))
    return out


def matrices_to_cores(mats: list, spec: TTSpec) -> list[np.ndarray]:
    """Inverse of :func:`cores_to_matrices`."""
    out = []
    for k, c in enumerate(mats):
        c = np.asarray(c)
        r0, r1 = spec.ranks[k], spec.ranks[k + 1]
        m_k, n_k = spec.out_modes[k], spec.in_modes[k]
        g4 = c.reshape(r0, n_k, m_k, r1).transpose(0, 2, 1, 3)
        out.append(np.ascontiguousarray(g4.reshape(r0, m_k * n_k, r1)))
    return out


# ---------------------------------------------------------------------------
# Accounting (paper Eq. 2 / Table I)
# ---------------------------------------------------------------------------
def tt_params(spec: TTSpec) -> int:
    return spec.n_params()


def compression_ratio(spec: TTSpec) -> float:
    return spec.compression_ratio()

"""Whole-model compression pipeline (the paper's §V.A recipe).

``compress_model(dense_params, dense_cfg, target_cfg)`` converts a trained
dense checkpoint into the target config's parameterization:

  * linears whose target spec is ``tt``   -> TT-SVD cores (Algorithm 1)
  * linears whose target spec is ``int4`` -> packed int4 + per-group scales
  * everything else                       -> copied

``compression_report(cfg)`` computes Table-I-style CR accounting (per layer
role / per block / whole network, in parameter counts and in storage bits)
without needing any weights.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..config import ModelConfig
from ..models.modules import LinearSpec, linear_param_bits, linear_param_count
from .quant import quantize_int4
from .ttd import TTSpec, cores_to_matrices, tt_svd


# ---------------------------------------------------------------------------
# Weight conversion
# ---------------------------------------------------------------------------
def _convert_linear(p_dense: dict[str, Any], spec: LinearSpec, svd_method: str):
    """p_dense: {"w": (..., n_in, n_out)[, "b"]} -> target params subtree.

    An embedding table rides the same path: ``{"table": (V, D)}`` is a
    transposed linear ``w`` (the TT's (M, N) weight has M = V), so the
    shared ``flat[i].T`` below hands TT-SVD the (V, D) matrix directly.
    """
    if "table" in p_dense:
        if spec.kind != "tt":
            raise ValueError(
                f"embedding tables only compress to TT cores, got {spec.kind!r}")
        w = np.asarray(p_dense["table"], dtype=np.float32).T  # (D, V) ~ (n_in, n_out)
    else:
        w = np.asarray(p_dense["w"], dtype=np.float32)
    lead = w.shape[:-2]
    flat = w.reshape((-1,) + w.shape[-2:])
    out: dict[str, Any] = {}
    if spec.kind == "dense":
        out["w"] = jnp.asarray(w)
    elif spec.kind == "tt":
        per_core: list[list[np.ndarray]] = [[] for _ in range(spec.tt.d)]
        for i in range(flat.shape[0]):
            cores3d = tt_svd(flat[i].T, spec.tt, method=svd_method)  # (M,N) layout
            mats = cores_to_matrices(cores3d, spec.tt)
            for k, m in enumerate(mats):
                per_core[k].append(np.asarray(m, np.float32))
        cores = [np.stack(cs).reshape(lead + cs[0].shape) if lead else cs[0]
                 for cs in per_core]
        out["cores"] = [jnp.asarray(c) for c in cores]
    elif spec.kind == "int4":
        qws, scs = [], []
        for i in range(flat.shape[0]):
            q = quantize_int4(flat[i].T, spec.quant_group)  # (out, in) layout
            qws.append(np.asarray(q["qweight"]))
            scs.append(np.asarray(q["scales"]))
        out["qweight"] = jnp.asarray(np.stack(qws).reshape(lead + qws[0].shape) if lead else qws[0])
        out["scales"] = jnp.asarray(np.stack(scs).reshape(lead + scs[0].shape) if lead else scs[0])
    else:
        raise ValueError(spec.kind)
    if "b" in p_dense:
        out["b"] = jnp.asarray(p_dense["b"])
    return out


def _walk(p_dense, spec_tree, svd_method, path=""):
    if isinstance(spec_tree, LinearSpec):
        return _convert_linear(p_dense, spec_tree, svd_method)
    if spec_tree is None:
        return p_dense
    if isinstance(spec_tree, dict):
        missing = set(spec_tree) - set(p_dense)
        if missing:
            # a dangling spec key would otherwise drop its conversion silently
            raise ValueError(
                f"compress: spec keys {sorted(missing)} at "
                f"{path or '<root>'!r} have no matching param entries")
        return {k: _walk(p_dense[k], spec_tree[k], svd_method,
                         f"{path}/{k}" if path else k) if k in spec_tree
                else p_dense[k] for k in p_dense}
    if isinstance(spec_tree, (list, tuple)):
        if len(p_dense) != len(spec_tree):
            # a silent zip here would drop trailing layers uncompressed
            raise ValueError(
                f"compress: param/spec tree length mismatch at "
                f"{path or '<root>'!r}: {len(p_dense)} param entries vs "
                f"{len(spec_tree)} spec entries")
        return [_walk(p, s, svd_method, f"{path}[{i}]")
                for i, (p, s) in enumerate(zip(p_dense, spec_tree))]
    raise TypeError(type(spec_tree))


def _specs_tree(cfg: ModelConfig):
    if cfg.family in ("dense", "moe"):
        from ..models import transformer
        return transformer.specs_tree(cfg)
    if cfg.family == "rwkv":
        from ..models import rwkv
        return rwkv.specs_tree(cfg)
    if cfg.family == "griffin":
        from ..models import griffin
        return griffin.specs_tree(cfg)
    if cfg.family == "encdec":
        from ..models import whisper
        return whisper.specs_tree(cfg)
    raise ValueError(cfg.family)


def compress_model(dense_params, dense_cfg: ModelConfig, target_cfg: ModelConfig,
                   svd_method: str = "auto"):
    """Dense checkpoint -> target (TT/int4) parameterization."""
    tree = _specs_tree(target_cfg)
    if target_cfg.family in ("dense", "moe"):
        from ..models.transformer import segment_plan
        # re-split the dense layer stack to the target segment boundaries
        dense_stack = dense_params["segments"]
        cat = jax.tree.map(lambda *xs: np.concatenate([np.asarray(x) for x in xs], 0),
                           *dense_stack) if len(dense_stack) > 1 else \
            jax.tree.map(np.asarray, dense_stack[0])
        segs, off = [], 0
        for n, _ in segment_plan(target_cfg):
            segs.append(jax.tree.map(lambda a, n=n, off=off: a[off:off + n], cat))
            off += n
        dense_params = dict(dense_params)
        dense_params["segments"] = segs
    return _walk(dense_params, tree, svd_method)


# ---------------------------------------------------------------------------
# CR accounting (Table I reproduction)
# ---------------------------------------------------------------------------
@dataclass
class RoleReport:
    role: str
    kind: str
    n_in: int
    n_out: int
    dense_params: int
    params: int
    bits: int

    @property
    def cr(self) -> float:
        return self.dense_params / max(self.params, 1)


@dataclass
class CompressionReport:
    name: str
    roles: list[RoleReport] = field(default_factory=list)
    block_dense: int = 0  # params of one (uncompressed) block
    block_comp: int = 0  # params of one compressed block
    n_blocks: int = 0
    n_tt_blocks: int = 0
    embed_params: int = 0  # dense embedding storage (table counted once when tied)
    embed_params_comp: int = 0  # after TT embed compression (== embed_params when off)
    block_bits_dense: int = 0
    block_bits_comp: int = 0

    @property
    def block_cr(self) -> float:
        return self.block_dense / max(self.block_comp, 1)

    @property
    def network_cr(self) -> float:
        """Paper convention: transformer blocks only (validated in DESIGN.md)."""
        total_dense = self.n_blocks * self.block_dense
        total_comp = (self.n_tt_blocks * self.block_comp
                      + (self.n_blocks - self.n_tt_blocks) * self.block_dense)
        return total_dense / max(total_comp, 1)

    @property
    def network_cr_with_embed(self) -> float:
        total_dense = self.n_blocks * self.block_dense + self.embed_params
        total_comp = (self.n_tt_blocks * self.block_comp
                      + (self.n_blocks - self.n_tt_blocks) * self.block_dense
                      + self.embed_params_comp)
        return total_dense / max(total_comp, 1)

    @property
    def network_cr_bits(self) -> float:
        total_dense = self.n_blocks * self.block_bits_dense
        total_comp = (self.n_tt_blocks * self.block_bits_comp
                      + (self.n_blocks - self.n_tt_blocks) * self.block_bits_dense)
        return total_dense / max(total_comp, 1)


def _collect_linear_specs(tree, prefix="") -> list[tuple[str, LinearSpec]]:
    out = []
    if isinstance(tree, LinearSpec):
        return [(prefix, tree)]
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.extend(_collect_linear_specs(v, f"{prefix}/{k}" if prefix else k))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.extend(_collect_linear_specs(v, f"{prefix}[{i}]"))
    return out


_DTYPE_BITS = {"float32": 32, "bfloat16": 16, "float16": 16}


def compression_report(cfg: ModelConfig,
                       param_bits: int | None = None) -> CompressionReport:
    """Per-role + block + network CR for a transformer-family config
    (the paper's Table I columns).

    ``param_bits`` is the *dense baseline* storage width; by default it is
    derived from ``cfg.param_dtype`` instead of a global 16 so a float32
    config reports honest bit-CRs.  Mixed compressed kinds already count
    their own widths per role (int4 weights 4 bits + f16 group scales, TT
    cores ``param_bits``) via ``linear_param_bits``.
    """
    from ..models.modules import embed_spec
    from ..models.transformer import make_block_specs, segment_plan

    if param_bits is None:
        param_bits = _DTYPE_BITS.get(cfg.param_dtype, 32)
    rep = CompressionReport(name=cfg.name)
    rep.n_blocks = cfg.n_layers
    plan = segment_plan(cfg)
    rep.n_tt_blocks = sum(n for n, tt in plan if tt)
    head = 0 if cfg.tie_embeddings else cfg.vocab_size * cfg.d_model
    rep.embed_params = cfg.vocab_size * cfg.d_model + head
    esp = embed_spec(cfg)
    rep.embed_params_comp = (
        (esp.tt.n_params() if esp is not None else cfg.vocab_size * cfg.d_model)
        + head)  # untied head stays dense under TT embed compression

    comp_specs = make_block_specs(cfg, ttd_block=True)
    base_specs = make_block_specs(cfg.replace(ttd=cfg.ttd.__class__(enabled=False),
                                              quant=cfg.quant.__class__(enabled=False)),
                                  ttd_block=False)

    def spec_list(bs):
        out = list(bs.attn)
        if bs.moe is not None:
            out.append(("router", bs.moe["router"]))
            for nm, sp in bs.moe["expert"].items():
                out.append((f"expert_{nm}", sp))
        else:
            out.extend(bs.mlp)
        return out

    mult = {  # per-block multiplicity of each role
        nm: (cfg.n_experts if nm.startswith("expert_") else 1)
        for nm, _ in spec_list(comp_specs)
    }
    for (nm, sp), (_, sp0) in zip(spec_list(comp_specs), spec_list(base_specs)):
        m = mult[nm]
        rr = RoleReport(role=nm, kind=sp.kind, n_in=sp.n_in, n_out=sp.n_out,
                        dense_params=linear_param_count(sp0),
                        params=linear_param_count(sp),
                        bits=linear_param_bits(sp, param_bits))
        rep.roles.append(rr)
        rep.block_dense += m * rr.dense_params
        rep.block_comp += m * rr.params
        rep.block_bits_dense += m * linear_param_bits(sp0, param_bits)
        rep.block_bits_comp += m * rr.bits
    return rep


# ---------------------------------------------------------------------------
# Compression → serving handoff.  A compressed tree is only interpretable
# together with the target cfg it was compressed *for* (the specs ride the
# cfg, not the tree — DESIGN.md §11), so the checkpoint carries the cfg in
# its manifest and loading validates structure eagerly instead of
# shape-failing inside a jitted step.
# ---------------------------------------------------------------------------
_KIND_KEYS = {"dense": ("w",), "tt": ("cores",), "int4": ("qweight", "scales")}


def validate_compressed_params(cfg: ModelConfig, params) -> None:
    """Raise ``ValueError`` naming every leaf where ``params`` does not
    structurally match ``cfg``'s spec tree (wrong kind, missing keys)."""
    errs: list[str] = []

    def walk(p, s, path):
        if isinstance(s, LinearSpec):
            want = set(_KIND_KEYS[s.kind]) | ({"b"} if s.bias else set())
            have = set(p) if isinstance(p, dict) else set()
            if want - have:
                kinds = [k for k, keys in _KIND_KEYS.items()
                         if set(keys) <= have]
                got = f"a {kinds[0]!r} subtree" if kinds else f"keys {sorted(have)}"
                errs.append(f"{path or '<root>'}: expected {s.kind!r} linear "
                            f"(keys {sorted(want)}), tree has {got}")
            elif s.kind == "tt" and len(p["cores"]) != s.tt.d:
                errs.append(f"{path or '<root>'}: {len(p['cores'])} TT cores "
                            f"vs spec d={s.tt.d}")
            return
        if s is None:
            return
        if isinstance(s, dict):
            if not isinstance(p, dict) or set(s) - set(p):
                errs.append(f"{path or '<root>'}: missing keys "
                            f"{sorted(set(s) - set(p if isinstance(p, dict) else ())) }")
                return
            for k in s:
                walk(p[k], s[k], f"{path}/{k}" if path else k)
            return
        if len(p) != len(s):
            errs.append(f"{path or '<root>'}: {len(p)} param entries vs "
                        f"{len(s)} spec entries")
            return
        for i, (pp, ss) in enumerate(zip(p, s)):
            walk(pp, ss, f"{path}[{i}]")

    walk(params, _specs_tree(cfg), "")
    if errs:
        raise ValueError(
            f"param tree does not match config {cfg.name!r} "
            f"(ttd={'on' if cfg.ttd.enabled else 'off'}, "
            f"quant={'on' if cfg.quant.enabled else 'off'}, "
            f"tt_embed={'on' if cfg.ttd.embed else 'off'}) — was it "
            "compressed for a different spec?\n  " + "\n  ".join(errs))


def save_compressed(ckpt_dir, params, cfg: ModelConfig, *, step: int = 0):
    """Checkpoint a compressed tree together with the cfg it serves under."""
    from ..checkpoint.store import save_checkpoint
    from ..config import config_to_dict
    validate_compressed_params(cfg, params)
    return save_checkpoint(ckpt_dir, step, params,
                           extra={"model_config": config_to_dict(cfg)})


def load_compressed(ckpt_dir, step: int | None = None):
    """Load ``(params, cfg)`` saved by :func:`save_compressed`.

    The target structure is rebuilt from the cfg in the manifest (no dense
    re-validation), then checked against the spec tree so a mismatched
    checkpoint fails here with leaf paths, not inside a jitted step.
    """
    import json
    from pathlib import Path

    from ..checkpoint.store import latest_step, restore_checkpoint
    from ..config import config_from_dict
    from ..models import build_model

    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {ckpt_dir}")
    manifest = json.loads(
        (Path(ckpt_dir) / f"step_{step:08d}" / "manifest.json").read_text())
    extra = manifest["extra"]
    if "model_config" not in extra:
        raise ValueError(
            f"checkpoint {ckpt_dir} step {step} carries no model_config — "
            "re-save via core.compress.save_compressed so the target cfg "
            "round-trips with the tree")
    cfg = config_from_dict(extra["model_config"])
    model = build_model(cfg)
    target = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    params, _ = restore_checkpoint(ckpt_dir, step, target)
    mismatch = [
        f"{name}: saved {tuple(np.asarray(got).shape)} vs spec {tuple(want.shape)}"
        for (name, got), (_, want) in zip(
            _flatten_named(params), _flatten_named(target))
        if tuple(np.asarray(got).shape) != tuple(want.shape)]
    if mismatch:
        raise ValueError(
            f"checkpoint {ckpt_dir} step {step} does not match its own "
            f"manifest cfg {cfg.name!r}:\n  " + "\n  ".join(mismatch[:8]))
    validate_compressed_params(cfg, params)
    return params, cfg


def _flatten_named(tree):
    from ..checkpoint.store import _flatten_with_paths
    return _flatten_with_paths(tree)

import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS",
                                         "--xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.config import (QuantConfig, ShapeCell, TrainConfig,  # noqa: E402
                          shape_cell)
from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, get_config  # noqa: E402
from repro.dist.sharding import param_pspecs, param_shardings  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import input_specs  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.optim import init_optimizer  # noqa: E402
from repro.serve.steps import cache_shardings, serve_config_of  # noqa: E402
from repro.train.step import (TrainState, batch_pspec, build_train_step,  # noqa: E402
                              state_pspecs)

# ---------------------------------------------------------------------------
# Cell policy (DESIGN.md §5)
# ---------------------------------------------------------------------------
SUBQUADRATIC = {"rwkv6-7b", "recurrentgemma-2b", "mixtral-8x22b"}
BIG_TRAIN = {"kimi-k2-1t-a32b", "qwen1.5-110b", "mixtral-8x22b"}  # adafactor+mb4
# bf16 sharded params (f32 optimizer math) halves FSDP all-gather traffic;
# hillclimb-2 result, see EXPERIMENTS.md §Perf
BF16_PARAMS = BIG_TRAIN | {"recurrentgemma-2b", "rwkv6-7b"}


def cell_skip_reason(arch: str, cell: ShapeCell) -> str | None:
    if cell.name == "long_500k" and arch not in SUBQUADRATIC:
        return "long_500k requires sub-quadratic attention; skipped for pure full-attention archs"
    return None


def arch_cell_config(arch: str, cell: ShapeCell, *, baseline: bool = False,
                     reduced: bool = False):
    cfg = get_config(arch, reduced=reduced)
    if baseline:
        cfg = cfg.replace(ttd=cfg.ttd.__class__(enabled=False))
    if cell.kind == "train":
        cfg = cfg.replace(quant=QuantConfig(enabled=False),
                          param_dtype="bfloat16" if arch in BF16_PARAMS else "float32")
    else:
        cfg = serve_config_of(cfg)
    if cell.seq_len > cfg.max_seq_len:
        cfg = cfg.replace(max_seq_len=cell.seq_len)
    if os.environ.get("DRYRUN_MOE_IMPL"):
        cfg = cfg.replace(moe_impl=os.environ["DRYRUN_MOE_IMPL"])
    # record the env's dispatch backend on the config itself: the env already
    # outranks cfg in resolve_backend's chain, but pinning here makes the
    # lowered program reproducible from cfg alone (env may change pre-trace)
    if os.environ.get("REPRO_KERNEL_BACKEND"):
        cfg = cfg.replace(kernel_backend=os.environ["REPRO_KERNEL_BACKEND"])
    return cfg


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "s16": 2, "u16": 2, "pred": 1, "s64": 8, "u64": 8}
_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
_TYPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([0-9,]*)\]")


def _shape_bytes(m) -> int:
    dt, dims = m.group(1), m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _group_size(ls: str, n_dev: int) -> int:
    m = _GROUPS_IOTA_RE.search(ls)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(ls)
    if m:
        return len(m.group(1).split(","))
    return n_dev


def collective_bytes(hlo_text: str, n_dev: int = 256) -> dict:
    """Per-device collective traffic by op kind, from the post-SPMD HLO.

    Result bytes are local (post-partition); link traffic per device is
    modeled for ring algorithms over groups of size g:
      all-gather        out·(g-1)/g     (out = full gathered tensor)
      reduce-scatter    out·(g-1)       (out = one shard)
      all-reduce        2·out·(g-1)/g
      all-to-all        out·(g-1)/g
      collective-permute out
    ``*_raw`` fields keep the unweighted result-byte sums."""
    out = {k: 0.0 for k in _COLL_OPS}
    raw = {k: 0 for k in _COLL_OPS}
    out_count = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        if not ls or "=" not in ls:
            continue
        for op in _COLL_OPS:
            # `-start` lines carry the payload type; skip `-done` (would
            # double-count async collectives)
            if re.search(rf"\b{op}-done\(", ls):
                break
            if re.search(rf"\b{op}(-start)?\(", ls):
                lhs = ls.split("=", 1)[1]
                lhs = lhs.split("(", 1)[0]  # result type section
                b = sum(_shape_bytes(m) for m in _TYPE_RE.finditer(lhs))
                g = max(_group_size(ls, n_dev), 1)
                mult = {"all-gather": (g - 1) / g,
                        "reduce-scatter": (g - 1),
                        "all-reduce": 2 * (g - 1) / g,
                        "all-to-all": (g - 1) / g,
                        "collective-permute": 1.0}[op]
                raw[op] += b
                out[op] += b * mult
                out_count += 1
                break
    rec = {k: out[k] for k in _COLL_OPS}
    rec.update({f"{k}_raw": raw[k] for k in _COLL_OPS})
    rec["count"] = out_count
    rec["total"] = sum(out[k] for k in _COLL_OPS)
    rec["total_raw"] = sum(raw[k] for k in _COLL_OPS)
    return rec


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------
def lower_cell(arch: str, cell: ShapeCell, mesh, *, baseline: bool = False,
               optimizer: str | None = None, reduced: bool = False):
    """Lower + compile one (arch × cell) on ``mesh``; return artifacts."""
    cfg = arch_cell_config(arch, cell, baseline=baseline, reduced=reduced)
    model = build_model(cfg)
    batch = input_specs(cfg, cell)

    with jax.set_mesh(mesh):
        if cell.kind == "train":
            opt = optimizer or ("adafactor" if arch in BIG_TRAIN else "adamw")
            mb = 4 if arch in BIG_TRAIN else 1  # cuts activation temps 4x
            tc = TrainConfig(global_batch=cell.global_batch, seq_len=cell.seq_len,
                             optimizer=opt, remat="full", microbatches=mb)
            step = build_train_step(model, tc)
            specs = state_pspecs(model, tc, mesh)
            state_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                                    is_leaf=lambda x: isinstance(x, P))
            bspec = jax.tree.map(
                lambda x: NamedSharding(mesh, batch_pspec(mesh, len(x.shape) - 1)),
                batch)
            if "positions" in batch:  # (3, B, S): batch is dim 1
                bspec["positions"] = NamedSharding(
                    mesh, P(None, ("pod", "data") if "pod" in mesh.axis_names else "data", None))
            def _make_state(key):
                params = model.init(key)
                return TrainState(params=params,
                                  opt=init_optimizer(tc.optimizer, params),
                                  step=jnp.zeros((), jnp.int32))

            state_shapes = jax.eval_shape(_make_state, jax.random.PRNGKey(0))
            jitted = jax.jit(step, in_shardings=(state_sh, bspec),
                             out_shardings=(state_sh, None))
            lowered = jitted.lower(state_shapes, batch)
        elif cell.kind == "prefill":
            pshapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            psh = param_shardings(pshapes, mesh, fsdp=False)
            bspec = jax.tree.map(
                lambda x: NamedSharding(mesh, batch_pspec(mesh, len(x.shape) - 1)),
                batch)
            if "positions" in batch:
                bspec["positions"] = NamedSharding(
                    mesh, P(None, ("pod", "data") if "pod" in mesh.axis_names else "data", None))

            def prefill_step(params, b):
                return model.prefill(params, b, max_len=cell.seq_len)

            jitted = jax.jit(prefill_step, in_shardings=(psh, bspec))
            lowered = jitted.lower(pshapes, batch)
        else:  # decode
            pshapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            psh = param_shardings(pshapes, mesh, fsdp=False)
            cache_dt = getattr(jnp, os.environ.get("DRYRUN_CACHE_DTYPE", "bfloat16"))
            cshapes = jax.eval_shape(
                lambda: model.init_cache(cell.global_batch, cell.seq_len, cache_dt))
            csh = cache_shardings(cshapes, mesh)
            bax = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
            n_b = 1
            for a in bax:
                n_b *= mesh.shape[a]
            bax = bax if cell.global_batch % n_b == 0 else None
            bspec = {"tokens": NamedSharding(mesh, P(bax, None))}
            if "positions" in batch:
                bspec["positions"] = NamedSharding(mesh, P(None, bax, None))

            def serve_step(params, cache, b, pos):
                return model.decode_step(params, cache, b, pos)

            jitted = jax.jit(serve_step, in_shardings=(psh, csh, bspec, None))
            lowered = jitted.lower(pshapes, cshapes, batch,
                                   jax.ShapeDtypeStruct((), jnp.int32))

    t0 = time.perf_counter()
    compiled = lowered.compile()
    compile_s = time.perf_counter() - t0
    return cfg, lowered, compiled, compile_s


def analyze(lowered, compiled, mesh) -> dict:
    n_dev = mesh.devices.size
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # jax 0.4.x: one dict per device
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    mem_d = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "peak_memory_in_bytes"):
        v = getattr(mem, f, None)
        if v is not None:
            mem_d[f] = int(v)
    hlo = compiled.as_text()
    coll = collective_bytes(hlo, int(n_dev))
    return {
        "devices": int(n_dev),
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", cost.get("bytes_accessed", 0.0))),
        "transcendentals": float(cost.get("transcendentals", 0.0)),
        "memory": mem_d,
        "collectives": coll,
        "hlo_ops": len(hlo.splitlines()),
    }


def run_cell(arch: str, cell_name: str, multi_pod: bool, out_dir: Path,
             baseline: bool = False, mesh=None, reduced: bool = False,
             cell: ShapeCell | None = None) -> dict:
    cell = cell or shape_cell(cell_name)
    skip = cell_skip_reason(arch, cell)
    mesh_name = ("custom" if mesh is not None
                 else "2x16x16" if multi_pod else "16x16")
    rec = {"arch": arch, "cell": cell_name, "mesh": mesh_name,
           "baseline": baseline}
    if skip:
        rec["skipped"] = skip
        print(f"[dryrun] SKIP {arch} × {cell_name} × {mesh_name}: {skip}")
    else:
        mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
        t0 = time.perf_counter()
        cfg, lowered, compiled, compile_s = lower_cell(arch, cell, mesh,
                                                       baseline=baseline,
                                                       reduced=reduced)
        rec.update(analyze(lowered, compiled, mesh))
        rec["microbatches"] = 4 if (cell.kind == "train" and arch in BIG_TRAIN) else 1
        rec["compile_s"] = compile_s
        rec["total_s"] = time.perf_counter() - t0
        mem = rec["memory"]
        print(f"[dryrun] OK {arch} × {cell_name} × {mesh_name}"
              f"{' [baseline]' if baseline else ''}: "
              f"flops={rec['flops']:.3e} coll={rec['collectives']['total']:.3e}B "
              f"args={mem.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
              f"temp={mem.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
              f"compile={compile_s:.0f}s")
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = "_baseline" if baseline else ""
    fname = out_dir / f"{arch}_{cell_name}_{mesh_name}{suffix}.json"
    fname.write_text(json.dumps(rec, indent=1))
    return rec


# ---------------------------------------------------------------------------
# Depth probes: XLA counts a scan body once regardless of trip count, so the
# raw cost_analysis underestimates layer-stack costs.  Compiling depth-1 and
# depth-2 variants gives exact per-layer deltas; benchmarks/roofline.py
# extrapolates  total = base + Σ n_seg · Δ_seg  (see EXPERIMENTS.md §Roofline
# methodology).
# ---------------------------------------------------------------------------
def probe_plan(arch: str) -> list[tuple[str, dict]]:
    cfg = get_config(arch)
    fam = cfg.family
    ft = cfg.ttd.first_tt_block
    if fam == "encdec":
        return [("e1d1", {"n_enc_layers": 1, "n_layers": 1}),
                ("e2d1", {"n_enc_layers": 2, "n_layers": 1}),
                ("e1d2", {"n_enc_layers": 1, "n_layers": 2})]
    if fam == "griffin":
        return [("g1", {"n_layers": 3}), ("g2", {"n_layers": 6}),
                ("g1r1", {"n_layers": 4})]
    if ft > 0:  # two-segment transformers (paper's partial-TT recipe)
        return [("d1", {"n_layers": 1, "_ft": 1}), ("d2", {"n_layers": 2, "_ft": 2}),
                ("t1", {"n_layers": 1, "_ft": 0}), ("t2", {"n_layers": 2, "_ft": 0})]
    return [("L1", {"n_layers": 1}), ("L2", {"n_layers": 2})]


def probe_cell(arch: str, cell_name: str, out_dir: Path) -> dict:
    cell = shape_cell(cell_name)
    if cell_skip_reason(arch, cell):
        return {}
    mesh = make_production_mesh(multi_pod=False)
    rec = {"arch": arch, "cell": cell_name, "probes": {}}
    for tag, mods in probe_plan(arch):
        mods = dict(mods)
        ft = mods.pop("_ft", None)
        base_cfg = arch_cell_config(arch, cell)
        cfg = base_cfg.replace(**mods)
        if ft is not None:
            cfg = cfg.replace(ttd=base_cfg.ttd.__class__(
                **{**base_cfg.ttd.__dict__, "first_tt_block": ft}))
        model = build_model(cfg)
        batch = input_specs(cfg, cell)
        # lower exactly like lower_cell but with the mutated cfg
        lowered, compiled = _lower_with_cfg(cfg, model, cell, mesh, arch)
        a = analyze(lowered, compiled, mesh)
        rec["probes"][tag] = {"flops": a["flops"], "bytes": a["bytes_accessed"],
                              "coll": a["collectives"]["total"],
                              "coll_by": {k: a["collectives"][k] for k in _COLL_OPS}}
        print(f"[probe] {arch} × {cell_name} × {tag}: flops={a['flops']:.3e}")
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{arch}_{cell_name}_16x16_probes.json").write_text(json.dumps(rec, indent=1))
    return rec


def _lower_with_cfg(cfg, model, cell, mesh, arch):
    """Shared lowering used by probes (mirrors lower_cell's three kinds)."""
    batch = input_specs(cfg, cell)
    with jax.set_mesh(mesh):
        if cell.kind == "train":
            opt = "adafactor" if arch in BIG_TRAIN else "adamw"
            mb = 4 if arch in BIG_TRAIN else 1
            tc = TrainConfig(global_batch=cell.global_batch, seq_len=cell.seq_len,
                             optimizer=opt, remat="full", microbatches=mb)
            step = build_train_step(model, tc)
            specs = state_pspecs(model, tc, mesh)
            state_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                                    is_leaf=lambda x: isinstance(x, P))
            bspec = jax.tree.map(
                lambda x: NamedSharding(mesh, batch_pspec(mesh, len(x.shape) - 1)), batch)
            if "positions" in batch:
                bspec["positions"] = NamedSharding(mesh, P(None, "data", None))

            def _make_state(key):
                params = model.init(key)
                return TrainState(params=params, opt=init_optimizer(tc.optimizer, params),
                                  step=jnp.zeros((), jnp.int32))

            state_shapes = jax.eval_shape(_make_state, jax.random.PRNGKey(0))
            jitted = jax.jit(step, in_shardings=(state_sh, bspec),
                             out_shardings=(state_sh, None))
            lowered = jitted.lower(state_shapes, batch)
        elif cell.kind == "prefill":
            pshapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            psh = param_shardings(pshapes, mesh, fsdp=False)
            bspec = jax.tree.map(
                lambda x: NamedSharding(mesh, batch_pspec(mesh, len(x.shape) - 1)), batch)
            if "positions" in batch:
                bspec["positions"] = NamedSharding(mesh, P(None, "data", None))
            jitted = jax.jit(lambda p, b: model.prefill(p, b, max_len=cell.seq_len),
                             in_shardings=(psh, bspec))
            lowered = jitted.lower(pshapes, batch)
        else:
            pshapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            psh = param_shardings(pshapes, mesh, fsdp=False)
            cshapes = jax.eval_shape(
                lambda: model.init_cache(cell.global_batch, cell.seq_len, jnp.bfloat16))
            csh = cache_shardings(cshapes, mesh)
            bax = "data" if cell.global_batch % mesh.shape["data"] == 0 else None
            bspec = {"tokens": NamedSharding(mesh, P(bax, None))}
            if "positions" in batch:
                bspec["positions"] = NamedSharding(mesh, P(None, bax, None))
            jitted = jax.jit(lambda p, c, b, pos: model.decode_step(p, c, b, pos),
                             in_shardings=(psh, csh, bspec, None))
            lowered = jitted.lower(pshapes, cshapes, batch,
                                   jax.ShapeDtypeStruct((), jnp.int32))
    return lowered, lowered.compile()


def main(argv=None):
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None, help="arch id (default: all assigned)")
    ap.add_argument("--shape", default=None, help="shape cell (default: all four)")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--baseline", action="store_true",
                    help="lower the non-TTD baseline instead of the paper config")
    ap.add_argument("--probe", action="store_true",
                    help="run depth-delta probes (single-pod) instead of full cells")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else list(ALL_ARCHS)
    cells = [args.shape] if args.shape else ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]
    out_dir = Path(args.out)
    failures = []
    for arch in archs:
        for cell in cells:
            if args.probe:
                try:
                    probe_cell(arch, cell, out_dir)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, cell, "probe", repr(e)))
                    print(f"[dryrun] FAIL probe {arch} × {cell}: {e!r}")
                continue
            for mp in meshes:
                try:
                    run_cell(arch, cell, mp, out_dir, baseline=args.baseline)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, cell, mp, repr(e)))
                    print(f"[dryrun] FAIL {arch} × {cell} × mp={mp}: {e!r}")
    if failures:
        print(f"[dryrun] {len(failures)} failures")
        sys.exit(1)
    print("[dryrun] all requested cells compiled")


if __name__ == "__main__":
    main()

"""ShapeDtypeStruct input stand-ins for every (arch × shape-cell).

Nothing here allocates device memory: these feed ``jax.jit(...).lower()``.
The modality frontends (audio frames / vision patches) are stubs per the
assignment: whisper receives precomputed (B, 1500, D) frame embeddings and
``seq_len`` means the *decoder* length; qwen2-vl receives token ids plus 3D
M-RoPE position ids.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..config import ModelConfig, ShapeCell

SDS = jax.ShapeDtypeStruct


def train_input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    b, s = cell.global_batch, cell.seq_len
    batch = {
        "tokens": SDS((b, s), jnp.int32),
        "targets": SDS((b, s), jnp.int32),
        "loss_mask": SDS((b, s), jnp.float32),
    }
    if cfg.pos_type == "mrope":
        batch["positions"] = SDS((3, b, s), jnp.int32)
    if cfg.family == "encdec":
        batch["enc_frames"] = SDS((b, cfg.enc_len, cfg.d_model), jnp.bfloat16)
    return batch


def prefill_input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    b, s = cell.global_batch, cell.seq_len
    batch = {"tokens": SDS((b, s), jnp.int32)}
    if cfg.pos_type == "mrope":
        batch["positions"] = SDS((3, b, s), jnp.int32)
    if cfg.family == "encdec":
        batch["enc_frames"] = SDS((b, cfg.enc_len, cfg.d_model), jnp.bfloat16)
    return batch


def decode_input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    b = cell.global_batch
    batch = {"tokens": SDS((b, 1), jnp.int32)}
    if cfg.pos_type == "mrope":
        batch["positions"] = SDS((3, b, 1), jnp.int32)
    return batch


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    if cell.kind == "train":
        return train_input_specs(cfg, cell)
    if cell.kind == "prefill":
        return prefill_input_specs(cfg, cell)
    return decode_input_specs(cfg, cell)

"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  Dry-run processes must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before the first
jax import (launch/dryrun.py does this in its first two lines).
"""
from __future__ import annotations

import jax

from ..config import MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(cfg: MeshConfig):
    return jax.make_mesh(cfg.shape, cfg.axis_names,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(cfg.axis_names))


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (possibly fake) local devices exist."""
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto, jax.sharding.AxisType.Auto))

"""Distributed training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --reduced --steps 100 --data 1 --model 1

On a real cluster each host runs this with jax.distributed initialized by the
scheduler; the mesh spans all pods ((pod, data, model) axes). In this
container it runs on however many (real or DRYRUN_XLA_FLAGS-faked) devices
exist. Composes: config registry -> sharded TrainState -> jitted train_step
-> fault-tolerant Trainer (async checkpoints, watchdog, resume).
"""
from __future__ import annotations

import argparse
import logging

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.config import MeshConfig, TrainConfig
from repro.configs import ALL_ARCHS, get_config
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.train.step import (batch_pspec, build_train_step, init_train_state,
                              state_pspecs)
from repro.train.trainer import Trainer

logging.basicConfig(level=logging.INFO, format="%(message)s")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=list(ALL_ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "adafactor"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="dots", choices=["none", "dots", "full"])
    ap.add_argument("--data", type=int, default=1, help="data-parallel mesh dim")
    ap.add_argument("--model", type=int, default=1, help="model-parallel mesh dim")
    ap.add_argument("--pods", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    model = build_model(cfg)
    tc = TrainConfig(global_batch=args.global_batch, seq_len=args.seq_len,
                     lr=args.lr, optimizer=args.optimizer,
                     microbatches=args.microbatches, remat=args.remat,
                     total_steps=args.steps, warmup_steps=max(args.steps // 20, 5))
    mesh_cfg = MeshConfig(data=args.data, model=args.model, pods=args.pods)
    use_mesh = mesh_cfg.n_devices > 1
    mesh = make_mesh(mesh_cfg) if use_mesh else None

    state = init_train_state(model, tc, jax.random.PRNGKey(tc.seed), mesh=mesh)
    step = build_train_step(model, tc)
    shardings = None
    if mesh is not None:
        specs = state_pspecs(model, tc, mesh)
        shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                                 is_leaf=lambda x: isinstance(x, P))
        bspec = NamedSharding(mesh, batch_pspec(mesh, 1))
        step = jax.jit(step, in_shardings=(shardings, {
            "tokens": bspec, "targets": bspec,
            "loss_mask": NamedSharding(mesh, batch_pspec(mesh, 1))}),
            out_shardings=(shardings, None))
    else:
        step = jax.jit(step)

    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=tc.seq_len,
                      global_batch=tc.global_batch, seed=tc.seed)
    trainer = Trainer(step, state, data, ckpt_dir=args.ckpt_dir,
                      state_shardings=shardings)
    if args.resume and args.ckpt_dir:
        trainer._restore_latest()
    ctx = mesh or _nullcontext()
    with (jax.set_mesh(mesh) if mesh is not None else _nullcontext()):
        report = trainer.run(args.steps)
    print(f"done: loss {report.losses[0]:.4f} -> {report.losses[-1]:.4f} "
          f"({report.steps_done} steps, {report.restarts} restarts)")


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()

"""Serving launcher: the unified session engine over a registry model.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --reduced --requests 8 --max-tokens 12

Any family serves: the engine picks the architecture's default state
backend (paged block pools, per-slot rings for SWA, recurrent state, or
encoder-context + paged self-attention for enc-dec) — override with
``--backend``.  Production deployment would load a TT+int4 compressed
checkpoint (repro.core.compress) and shard params/state over a
(data, model) mesh via repro.serve.steps; this CLI demonstrates the full
request path.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import ALL_ARCHS, get_config
from repro.models import build_model
from repro.serve.engine import Engine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=list(ALL_ARCHS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--backend", default=None,
                    help="state backend (default: family's preferred)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-tokens", type=int, default=12)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced).replace(
        compute_dtype="float32", param_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params, slots=args.slots, max_len=args.max_len,
                    backend=args.backend, prefill_chunk=args.prefill_chunk)
    print(f"{cfg.name}: serving through the {engine.session.backend!r} backend")
    for i in range(args.requests):
        engine.submit([1 + i, 2, 3] + list(range(4, 4 + i % 5)),
                      max_tokens=args.max_tokens)
    t0 = time.perf_counter()
    done = engine.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests / {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()

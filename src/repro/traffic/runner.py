"""Open-loop traffic player: replay a workload against the async front-end.

:func:`play` drives an :class:`~repro.serve.frontend.AsyncEngine` with a
:func:`~repro.traffic.workload.make_workload` schedule, open-loop: requests
are submitted at their scheduled arrival times whether or not the engine has
kept up (the realistic serving regime — a slow engine builds a queue, it
does not slow the clients down).  Each submission gets a consumer coroutine
draining its token stream and, when the schedule says the client abandons,
a cancel timer racing the request's completion.  Everything shares one event
loop with the engine pump, so consumer wakeups interleave with device
dispatch exactly as they would in a real server.

``time_scale`` stretches the *entire* schedule uniformly — arrivals,
deadlines, TTFT SLOs, and cancel points — so one workload spec is meaningful
on both a CPU-interpret CI runner (``time_scale=4``) and a fast backend
(``time_scale=1``): the shape of the contention is preserved, only the clock
changes.

Latency accounting deliberately reuses the **engine's** monotonic stamps
(``Request.t_submit`` / ``t_first`` / ``t_done``) via
:func:`~repro.traffic.report.outcome_of` rather than timing in the consumer
coroutines — the obs registry is the single source of truth for percentiles
and the outcomes must agree with it.
"""
from __future__ import annotations

import asyncio
import time
from dataclasses import dataclass

from .report import RequestOutcome, outcome_of
from .workload import TrafficRequest


@dataclass
class TrafficResult:
    """One scenario replay: per-request outcomes + the wall clock."""

    outcomes: list[RequestOutcome]
    wall_s: float
    time_scale: float


async def play(frontend, requests: list[TrafficRequest], *,
               time_scale: float = 1.0) -> TrafficResult:
    """Replay ``requests`` (sorted by arrival) against ``frontend``.

    Returns when every request finished, cancelled, or expired; a pump
    failure propagates.  ``frontend`` is any object with the
    :class:`~repro.serve.frontend.AsyncEngine` submit/drain surface.
    """
    if time_scale <= 0:
        raise ValueError("time_scale must be > 0")
    handles = []
    aux: list[asyncio.Task] = []

    async def consume(handle):
        async for _ in handle.stream():
            pass

    async def cancel_later(handle, delay: float):
        # race the client's patience against the request finishing first
        try:
            await asyncio.wait_for(handle.wait_done(), timeout=delay)
        except asyncio.TimeoutError:
            handle.cancel()

    t0 = time.perf_counter()
    for treq in requests:
        delay = treq.t_arrival * time_scale - (time.perf_counter() - t0)
        if delay > 0:
            await asyncio.sleep(delay)
        handle = frontend.submit(
            treq.prompt, max_tokens=treq.max_tokens,
            deadline_s=(None if treq.deadline_s is None
                        else treq.deadline_s * time_scale))
        handles.append(handle)
        aux.append(asyncio.create_task(consume(handle)))
        if treq.cancel_after_s is not None:
            aux.append(asyncio.create_task(
                cancel_later(handle, treq.cancel_after_s * time_scale)))
    await frontend.drain()
    await asyncio.gather(*aux)
    wall = time.perf_counter() - t0
    outcomes = [
        outcome_of(h.req, idx=treq.idx,
                   ttft_slo_s=(None if treq.ttft_slo_s is None
                               else treq.ttft_slo_s * time_scale))
        for treq, h in zip(requests, handles)]
    return TrafficResult(outcomes=outcomes, wall_s=wall,
                         time_scale=time_scale)


def drive(frontend, requests: list[TrafficRequest], *,
          time_scale: float = 1.0) -> TrafficResult:
    """Synchronous wrapper: run :func:`play` on a fresh event loop."""
    return asyncio.run(play(frontend, requests, time_scale=time_scale))

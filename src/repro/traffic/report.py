"""Traffic accounting: outcomes, goodput, and the shared summary schema.

Pure bookkeeping — no asyncio, no jax.  The runner (and the synchronous
``benchmarks/decode_speed.py --serve`` path) both report through these
helpers so ``BENCH_traffic.json`` and ``BENCH_serve.json`` carry **one**
summary shape:

* :func:`pct_row` — ``{count, mean, p50, p95, p99}`` from an obs histogram
  (``None``-safe: an absent/empty histogram yields null fields, not a crash).
* :func:`registry_summary` — the serving metrics every bench row embeds
  (TTFT / inter-token / queue-time percentiles plus token, tick, preemption,
  cancellation, and deadline-miss totals), pulled from the engine's
  :class:`~repro.obs.registry.MetricsRegistry` — the obs layer is the single
  source of truth for latency percentiles.
* :class:`RequestOutcome` / :func:`outcome_of` — per-request accounting from
  the engine's monotonic stamps; ``slo_attained`` means *completed with the
  first token inside its TTFT SLO*.
* :func:`goodput_tok_per_s` — SLO-attained tokens per wall second: tokens
  from requests that missed their SLO (or were cancelled / deadline-expired)
  spent compute but delivered no client value, so they count in ``tok_per_s``
  but not in goodput.
"""
from __future__ import annotations

from dataclasses import dataclass

PCT_FIELDS = ("count", "mean", "p50", "p95", "p99")


def pct_row(h) -> dict:
    """``{count, mean, p50, p95, p99}`` from an obs histogram (None-safe)."""
    if h is None or h.count == 0:
        return {"count": 0, "mean": None, "p50": None, "p95": None, "p99": None}
    return {"count": h.count, "mean": h.mean(), "p50": h.percentile(0.50),
            "p95": h.percentile(0.95), "p99": h.percentile(0.99)}


def registry_summary(reg) -> dict:
    """The shared serving-metrics block for BENCH rows.

    ``reg`` is a :class:`~repro.obs.registry.MetricsRegistry`; metrics the
    run never touched report zero / null rather than raising.
    """
    def total(name: str) -> int:
        c = reg.get(name)
        return int(c.value) if c is not None else 0

    return {
        "ttft_s": pct_row(reg.get("serve_ttft_seconds")),
        "inter_token_s": pct_row(reg.get("serve_inter_token_seconds")),
        "queue_s": pct_row(reg.get("serve_queue_seconds")),
        "tokens": total("serve_tokens_total"),
        "decode_ticks": total("serve_decode_ticks_total"),
        "preempts": total("serve_preemptions_total"),
        "cancels": total("serve_cancellations_total"),
        "deadline_misses": total("serve_deadline_miss_total"),
    }


@dataclass
class RequestOutcome:
    """Per-request accounting derived from the engine's monotonic stamps."""

    idx: int
    rid: int
    n_tokens: int
    finish_reason: str        # eos | max_tokens | max_len | user | deadline
    completed: bool           # finished normally (not cancelled/expired)
    ttft_s: float | None      # first-token latency (None: never got one)
    latency_s: float | None   # submit -> done
    slo_attained: bool        # completed and TTFT within its SLO


def outcome_of(req, *, ttft_slo_s: float | None = None,
               idx: int = -1) -> RequestOutcome:
    """Account one finished engine :class:`~repro.serve.engine.Request`.

    ``ttft_slo_s`` (already time-scaled by the caller when the schedule was)
    gates ``slo_attained``; ``None`` means every completed request attains.
    """
    completed = bool(req.done and not req.cancelled)
    ttft = (req.t_first - req.t_submit) if req.t_first else None
    latency = (req.t_done - req.t_submit) if req.t_done else None
    attained = completed and (ttft_slo_s is None
                              or (ttft is not None and ttft <= ttft_slo_s))
    return RequestOutcome(idx=idx, rid=req.rid, n_tokens=len(req.out_tokens),
                          finish_reason=req.finish_reason, completed=completed,
                          ttft_s=ttft, latency_s=latency, slo_attained=attained)


def goodput_tok_per_s(outcomes, wall_s: float) -> float:
    """SLO-attained tokens per wall-clock second (0 when nothing attained)."""
    if wall_s <= 0:
        raise ValueError("wall_s must be > 0")
    return sum(o.n_tokens for o in outcomes if o.slo_attained) / wall_s


def traffic_row(*, result, registry, **labels) -> dict:
    """One BENCH_traffic.json row: labels + outcome counts + shared summary.

    ``result`` is a :class:`~repro.traffic.runner.TrafficResult`; ``labels``
    (family/arch/scenario/…) pass through verbatim.
    """
    outs = result.outcomes
    toks = sum(o.n_tokens for o in outs)
    return {
        **labels,
        "n_requests": len(outs),
        "n_completed": sum(o.completed for o in outs),
        "n_cancelled": sum(o.finish_reason == "user" for o in outs),
        "n_deadline_missed": sum(o.finish_reason == "deadline" for o in outs),
        "n_slo_attained": sum(o.slo_attained for o in outs),
        "wall_s": result.wall_s,
        "time_scale": result.time_scale,
        "tok_per_s": toks / result.wall_s if result.wall_s > 0 else 0.0,
        "goodput_tok_per_s": goodput_tok_per_s(outs, result.wall_s),
        **registry_summary(registry),
    }


def check_traffic_schema(rec: dict) -> None:
    """Assert a BENCH_traffic.json record has the acceptance shape.

    Thin wrapper over the shared BENCH schema table
    (``repro.analyze.bench``) so the traffic report is validated by the
    same code as ``python -m repro.analyze --bench``; kept here for the
    public ``repro.traffic`` API surface.
    """
    from repro.analyze.bench import check_report

    errors = check_report("traffic", rec)
    assert not errors, "; ".join(errors)

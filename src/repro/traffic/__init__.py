"""repro.traffic — reproducible serving traffic: workloads, replay, reports.

Three layers (DESIGN.md §12):

* :mod:`~repro.traffic.workload` — declarative :class:`WorkloadSpec`
  (seeded Poisson/bursty arrivals, bucket-mixture lengths, SLOs, deadlines,
  client cancellations) expanded deterministically by :func:`make_workload`.
* :mod:`~repro.traffic.runner` — :func:`play`/:func:`drive` replay a
  schedule open-loop against the asyncio front-end
  (:class:`~repro.serve.frontend.AsyncEngine`), with ``time_scale``
  stretching the whole clock for slow CI backends.
* :mod:`~repro.traffic.report` — shared summary schema: obs-registry
  percentile rows, per-request outcomes, goodput (SLO-attained tok/s), and
  the ``BENCH_traffic.json`` schema checker.  ``benchmarks/decode_speed.py
  --serve`` reports through the same helpers so the BENCH files agree.
"""
from .report import (  # noqa: F401
    RequestOutcome,
    check_traffic_schema,
    goodput_tok_per_s,
    outcome_of,
    pct_row,
    registry_summary,
    traffic_row,
)
from .runner import TrafficResult, drive, play  # noqa: F401
from .workload import TrafficRequest, WorkloadSpec, make_workload  # noqa: F401

"""Reproducible serving workloads: seeded arrivals, lengths, SLOs, cancels.

A :class:`WorkloadSpec` describes a traffic pattern declaratively and
:func:`make_workload` expands it into a concrete, fully deterministic list of
:class:`TrafficRequest` — every arrival time, prompt token, output budget,
and cancellation point is drawn from one ``numpy`` generator seeded by
``spec.seed``, so a scenario re-runs bit-identically across machines and the
fuzz suite can shrink failures by seed.

Arrival processes:

* ``poisson`` — independent exponential inter-arrival gaps at ``rate_rps``
  requests/second (the classic open-loop serving assumption).
* ``bursty`` — arrivals come in bursts of ``burst_size`` *simultaneous*
  requests; the gaps between bursts are exponential at
  ``rate_rps / burst_size`` bursts/second, so the long-run request rate
  still equals ``rate_rps`` while the instantaneous load spikes (the
  admission/preemption stress case).

Lengths are drawn from small bucket mixtures (``prompt_len_buckets`` /
``out_tokens_buckets`` with matching weights) rather than continuous
distributions: buckets keep the jitted shapes repeatable while still mixing
short/long requests in one schedule.  Per-request service levels ride along:
``ttft_slo_s`` marks a request SLO-attained only when its first token
arrived in time (goodput accounting, ``repro.traffic.report``),
``deadline_s`` is handed to ``Engine.submit`` and *enforced* by the
scheduler, and ``cancel_prob`` picks requests that a client will abandon
mid-stream after a uniform draw from ``cancel_window_s`` seconds.

All times here are *unscaled* seconds; the runner's ``time_scale`` stretches
arrivals, deadlines, SLOs, and cancel points uniformly so one spec serves
both CPU-interpret CI and faster backends.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import numpy as np


@dataclass(frozen=True)
class WorkloadSpec:
    """Declarative traffic pattern; expand with :func:`make_workload`."""

    n_requests: int = 32
    arrival: str = "poisson"               # poisson | bursty
    rate_rps: float = 8.0                  # long-run request arrival rate
    burst_size: int = 4                    # requests per burst (bursty only)
    prompt_len_buckets: Sequence[int] = (8, 24, 48)
    prompt_len_weights: Sequence[float] = (0.5, 0.35, 0.15)
    out_tokens_buckets: Sequence[int] = (4, 16, 32)
    out_tokens_weights: Sequence[float] = (0.55, 0.3, 0.15)
    vocab: int = 256                       # prompt tokens drawn from [1, vocab)
    ttft_slo_s: float | None = None        # first-token SLO (goodput gate)
    deadline_s: float | None = None        # engine-enforced completion budget
    cancel_prob: float = 0.0               # P(client abandons mid-stream)
    cancel_window_s: tuple[float, float] = (0.05, 0.5)
    seed: int = 0

    def validate(self) -> None:
        if self.n_requests < 1:
            raise ValueError("n_requests must be >= 1")
        if self.arrival not in ("poisson", "bursty"):
            raise ValueError(f"arrival must be 'poisson' or 'bursty', "
                             f"got {self.arrival!r}")
        if self.rate_rps <= 0:
            raise ValueError("rate_rps must be > 0")
        if self.arrival == "bursty" and self.burst_size < 1:
            raise ValueError("burst_size must be >= 1")
        for name, buckets, weights in (
                ("prompt_len", self.prompt_len_buckets, self.prompt_len_weights),
                ("out_tokens", self.out_tokens_buckets, self.out_tokens_weights)):
            if not buckets or len(buckets) != len(weights):
                raise ValueError(f"{name}_buckets and {name}_weights must be "
                                 "non-empty and the same length")
            if any(b < 1 for b in buckets):
                raise ValueError(f"{name}_buckets must be positive")
            if any(w < 0 for w in weights) or sum(weights) <= 0:
                raise ValueError(f"{name}_weights must be non-negative and "
                                 "sum > 0")
        if self.vocab < 2:
            raise ValueError("vocab must be >= 2")
        if not 0.0 <= self.cancel_prob <= 1.0:
            raise ValueError("cancel_prob must be in [0, 1]")
        lo, hi = self.cancel_window_s
        if lo < 0 or hi < lo:
            raise ValueError("cancel_window_s must be 0 <= lo <= hi")
        if self.ttft_slo_s is not None and self.ttft_slo_s <= 0:
            raise ValueError("ttft_slo_s must be > 0 (or None)")
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError("deadline_s must be > 0 (or None)")

    def to_dict(self) -> dict:
        """JSON-ready spec record (embedded in BENCH rows for provenance)."""
        return {
            "n_requests": self.n_requests, "arrival": self.arrival,
            "rate_rps": self.rate_rps, "burst_size": self.burst_size,
            "prompt_len_buckets": list(self.prompt_len_buckets),
            "prompt_len_weights": list(self.prompt_len_weights),
            "out_tokens_buckets": list(self.out_tokens_buckets),
            "out_tokens_weights": list(self.out_tokens_weights),
            "vocab": self.vocab, "ttft_slo_s": self.ttft_slo_s,
            "deadline_s": self.deadline_s, "cancel_prob": self.cancel_prob,
            "cancel_window_s": list(self.cancel_window_s), "seed": self.seed,
        }


@dataclass
class TrafficRequest:
    """One concrete arrival: everything the runner needs to play it."""

    idx: int                          # position in the schedule
    t_arrival: float                  # seconds from scenario start (unscaled)
    prompt: list[int] = field(repr=False, default_factory=list)
    max_tokens: int = 16
    ttft_slo_s: float | None = None
    deadline_s: float | None = None
    cancel_after_s: float | None = None  # client abandons this long after submit


def _arrival_times(spec: WorkloadSpec, rng: np.random.Generator) -> np.ndarray:
    n = spec.n_requests
    if spec.arrival == "poisson":
        gaps = rng.exponential(1.0 / spec.rate_rps, size=n)
        return np.cumsum(gaps)
    # bursty: bursts of burst_size simultaneous arrivals, exponential gaps
    # between bursts at rate_rps / burst_size so the long-run rate matches
    n_bursts = -(-n // spec.burst_size)
    gaps = rng.exponential(spec.burst_size / spec.rate_rps, size=n_bursts)
    burst_t = np.cumsum(gaps)
    return np.repeat(burst_t, spec.burst_size)[:n]


def _bucket_draws(buckets, weights, n: int, rng: np.random.Generator):
    p = np.asarray(weights, np.float64)
    p = p / p.sum()
    return rng.choice(np.asarray(buckets, np.int64), size=n, p=p)


def make_workload(spec: WorkloadSpec) -> list[TrafficRequest]:
    """Expand ``spec`` into its deterministic request schedule.

    Same spec (same seed) → bit-identical schedule: arrivals, prompt tokens,
    output budgets, and cancellation points all come from one seeded
    generator, drawn in a fixed order.
    """
    spec.validate()
    rng = np.random.default_rng(spec.seed)
    arrivals = _arrival_times(spec, rng)
    plens = _bucket_draws(spec.prompt_len_buckets, spec.prompt_len_weights,
                          spec.n_requests, rng)
    outs = _bucket_draws(spec.out_tokens_buckets, spec.out_tokens_weights,
                         spec.n_requests, rng)
    cancel_u = rng.random(spec.n_requests)
    lo, hi = spec.cancel_window_s
    cancel_at = rng.uniform(lo, hi, size=spec.n_requests)
    reqs = []
    for i in range(spec.n_requests):
        prompt = [int(t) for t in rng.integers(1, spec.vocab, int(plens[i]))]
        cancels = spec.cancel_prob > 0 and cancel_u[i] < spec.cancel_prob
        reqs.append(TrafficRequest(
            idx=i, t_arrival=float(arrivals[i]), prompt=prompt,
            max_tokens=int(outs[i]), ttft_slo_s=spec.ttft_slo_s,
            deadline_s=spec.deadline_s,
            cancel_after_s=float(cancel_at[i]) if cancels else None))
    return reqs

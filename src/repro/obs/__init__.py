"""repro.obs — observability for the serving/training stack (DESIGN.md §9).

One :class:`Observer` bundles the three layers:

* a :class:`~repro.obs.registry.MetricsRegistry` (counters / gauges /
  mergeable fixed-bucket histograms with exact-to-one-bucket percentiles),
* a :class:`~repro.obs.trace.Trace` of structured scheduler events
  (monotonic timestamps, optionally streamed to JSONL),
* optional ``jax.profiler`` trace annotations around dispatch regions.

**Overhead contract:** everything is off by default.  Components take an
``obs=None`` argument: ``None`` resolves to the process-default observer
built from the environment (``REPRO_OBS`` unset → *no* observer — the
disabled hot path is a single ``is None`` check, no allocation, no device
syncs), ``False`` forces off, and an :class:`Observer` / enabled
:class:`ObsConfig` turns instrumentation on explicitly.  Enabling obs adds
host-side bookkeeping only; it never inserts a device sync the engine was
not already doing (TTFT was always stamped after ``block_until_ready``).

Env knobs (read once, at first ``default_observer()`` call):

====================================  =======================================
``REPRO_OBS=1``                       enable the process-default observer
``REPRO_OBS_JSONL=<path>``            stream trace events to ``<path>``
``REPRO_OBS_PROFILER=1``              ``jax.profiler`` annotations on
                                      prefill/decode dispatch
``REPRO_OBS_KERNEL_TIMING=1``         per-(role, backend) kernel wall-time
                                      histograms in ``kernels.dispatch``
                                      (fences with ``block_until_ready``;
                                      eager calls only — never inside jit)
``REPRO_OBS_POOL_EVERY=<n>``          sample pool gauges every n ticks (1)
====================================  =======================================
"""
from __future__ import annotations

import os
from dataclasses import dataclass

from .export import (  # noqa: F401  (public re-exports)
    JsonlWriter,
    bench_summary,
    prometheus_text,
    read_jsonl,
    validate_events,
    validate_jsonl,
)
from .registry import (  # noqa: F401
    DEFAULT_LATENCY_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    exp_buckets,
)
from .trace import Trace, annotate, maybe_annotate  # noqa: F401

ENV_ENABLE = "REPRO_OBS"
ENV_JSONL = "REPRO_OBS_JSONL"
ENV_PROFILER = "REPRO_OBS_PROFILER"
ENV_KERNEL_TIMING = "REPRO_OBS_KERNEL_TIMING"
ENV_POOL_EVERY = "REPRO_OBS_POOL_EVERY"


def _truthy(v: str | None) -> bool:
    return (v or "").strip().lower() not in ("", "0", "false", "no", "off")


@dataclass(frozen=True)
class ObsConfig:
    """What to record.  ``enabled=False`` means "no observer at all"."""

    enabled: bool = True
    jsonl_path: str | None = None      # stream trace events here
    profiler_annotations: bool = False  # jax.profiler spans on dispatch
    kernel_timing: bool = False         # fenced per-kernel wall histograms
    pool_sample_every: int = 1          # ticks between pool gauge samples

    @classmethod
    def from_env(cls) -> "ObsConfig":
        return cls(
            enabled=_truthy(os.environ.get(ENV_ENABLE)),
            jsonl_path=os.environ.get(ENV_JSONL) or None,
            profiler_annotations=_truthy(os.environ.get(ENV_PROFILER)),
            kernel_timing=_truthy(os.environ.get(ENV_KERNEL_TIMING)),
            pool_sample_every=max(1, int(os.environ.get(ENV_POOL_EVERY, "1"))),
        )


class Observer:
    """Live instrumentation handle: registry + trace (+ profiler spans)."""

    def __init__(self, config: ObsConfig | None = None, *,
                 registry: MetricsRegistry | None = None):
        self.config = config if config is not None else ObsConfig()
        self.registry = registry if registry is not None else MetricsRegistry()
        writer = (JsonlWriter(self.config.jsonl_path)
                  if self.config.jsonl_path else None)
        self.trace = Trace(writer=writer)

    def event(self, ev: str, t: float | None = None, **fields) -> dict:
        return self.trace.emit(ev, t=t, **fields)

    def annotate(self, name: str):
        """Profiler span when ``profiler_annotations`` is on, else no-op."""
        return maybe_annotate(name, self.config.profiler_annotations)

    def close(self) -> None:
        self.trace.close()


_DEFAULT: list = []  # memo cell: [] = unresolved, [None | Observer] = resolved


def default_observer() -> Observer | None:
    """Process-default observer from the environment, memoized.

    ``None`` unless ``REPRO_OBS`` is truthy — the disabled path must cost
    one ``is None`` check at the call sites.
    """
    if not _DEFAULT:
        cfg = ObsConfig.from_env()
        _DEFAULT.append(Observer(cfg) if cfg.enabled else None)
    return _DEFAULT[0]


def reset_default_observer() -> None:
    """Drop the memoized default (tests re-read the environment)."""
    if _DEFAULT and _DEFAULT[0] is not None:
        _DEFAULT[0].close()
    _DEFAULT.clear()


def resolve_observer(obs) -> Observer | None:
    """Normalize a component's ``obs`` argument.

    ``None`` → the env-driven process default; ``False`` → force-off;
    an :class:`Observer` passes through; an :class:`ObsConfig` builds a
    fresh observer (or ``None`` when ``enabled=False``).
    """
    if obs is None:
        return default_observer()
    if obs is False:
        return None
    if isinstance(obs, Observer):
        return obs
    if isinstance(obs, ObsConfig):
        return Observer(obs) if obs.enabled else None
    raise TypeError(f"obs must be None, False, ObsConfig or Observer; "
                    f"got {type(obs).__name__}")

"""Structured scheduler event trace + optional ``jax.profiler`` annotations.

The serving engine narrates its scheduling decisions as a flat stream of
dict events — one per admit / prefill chunk / decode tick / preemption /
cancel / deadline miss / finish / pool sample — each stamped with a
**monotonic** timestamp
(``time.perf_counter``; wall-clock never enters duration math, DESIGN.md §9)
and a process-wide sequence number.  The stream is the ground truth the
ordering-invariant tests replay (submit ≤ admit ≤ first token ≤ finish;
every preempt is followed by a re-admission), and ``repro.obs.export``
validates and persists it as JSONL.

``annotate`` wraps a region in a ``jax.profiler.TraceAnnotation`` so the
engine's prefill/decode dispatches show up as named spans in a TensorBoard
/ Perfetto profile; it is import-light and a no-op-cost ``nullcontext``
when disabled.
"""
from __future__ import annotations

import contextlib
import itertools
import time

# Event types and their required per-type fields (beyond the common
# ``ev`` / ``t`` / ``seq``).  ``repro.obs.export.EVENT_SCHEMA`` builds the
# full field-type map from this table.
EVENT_FIELDS: dict[str, tuple[str, ...]] = {
    "submit": ("rid", "prompt_len", "max_tokens"),
    "admit": ("rid", "slot", "tick", "n_tokens"),
    "prefill_chunk": ("tick", "chunk", "n_chunks", "rids"),
    "first_token": ("rid", "tick", "ttft_s"),
    "decode_tick": ("tick", "active"),
    "preempt": ("rid", "slot", "tick"),
    "cancel": ("rid", "slot", "tick", "reason"),
    "deadline_miss": ("rid", "tick", "deadline_s"),
    "finish": ("rid", "tick", "reason", "n_out"),
    "pool_sample": ("tick", "utilization", "free_blocks", "live_tokens",
                    "active_slots"),
}

_seq = itertools.count()


class Trace:
    """Append-only event log with monotonic timestamps.

    ``writer`` (anything with a ``write(dict)`` method — see
    ``export.JsonlWriter``) receives every event as it is emitted; ``keep``
    retains events in memory for in-process inspection (the default — the
    fuzz replays read ``trace.events`` directly).
    """

    def __init__(self, writer=None, keep: bool = True):
        self.events: list[dict] = []
        self._writer = writer
        self._keep = keep

    def emit(self, ev: str, t: float | None = None, **fields) -> dict:
        """Record one event; ``t`` defaults to ``perf_counter()`` now but may
        be passed in so an event reuses a timestamp already taken (e.g. the
        post-``block_until_ready`` TTFT stamp)."""
        rec = {"ev": ev, "t": time.perf_counter() if t is None else t,
               "seq": next(_seq), **fields}
        if self._keep:
            self.events.append(rec)
        if self._writer is not None:
            self._writer.write(rec)
        return rec

    def by_type(self, ev: str) -> list[dict]:
        return [e for e in self.events if e["ev"] == ev]

    def close(self) -> None:
        if self._writer is not None and hasattr(self._writer, "close"):
            self._writer.close()


def annotate(name: str):
    """``jax.profiler.TraceAnnotation`` region named ``name``.

    Import is local so the pure-Python metrics path never pulls in jax.
    """
    import jax.profiler
    return jax.profiler.TraceAnnotation(name)


def maybe_annotate(name: str, enabled: bool):
    return annotate(name) if enabled else contextlib.nullcontext()

"""Exporters for the obs layer: JSONL event logs, Prometheus text, BENCH JSON.

Three consumers, three formats:

* :class:`JsonlWriter` — streams trace events to disk one JSON object per
  line (line-buffered, so the file is valid after a crash mid-run); the CI
  smoke matrix validates the result with :func:`validate_jsonl`, runnable
  standalone as ``python -m repro.obs.export --validate <path>``.
* :func:`prometheus_text` — the Prometheus text exposition format
  (``name{labels} value``, histogram ``_bucket``/``_sum``/``_count``
  series with cumulative ``le`` edges) from a
  :class:`~repro.obs.registry.MetricsRegistry` snapshot.
* :func:`bench_summary` — the compact JSON summary the ``BENCH_*.json``
  files embed: per-histogram count/mean/p50/p95/p99, counters and gauges
  verbatim.
"""
from __future__ import annotations

import atexit
import json
import math
from pathlib import Path

from .registry import Counter, Gauge, Histogram, MetricsRegistry
from .trace import EVENT_FIELDS

# ---------------------------------------------------------------------------
# JSONL event log
# ---------------------------------------------------------------------------


class JsonlWriter:
    """Append-only JSONL sink; opens lazily, one ``json.dumps`` per event.

    Line-buffered text IO: every event is flushed at its newline, so the
    log is complete even if the process dies without a clean close.
    """

    def __init__(self, path):
        self.path = Path(path)
        self._fh = None

    def write(self, rec: dict) -> None:
        if self._fh is None:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            self._fh = open(self.path, "a", buffering=1)  # noqa: SIM115  long-lived handle, closed in close()
            atexit.register(self.close)
        self._fh.write(json.dumps(rec, separators=(",", ":")) + "\n")

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


def read_jsonl(path) -> list[dict]:
    with open(path) as fh:
        return [json.loads(line) for line in fh if line.strip()]


# ---------------------------------------------------------------------------
# Event schema validation
# ---------------------------------------------------------------------------
_COMMON = {"ev": str, "t": (int, float), "seq": int}
_FIELD_TYPES = {
    "rid": int, "slot": int, "tick": int, "prompt_len": int,
    "max_tokens": int, "n_tokens": int, "chunk": int, "n_chunks": int,
    "rids": list, "ttft_s": (int, float), "active": int, "reason": str,
    "n_out": int, "utilization": (int, float), "free_blocks": int,
    "live_tokens": int, "active_slots": int, "deadline_s": (int, float),
}
EVENT_SCHEMA = {
    ev: {**_COMMON, **{f: _FIELD_TYPES[f] for f in fields}}
    for ev, fields in EVENT_FIELDS.items()
}


def validate_events(events) -> list[str]:
    """Schema errors for an iterable of event dicts ([] = valid).

    Checks: known event type, required fields present with the right types,
    finite timestamps, and non-decreasing ``seq`` (emission order survived
    serialization).
    """
    errors = []
    last_seq = -1
    for i, e in enumerate(events):
        where = f"event {i}"
        if not isinstance(e, dict):
            errors.append(f"{where}: not an object")
            continue
        ev = e.get("ev")
        schema = EVENT_SCHEMA.get(ev)
        if schema is None:
            errors.append(f"{where}: unknown event type {ev!r}")
            continue
        for f, typ in schema.items():
            if f not in e:
                errors.append(f"{where} ({ev}): missing field {f!r}")
            elif not isinstance(e[f], typ) or isinstance(e[f], bool):
                errors.append(f"{where} ({ev}): field {f!r} has "
                              f"{type(e[f]).__name__}, want {typ}")
        t = e.get("t")
        if isinstance(t, (int, float)) and not math.isfinite(t):
            errors.append(f"{where} ({ev}): non-finite timestamp {t}")
        seq = e.get("seq")
        if isinstance(seq, int):
            if seq < last_seq:
                errors.append(f"{where} ({ev}): seq {seq} < previous {last_seq}")
            last_seq = seq
    return errors


def validate_jsonl(path) -> list[str]:
    try:
        events = read_jsonl(path)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: {e}"]
    if not events:
        return [f"{path}: no events"]
    return validate_events(events)


# ---------------------------------------------------------------------------
# Registry snapshots
# ---------------------------------------------------------------------------
def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def _fmt_val(v: float) -> str:
    return repr(float(v))


def prometheus_text(registry: MetricsRegistry) -> str:
    """Prometheus text exposition format snapshot of ``registry``."""
    lines = []
    typed: set[str] = set()
    for name, labels, m in registry.collect():
        if name not in typed:
            typed.add(name)
            kind = {Counter: "counter", Gauge: "gauge",
                    Histogram: "histogram"}[type(m)]
            lines.append(f"# TYPE {name} {kind}")
        if isinstance(m, (Counter, Gauge)):
            lines.append(f"{name}{_fmt_labels(labels)} {_fmt_val(m.value)}")
            continue
        cum = 0
        for edge, c in zip(m.boundaries, m.counts):
            cum += c
            lab = _fmt_labels({**labels, "le": _fmt_val(edge)})
            lines.append(f"{name}_bucket{lab} {cum}")
        lab = _fmt_labels({**labels, "le": "+Inf"})
        lines.append(f"{name}_bucket{lab} {m.count}")
        lines.append(f"{name}_sum{_fmt_labels(labels)} {_fmt_val(m.sum)}")
        lines.append(f"{name}_count{_fmt_labels(labels)} {m.count}")
    return "\n".join(lines) + "\n"


def bench_summary(registry: MetricsRegistry) -> dict:
    """BENCH-compatible JSON summary: histograms as percentile rows."""
    out: dict[str, list] = {}
    for name, labels, m in registry.collect():
        if isinstance(m, Histogram):
            row = {"labels": labels, "count": m.count, "mean": m.mean(),
                   "min": m.vmin, "max": m.vmax,
                   "p50": m.percentile(0.50), "p95": m.percentile(0.95),
                   "p99": m.percentile(0.99)}
        else:
            row = {"labels": labels, "value": m.value}
        out.setdefault(name, []).append(row)
    return out


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="validate an obs JSONL event log against the schema")
    ap.add_argument("--validate", metavar="PATH", required=True,
                    help="JSONL trace to check; exits 1 on any schema error")
    args = ap.parse_args(argv)
    errors = validate_jsonl(args.validate)
    if errors:
        for e in errors[:50]:
            print(f"INVALID: {e}")
        return 1
    n = len(read_jsonl(args.validate))
    print(f"OK: {args.validate} ({n} events, schema-valid)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

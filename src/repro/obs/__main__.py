"""CLI: ``python -m repro.obs --validate trace.jsonl`` (see export.main)."""
from .export import main

raise SystemExit(main())

"""Process-local metrics registry: counters, gauges, fixed-bucket histograms.

Zero-dependency (stdlib only — no jax, no numpy) so the serving scheduler,
the kernel dispatcher, and the trainer can all record into one registry
without import cycles or device work.  The three metric kinds mirror the
Prometheus data model (``repro.obs.export`` renders the text exposition
format), but percentiles are computed *here*, from the buckets, so
benchmarks never need a scrape pipeline:

* :class:`Counter` — monotonically increasing float.
* :class:`Gauge` — last-written float (pool utilization, tokens/sec).
* :class:`Histogram` — fixed upper-bound buckets; ``percentile(q)`` is
  exact to one bucket width (it returns the upper edge of the bucket
  holding the rank-``q`` observation, or the observed max for the overflow
  bucket), and histograms with identical boundaries :meth:`~Histogram.merge`
  losslessly — the multi-process story is "merge the snapshots".

All operations are O(1) except ``percentile`` (O(buckets)); nothing here
allocates on the observe path beyond float arithmetic, which is what lets
the serving engine keep its overhead contract (DESIGN.md §9).
"""
from __future__ import annotations

import bisect
from typing import Iterator


def exp_buckets(start: float, factor: float, count: int) -> tuple[float, ...]:
    """``count`` exponentially spaced bucket upper bounds from ``start``."""
    if start <= 0 or factor <= 1 or count < 1:
        raise ValueError("need start > 0, factor > 1, count >= 1")
    return tuple(start * factor ** i for i in range(count))


# 10µs … ~160s at ~35% resolution: covers a Pallas kernel on TPU up to a
# multi-minute CPU-interpreter prefill with one bucket scheme, so histograms
# recorded anywhere in the stack stay mergeable.
DEFAULT_LATENCY_BUCKETS = exp_buckets(1e-5, 1.35, 56)


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError("counters only go up")
        self.value += n


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed-bucket histogram over ascending upper bounds.

    Bucket *i* counts observations ``v <= boundaries[i]`` (and above the
    previous bound); one implicit overflow bucket catches the rest.  Tracks
    count / sum / min / max exactly.
    """

    __slots__ = ("boundaries", "counts", "count", "sum", "vmin", "vmax")

    def __init__(self, boundaries=DEFAULT_LATENCY_BUCKETS):
        bounds = tuple(float(b) for b in boundaries)
        if not bounds or any(a >= b for a, b in zip(bounds, bounds[1:])):
            raise ValueError("boundaries must be non-empty and ascending")
        self.boundaries = bounds
        self.counts = [0] * (len(bounds) + 1)  # [-1] = overflow
        self.count = 0
        self.sum = 0.0
        self.vmin: float | None = None
        self.vmax: float | None = None

    def observe(self, v: float) -> None:
        self.counts[bisect.bisect_left(self.boundaries, v)] += 1
        self.count += 1
        self.sum += v
        if self.vmin is None or v < self.vmin:
            self.vmin = v
        if self.vmax is None or v > self.vmax:
            self.vmax = v

    def mean(self) -> float | None:
        return self.sum / self.count if self.count else None

    def percentile(self, q: float) -> float | None:
        """Value at quantile ``q`` ∈ [0, 1], exact to one bucket width.

        Returns the upper edge of the bucket containing the rank-``q``
        observation (the true value is ≤ that edge and > the previous one);
        the overflow bucket reports the observed max.  ``None`` when empty.
        """
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        if self.count == 0:
            return None
        rank = max(1, -(-q * self.count // 1))  # ceil(q * count), at least 1
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= rank:
                if i == len(self.boundaries):
                    return self.vmax
                # tighten to observed extremes: a single-bucket histogram
                # should still report a value that was actually seen
                edge = self.boundaries[i]
                return min(edge, self.vmax) if self.vmax is not None else edge
        return self.vmax  # unreachable

    def merge(self, other: "Histogram") -> None:
        if self.boundaries != other.boundaries:
            raise ValueError("cannot merge histograms with different buckets")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.count += other.count
        self.sum += other.sum
        for v in (other.vmin, other.vmax):
            if v is None:
                continue
            if self.vmin is None or v < self.vmin:
                self.vmin = v
            if self.vmax is None or v > self.vmax:
                self.vmax = v


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Get-or-create store of named, labelled metrics.

    A metric identity is ``(name, sorted label items)``; a name is pinned to
    one kind at first use (asking for the same name as a different kind
    raises, mirroring Prometheus).  Registries merge (counters/sums add,
    gauges take the other's last write, histograms bucket-merge), which is
    the aggregation story for per-engine or per-process registries.
    """

    def __init__(self):
        self._metrics: dict[tuple, object] = {}
        self._kinds: dict[str, str] = {}

    def _get(self, kind: str, name: str, labels: dict, **kw):
        seen = self._kinds.setdefault(name, kind)
        if seen != kind:
            raise ValueError(f"metric {name!r} already registered as {seen}")
        key = (name, tuple(sorted(labels.items())))
        m = self._metrics.get(key)
        if m is None:
            m = self._metrics[key] = _KINDS[kind](**kw)
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels)

    def histogram(self, name: str, buckets=None, **labels) -> Histogram:
        kw = {} if buckets is None else {"boundaries": buckets}
        return self._get("histogram", name, labels, **kw)

    def get(self, name: str, **labels):
        """Existing metric or ``None`` (never creates)."""
        return self._metrics.get((name, tuple(sorted(labels.items()))))

    def kind(self, name: str) -> str | None:
        return self._kinds.get(name)

    def collect(self) -> Iterator[tuple[str, dict, object]]:
        """(name, labels, metric) in insertion order."""
        for (name, labels), m in self._metrics.items():
            yield name, dict(labels), m

    def merge(self, other: "MetricsRegistry") -> None:
        for name, labels, m in other.collect():
            kind = other._kinds[name]
            mine = self._get(kind, name, labels,
                             **({"boundaries": m.boundaries}
                                if kind == "histogram" else {}))
            if kind == "counter":
                mine.inc(m.value)
            elif kind == "gauge":
                mine.set(m.value)
            else:
                mine.merge(m)

    def reset(self) -> None:
        self._metrics.clear()
        self._kinds.clear()

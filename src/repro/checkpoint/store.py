"""Sharded, async, elastic checkpointing.

Layout:  <dir>/step_<k>/
           manifest.json           tree structure, shapes, dtypes, shard map
           shard_<i>.npz           per-host shard files (leaf -> local slice)
           COMMIT                  written last: partial checkpoints are never
                                   visible to ``latest_step``

Elasticity: leaves are saved as *global* logical arrays (assembled from
addressable shards); ``restore_checkpoint`` re-shards onto whatever mesh the
restoring job provides — growing or shrinking the cluster just changes the
target ``NamedSharding``.  On a real multi-host cluster each host writes the
shards it owns; in this single-process container that degenerates to one
shard file, but the addressable-shard walk is the same code path.

Fault-tolerance contract with the trainer: save is atomic (COMMIT marker),
async (background thread, overlaps the next steps), and keeps the last
``keep`` checkpoints.
"""
from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.collectives import reshard

_SEP = "/"


def _flatten_with_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        keys = []
        for p in path:
            keys.append(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))))
        out.append((_SEP.join(keys), leaf))
    return out


def save_checkpoint(ckpt_dir: str | Path, step: int, tree, *, extra: dict | None = None,
                    keep: int = 3) -> Path:
    """Write a checkpoint synchronously; returns its directory."""
    ckpt_dir = Path(ckpt_dir)
    out = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves = _flatten_with_paths(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": {}}
    arrays = {}
    for name, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        arrays[name] = arr
        manifest["leaves"][name] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
    np.savez(tmp / "shard_0.npz", **{k.replace("/", "::"): v for k, v in arrays.items()})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    (tmp / "COMMIT").write_text("ok")
    if out.exists():
        shutil.rmtree(out)
    tmp.rename(out)
    _gc(ckpt_dir, keep)
    return out


def _gc(ckpt_dir: Path, keep: int):
    steps = sorted(p for p in ckpt_dir.glob("step_*") if (p / "COMMIT").exists())
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(ckpt_dir: str | Path) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    steps = sorted(int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*")
                   if (p / "COMMIT").exists())
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str | Path, step: int, target_tree, *,
                       shardings=None) -> tuple[Any, dict]:
    """Restore into the structure of ``target_tree`` (values ignored), placing
    leaves with ``shardings`` (pytree of NamedSharding) when given —
    re-sharding onto a different mesh than the one that saved is fine."""
    path = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((path / "manifest.json").read_text())
    with np.load(path / "shard_0.npz") as z:
        arrays = {k.replace("::", "/"): z[k] for k in z.files}
    for name, a in arrays.items():
        want = manifest["leaves"].get(name, {}).get("dtype")
        if want and str(a.dtype) != want:
            # npz stores extended dtypes (bfloat16) as raw void bytes;
            # reinterpret through the dtype the manifest recorded
            arrays[name] = a.view(jnp.dtype(want))

    names = [n for n, _ in _flatten_with_paths(target_tree)]
    missing = [n for n in names if n not in arrays]
    if missing:
        raise KeyError(f"checkpoint missing leaves: {missing[:5]} …")
    leaves = [arrays[n] for n in names]
    treedef = jax.tree_util.tree_structure(target_tree)
    sh_leaves = None if shardings is None else treedef.flatten_up_to(shardings)
    leaves = reshard(leaves, sh_leaves)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extra"]


class AsyncCheckpointer:
    """Background-thread checkpointing: ``maybe_save`` returns immediately;
    the previous save is joined before a new one starts (bounded queue of 1,
    so training is never more than one checkpoint behind)."""

    def __init__(self, ckpt_dir: str | Path, every: int = 100, keep: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.every = every
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.saved_steps: list[int] = []

    def maybe_save(self, step: int, tree, extra: dict | None = None, force=False):
        if not force and (self.every <= 0 or step % self.every != 0):
            return False
        self.wait()
        # materialize on host *before* handing to the thread so the device
        # buffers can be donated/updated by subsequent steps
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            save_checkpoint(self.ckpt_dir, step, host_tree, extra=extra, keep=self.keep)
            self.saved_steps.append(step)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        return True

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

"""Granite-3 8B: 40L d4096 32H (GQA kv=8) d_ff 12800 vocab 49155
[hf:ibm-granite/granite-3.0-8b-base; hf]."""
from repro.config import ModelConfig
from ._common import PAPER_TTD, reduced_common

ARCH = "granite-3-8b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense", n_layers=40, d_model=4096, n_heads=32,
        n_kv_heads=8, head_dim=128, d_ff=12800, vocab_size=49155,
        rope_theta=10000.0, ttd=PAPER_TTD,
    )


def reduced() -> ModelConfig:
    return reduced_common(config())

"""Qwen2-VL 7B backbone: 28L d3584 28H (GQA kv=4) d_ff 18944 vocab 152064,
M-RoPE; the vision patch frontend is a STUB (precomputed embeddings +
3D position ids come from input_specs)  [arXiv:2409.12191; hf]."""
from repro.config import ModelConfig
from ._common import PAPER_TTD, reduced_common

ARCH = "qwen2-vl-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense", n_layers=28, d_model=3584, n_heads=28,
        n_kv_heads=4, head_dim=128, d_ff=18944, vocab_size=152064,
        pos_type="mrope", mrope_sections=(16, 24, 24), rope_theta=1e6,
        ttd=PAPER_TTD,
    )


def reduced() -> ModelConfig:
    return reduced_common(config(), pos_type="mrope", mrope_sections=(2, 3, 3))

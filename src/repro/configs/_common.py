"""Shared helpers for architecture config files."""
from __future__ import annotations

from repro.config import ModelConfig, QuantConfig, TTDConfig, TTLayerOverride

# Paper-recipe TTD: attn output + all MLP / expert / channel-mix linears,
# Q/K/V excluded (paper SV.A), d=4, rank=16, balanced auto-factorization.
PAPER_TTD = TTDConfig(enabled=True, rank=16, d=4)
REDUCED_TTD = TTDConfig(enabled=True, rank=4, d=3)
INT4 = QuantConfig(enabled=True, bits=4, group_size=128)


def reduced_common(cfg: ModelConfig, **kw) -> ModelConfig:
    """Shrink any config to a CPU-smoke size, keeping the family's structure
    (TT path stays on, with rank 4 and power-of-two dims)."""
    base = dict(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        max_seq_len=128,
        ttd=REDUCED_TTD,
        quant=QuantConfig(enabled=False),
        q_block=32,
        kv_block=32,
    )
    base.update(kw)
    return cfg.replace(**base)

"""Qwen1.5-110B: 80L d8192 64H (GQA kv=8) d_ff 49152 vocab 152064,
QKV bias  [hf:Qwen/Qwen1.5-110B; hf]."""
from repro.config import ModelConfig
from ._common import PAPER_TTD, reduced_common

ARCH = "qwen1.5-110b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense", n_layers=80, d_model=8192, n_heads=64,
        n_kv_heads=8, head_dim=128, d_ff=49152, vocab_size=152064,
        qkv_bias=True, rope_theta=1e6, ttd=PAPER_TTD,
    )


def reduced() -> ModelConfig:
    return reduced_common(config(), qkv_bias=True)

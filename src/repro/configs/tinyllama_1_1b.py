"""TinyLlama 1.1B: 22L d2048 32H (GQA kv=4) d_ff 5632 vocab 32000
[arXiv:2401.02385; hf]."""
from repro.config import ModelConfig
from ._common import PAPER_TTD, reduced_common

ARCH = "tinyllama-1.1b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense", n_layers=22, d_model=2048, n_heads=32,
        n_kv_heads=4, head_dim=64, d_ff=5632, vocab_size=32000,
        rope_theta=10000.0, ttd=PAPER_TTD,
    )


def reduced() -> ModelConfig:
    return reduced_common(config())

"""Kimi K2 — trillion-param MoE: 61L d7168 64H (GQA kv=8) MoE 384e top-8,
expert d_ff 2048, vocab 163840  [arXiv:2501.kimi2; paper-table]."""
from repro.config import ModelConfig
from ._common import PAPER_TTD, reduced_common

ARCH = "kimi-k2-1t-a32b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="moe", n_layers=61, d_model=7168, n_heads=64,
        n_kv_heads=8, head_dim=112, d_ff=2048, d_ff_expert=2048,
        n_experts=384, experts_per_token=8, vocab_size=163840,
        rope_theta=50000.0, ttd=PAPER_TTD,
    )


def reduced() -> ModelConfig:
    return reduced_common(config(), n_experts=8, experts_per_token=2,
                          d_ff_expert=32, moe_impl="dense")

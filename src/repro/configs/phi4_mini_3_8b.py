"""Phi-4-mini 3.8B: 32L d3072 24H (GQA kv=8) d_ff 8192 vocab 200064,
RoPE SwiGLU GQA, tied embeddings  [arXiv:2412.08905; hf]."""
from repro.config import ModelConfig
from ._common import PAPER_TTD, reduced_common

ARCH = "phi4-mini-3.8b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense", n_layers=32, d_model=3072, n_heads=24,
        n_kv_heads=8, head_dim=128, d_ff=8192, vocab_size=200064,
        tie_embeddings=True, rope_theta=10000.0, ttd=PAPER_TTD,
    )


def reduced() -> ModelConfig:
    return reduced_common(config())

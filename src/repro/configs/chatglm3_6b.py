"""ChatGLM3-6B — the paper's own benchmark (Table I): 28L d4096 32H
(multi-query kv=2) d_ff 13696 vocab 65024; TTD on LinearO + MLP with the
paper's exact factorizations, 15 of 28 blocks compressed."""
from repro.config import ModelConfig, QuantConfig, TTDConfig, TTLayerOverride
from ._common import reduced_common

ARCH = "chatglm3-6b"

TT_OVERRIDES = (
    ("attn_o", TTLayerOverride(in_modes=(16, 8, 8, 4), out_modes=(4, 8, 8, 16), rank=16)),
    ("mlp_gate", TTLayerOverride(in_modes=(8, 8, 8, 8), out_modes=(4, 4, 8, 107), rank=16)),
    ("mlp_up", TTLayerOverride(in_modes=(8, 8, 8, 8), out_modes=(4, 4, 8, 107), rank=16)),
    ("mlp_down", TTLayerOverride(in_modes=(107, 8, 4, 4), out_modes=(8, 8, 8, 8), rank=16)),
)


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense", n_layers=28, d_model=4096, n_heads=32,
        n_kv_heads=2, head_dim=128, d_ff=13696, vocab_size=65024,
        qkv_bias=True, partial_rotary=0.5,
        ttd=TTDConfig(enabled=True, rank=16, d=4, overrides=TT_OVERRIDES,
                      first_tt_block=13),  # blocks 13..27 TT'd (15 of 28)
    )


def reduced() -> ModelConfig:
    return reduced_common(config(), qkv_bias=True, partial_rotary=0.5)

"""Whisper-base backbone: 6L enc + 6L dec, d512 8H d_ff 2048 vocab 51865,
enc-dec with conv frontend STUB (precomputed frame embeddings); decoder
positions extended to the assigned lengths  [arXiv:2212.04356]."""
from repro.config import ModelConfig, TTDConfig
from ._common import reduced_common

ARCH = "whisper-base"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="encdec", n_layers=6, n_enc_layers=6, d_model=512,
        n_heads=8, n_kv_heads=8, head_dim=64, d_ff=2048, vocab_size=51865,
        norm_type="layernorm", act="gelu_mlp", pos_type="learned",
        enc_len=1500, tie_embeddings=True, max_seq_len=32768,
        ttd=TTDConfig(enabled=True, rank=16, d=3),  # d=3: small dims
    )


def reduced() -> ModelConfig:
    return reduced_common(config(), n_layers=2, n_enc_layers=2, enc_len=16,
                          n_kv_heads=4, norm_type="layernorm", act="gelu_mlp",
                          pos_type="learned")

"""RecurrentGemma-2B (Griffin): 26L d2560 10H (MQA kv=1, hd 256) GeGLU
d_ff 7680, vocab 256000, RG-LRU + local attention (window 2048), pattern
(rec, rec, attn)  [arXiv:2402.19427; hf]."""
from repro.config import ModelConfig, TTDConfig
from ._common import PAPER_TTD, reduced_common

# hillclimb-2 iteration 4 (EXPERIMENTS.md §Perf): TT on the RG-LRU in/out
# projections forces a seq<->width activation reshard per recurrent block
# (the recurrence needs full-seq, TT wants token-sharded); dense
# column/row-parallel projections need no reshard. TT stays on the MLP +
# attn-O (the parameter mass).
GRIFFIN_TTD = TTDConfig(enabled=True, rank=16, d=4,
                        roles=("attn_o", "mlp_gate", "mlp_up", "mlp_down"))

ARCH = "recurrentgemma-2b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="griffin", n_layers=26, d_model=2560, n_heads=10,
        n_kv_heads=1, head_dim=256, d_ff=7680, vocab_size=256000,
        act="geglu", window=2048, lru_width=2560, conv_width=4,
        pattern=("rec", "rec", "attn"), tie_embeddings=True,
        rope_theta=10000.0, ttd=GRIFFIN_TTD,
    )


def reduced() -> ModelConfig:
    return reduced_common(config(), n_layers=4, n_heads=2, n_kv_heads=1,
                          head_dim=32, lru_width=64, window=16,
                          pattern=("rec", "rec", "attn"), act="geglu")

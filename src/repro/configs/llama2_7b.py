"""LLaMA2-7B — the paper's own benchmark (Table I): 32L d4096 32H MHA
d_ff 11008 vocab 32000; TTD on LinearO + MLP with the paper's exact
factorizations, 19 of 32 blocks compressed."""
from repro.config import ModelConfig, TTDConfig, TTLayerOverride
from ._common import reduced_common

ARCH = "llama2-7b"

TT_OVERRIDES = (
    ("attn_o", TTLayerOverride(in_modes=(16, 8, 8, 4), out_modes=(4, 8, 8, 16), rank=16)),
    ("mlp_gate", TTLayerOverride(in_modes=(16, 8, 8, 4), out_modes=(4, 4, 16, 43), rank=16)),
    ("mlp_up", TTLayerOverride(in_modes=(16, 8, 8, 4), out_modes=(4, 4, 16, 43), rank=16)),
    ("mlp_down", TTLayerOverride(in_modes=(43, 16, 4, 4), out_modes=(4, 8, 8, 16), rank=16)),
)


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense", n_layers=32, d_model=4096, n_heads=32,
        n_kv_heads=32, head_dim=128, d_ff=11008, vocab_size=32000,
        ttd=TTDConfig(enabled=True, rank=16, d=4, overrides=TT_OVERRIDES,
                      first_tt_block=13),  # blocks 13..31 TT'd (19 of 32)
    )


def reduced() -> ModelConfig:
    return reduced_common(config())

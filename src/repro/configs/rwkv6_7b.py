"""RWKV6 "Finch" 7B: 32L d4096 attention-free, d_ff 14336, vocab 65536,
data-dependent decay  [arXiv:2404.05892; hf]."""
from repro.config import ModelConfig
from ._common import PAPER_TTD, reduced_common

ARCH = "rwkv6-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="rwkv", n_layers=32, d_model=4096, n_heads=64,
        n_kv_heads=64, head_dim=64, d_ff=14336, vocab_size=65536,
        rwkv_head_dim=64, ttd=PAPER_TTD,
    )


def reduced() -> ModelConfig:
    return reduced_common(config(), n_heads=4, n_kv_heads=4, head_dim=16,
                          rwkv_head_dim=16, rwkv_lora_mix=8, rwkv_lora_decay=8)

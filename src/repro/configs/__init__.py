"""Architecture registry: the 10 assigned archs + the paper's own two."""
from importlib import import_module

_MODULES = {
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "mixtral-8x22b": "mixtral_8x22b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "qwen1.5-110b": "qwen1_5_110b",
    "granite-3-8b": "granite_3_8b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "rwkv6-7b": "rwkv6_7b",
    "whisper-base": "whisper_base",
    "chatglm3-6b": "chatglm3_6b",
    "llama2-7b": "llama2_7b",
}

ASSIGNED_ARCHS = tuple(list(_MODULES)[:10])
ALL_ARCHS = tuple(_MODULES)


def _mod(name: str):
    key = name.replace("_", "-")
    if key not in _MODULES:
        raise KeyError(f"unknown arch {name}; known: {ALL_ARCHS}")
    return import_module(f".{_MODULES[key]}", __package__)


def get_config(name: str, reduced: bool = False):
    m = _mod(name)
    return m.reduced() if reduced else m.config()

"""Mixtral 8x22B: 56L d6144 48H (GQA kv=8) MoE 8e top-2, d_ff 16384,
vocab 32768, sliding-window attention  [arXiv:2401.04088; hf]."""
from repro.config import ModelConfig
from ._common import PAPER_TTD, reduced_common

ARCH = "mixtral-8x22b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="moe", n_layers=56, d_model=6144, n_heads=48,
        n_kv_heads=8, head_dim=128, d_ff=16384, d_ff_expert=16384,
        n_experts=8, experts_per_token=2, vocab_size=32768,
        window=4096, rope_theta=1e6, ttd=PAPER_TTD,
    )


def reduced() -> ModelConfig:
    return reduced_common(config(), n_experts=4, experts_per_token=2,
                          d_ff_expert=32, window=16, moe_impl="dense")

"""Stream tokens from the asyncio serving front-end — with a mid-stream
cancel.

    PYTHONPATH=src python examples/serve_async.py

Two requests are submitted concurrently to :class:`repro.serve.AsyncEngine`
(DESIGN.md §12).  The first is streamed to completion with ``async for``;
the second is cancelled after its first few tokens arrive, which frees its
decode slot and KV blocks mid-flight.  The example asserts

* the completed stream is token-identical to generating the same prompt
  alone via ``model.prefill`` + ``model.decode_step``,
* the cancelled stream is a strict prefix of its solo reference and is
  marked ``cancelled`` with ``finish_reason == "user"``,
* the engine overlapped host and device work (dispatch-ahead ticks fired).
"""
import asyncio

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model
from repro.serve import AsyncEngine


def reference(model, params, prompt, n):
    logits, cache = model.prefill(params, {"tokens": jnp.asarray([prompt], jnp.int32)},
                                  cache_dtype=jnp.float32, max_len=96)
    out = [int(jnp.argmax(logits[0]))]
    for pos in range(len(prompt), len(prompt) + n - 1):
        logits, cache = model.decode_step(
            params, cache, {"tokens": jnp.asarray([[out[-1]]], jnp.int32)},
            jnp.int32(pos))
        out.append(int(jnp.argmax(logits[0])))
    return out


async def main():
    cfg = get_config("tinyllama-1.1b", reduced=True).replace(
        compute_dtype="float32", param_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    frontend = AsyncEngine(model, params, slots=2, max_len=96,
                           block_size=8, prefill_chunk=8)
    keep = frontend.submit([1, 2, 3, 4, 5], max_tokens=20)
    drop = frontend.submit([7, 8, 9], max_tokens=20)

    async def stream_all(handle):
        toks = []
        async for tok in handle.stream():
            toks.append(tok)
        return toks

    async def stream_then_cancel(handle, after):
        toks = []
        async for tok in handle.stream():
            toks.append(tok)
            if len(toks) == after:
                handle.cancel()  # frees the slot + KV blocks mid-flight
        return toks

    kept, dropped = await asyncio.gather(stream_all(keep),
                                         stream_then_cancel(drop, after=3))
    await frontend.drain()

    assert kept == reference(model, params, [1, 2, 3, 4, 5], 20)
    solo = reference(model, params, [7, 8, 9], 20)
    assert dropped == solo[:len(dropped)] and len(dropped) < len(solo)
    assert drop.cancelled and drop.finish_reason == "user"
    assert frontend.stats["ahead_ticks"] > 0  # double buffering engaged

    print(f"streamed {len(kept)} tokens (identical to the solo reference); "
          f"cancelled the second request after {len(dropped)} tokens "
          f"(a strict prefix of its reference)")
    print(f"dispatch-ahead ticks: {frontend.stats['ahead_ticks']}"
          f"/{frontend.stats['ticks']}")
    print("OK")


if __name__ == "__main__":
    asyncio.run(main())

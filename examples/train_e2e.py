"""End-to-end training driver.

    PYTHONPATH=src python examples/train_e2e.py --preset cpu-small
    PYTHONPATH=src python examples/train_e2e.py --preset 100m --steps 300

Presets:
  cpu-small  ~4M-param TT llama, runs a few hundred steps in minutes on CPU.
  100m       ~100M-param config (the assignment's e2e scale; needs real
             accelerators for sensible wall-time, works on CPU in principle).
  <arch-id>  any registry architecture at full size (--reduced to shrink).

Features exercised: TT-from-scratch training, AdamW/Adafactor, grad accum,
async checkpointing + resume, straggler watchdog, deterministic data.
"""
import argparse
import logging

import jax

from repro.config import TrainConfig
from repro.configs import ALL_ARCHS, get_config
from repro.data.pipeline import DataConfig
from repro.models import build_model
from repro.train.step import build_train_step, init_train_state
from repro.train.trainer import Trainer

logging.basicConfig(level=logging.INFO, format="%(message)s")

PRESETS = {
    "cpu-small": dict(arch="tinyllama-1.1b", reduced=True,
                      overrides=dict(n_layers=4, d_model=128, n_heads=4,
                                     n_kv_heads=2, head_dim=32, d_ff=256,
                                     vocab_size=512),
                      train=dict(global_batch=8, seq_len=128, lr=3e-3)),
    "100m": dict(arch="tinyllama-1.1b", reduced=False,
                 overrides=dict(n_layers=12, d_model=768, n_heads=12,
                                n_kv_heads=4, head_dim=64, d_ff=2048,
                                vocab_size=32000, max_seq_len=2048),
                 train=dict(global_batch=8, seq_len=512, lr=6e-4)),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="cpu-small",
                    help=f"cpu-small | 100m | one of {ALL_ARCHS}")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "adafactor"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    if args.preset in PRESETS:
        p = PRESETS[args.preset]
        cfg = get_config(p["arch"], reduced=p["reduced"]).replace(**p["overrides"])
        tkw = p["train"]
    else:
        cfg = get_config(args.preset, reduced=args.reduced)
        tkw = dict(global_batch=8, seq_len=256, lr=1e-3)

    model = build_model(cfg)
    tc = TrainConfig(total_steps=args.steps, warmup_steps=max(args.steps // 20, 5),
                     optimizer=args.optimizer, microbatches=args.microbatches,
                     remat="dots", **tkw)
    state = init_train_state(model, tc, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"arch={cfg.name} params={n/1e6:.1f}M ttd={cfg.ttd.enabled} "
          f"opt={tc.optimizer} batch={tc.global_batch}x{tc.seq_len}")

    step = jax.jit(build_train_step(model, tc))
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=tc.seq_len,
                      global_batch=tc.global_batch, seed=tc.seed)
    trainer = Trainer(step, state, data, ckpt_dir=args.ckpt_dir, ckpt_every=50)
    if args.resume:
        trainer._restore_latest()
    report = trainer.run(args.steps, log_every=20)
    print(f"done: {report.steps_done} steps, loss {report.losses[0]:.3f} -> "
          f"{report.losses[-1]:.3f}, {report.restarts} restarts, "
          f"{len(report.straggler_events)} straggler events")


if __name__ == "__main__":
    main()

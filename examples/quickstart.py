"""Quickstart: train a tiny TT-compressed LM from scratch on synthetic data.

    PYTHONPATH=src python examples/quickstart.py

TT cores are trainable parameters here (the from-scratch path); see
compress_pretrained.py for the paper's post-training compression path.
Runs in ~1 minute on CPU.
"""
import jax

from repro.config import TrainConfig
from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.models import build_model
from repro.train.step import build_train_step, init_train_state
from repro.train.trainer import Trainer


def main():
    cfg = get_config("tinyllama-1.1b", reduced=True)
    print(f"model: {cfg.name} (reduced) — TT rank {cfg.ttd.rank} on roles {cfg.ttd.roles[:4]}…")
    model = build_model(cfg)
    tc = TrainConfig(global_batch=8, seq_len=64, lr=3e-3, warmup_steps=10,
                     total_steps=150, optimizer="adamw", remat="none")
    state = init_train_state(model, tc, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"params: {n:,}")
    step = jax.jit(build_train_step(model, tc))
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=tc.seq_len,
                      global_batch=tc.global_batch, seed=0)
    trainer = Trainer(step, state, data)
    report = trainer.run(100, log_every=0)
    print(f"loss: {report.losses[0]:.3f} -> {report.losses[-1]:.3f} "
          f"over {report.steps_done} steps")
    assert report.losses[-1] < report.losses[0]
    print("OK")


if __name__ == "__main__":
    main()

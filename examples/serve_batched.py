"""Serve small TT-compressed models with continuous batching — every family
through one engine.

    PYTHONPATH=src python examples/serve_batched.py

Eight requests with different prompt lengths share 3 decode slots; finished
requests free resources for queued ones mid-flight.  The same workload runs
through the unified session engine (DESIGN.md §7) for a transformer (both
its state backends) and a recurrent family:

* ``backend="paged"`` — shared KV block pools + block tables
* ``backend="ring"``  — per-slot K/V rings (the SWA-capable layout)
* rwkv               — constant-size recurrent state

and every request's greedy output is asserted token-identical to generating
it alone via ``model.prefill`` + ``model.decode_step``.

The runs are instrumented through ``repro.obs`` (DESIGN.md §9): each engine
gets an :class:`~repro.obs.Observer`, the example prints p50/p95 TTFT from
the metrics registry, and the first engine streams its scheduler trace
(admit / prefill_chunk / decode_tick / finish events) to
``serve_trace.jsonl`` — validate it with
``python -m repro.obs --validate serve_trace.jsonl``.
"""
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model
from repro.obs import Observer, ObsConfig, validate_jsonl
from repro.serve.engine import Engine


def reference(model, params, prompt, n):
    logits, cache = model.prefill(params, {"tokens": jnp.asarray([prompt], jnp.int32)},
                                  cache_dtype=jnp.float32, max_len=96)
    out = [int(jnp.argmax(logits[0]))]
    for pos in range(len(prompt), len(prompt) + n - 1):
        logits, cache = model.decode_step(
            params, cache, {"tokens": jnp.asarray([[out[-1]]], jnp.int32)},
            jnp.int32(pos))
        out.append(int(jnp.argmax(logits[0])))
    return out


def serve(engine, prompts):
    reqs = [engine.submit(p, max_tokens=12) for p in prompts]
    t0 = time.perf_counter()
    done = engine.run()
    wall = time.perf_counter() - t0
    assert len(done) == len(prompts)
    toks = sum(len(r.out_tokens) for r in done)
    # the same numbers, from the obs registry's bucketed histogram
    ttft = engine.obs.registry.get("serve_ttft_seconds")
    print(f"  {engine.cfg.family:8s}/{engine.session.backend:9s}: "
          f"{toks} tokens in {wall:.2f}s ({toks / wall:.1f} tok/s, "
          f"ttft p50 {ttft.percentile(0.5) * 1e3:.0f}ms "
          f"p95 {ttft.percentile(0.95) * 1e3:.0f}ms)")
    return [r.out_tokens for r in reqs]


def main():
    prompts = [[1 + i, 2, 3 + i] + list(range(4, 4 + i)) for i in range(8)]
    print(f"serving {len(prompts)} requests on 3 slots (CPU):")
    trace_path = "serve_trace.jsonl"
    Path(trace_path).unlink(missing_ok=True)  # the writer appends
    first = True
    for arch, backends in (("tinyllama-1.1b", ("paged", "ring")),
                           ("rwkv6-7b", (None,))):
        cfg = get_config(arch, reduced=True).replace(
            compute_dtype="float32", param_dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        expected = [reference(model, params, p, 12) for p in prompts]
        for backend in backends:
            # the first engine also streams its scheduler trace to JSONL
            obs = Observer(ObsConfig(jsonl_path=trace_path if first else None))
            first = False
            out = serve(Engine(model, params, slots=3, max_len=96,
                               block_size=8, prefill_batch=2, prefill_chunk=8,
                               backend=backend, obs=obs), prompts)
            obs.close()
            assert out == expected, f"{arch}/{backend} diverged from reference"
    errors = validate_jsonl(trace_path)
    assert not errors, errors
    print(f"wrote schema-valid scheduler trace to {trace_path}")
    print("OK (all backends token-identical to the one-request reference)")


if __name__ == "__main__":
    main()

"""Serve a small TT-compressed model with continuous batching.

    PYTHONPATH=src python examples/serve_batched.py

Eight requests with different prompt lengths share 3 decode slots; finished
requests free slots for queued ones mid-flight (the engine's scheduling is
the same shape as a production continuous-batching server).
"""
import time

import jax

from repro.configs import get_config
from repro.models import get_model
from repro.serve.engine import Engine


def main():
    cfg = get_config("tinyllama-1.1b", reduced=True).replace(
        compute_dtype="float32", param_dtype="float32")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params, slots=3, max_len=96)

    prompts = [[1 + i, 2, 3 + i] + list(range(4, 4 + i)) for i in range(8)]
    reqs = [engine.submit(p, max_tokens=12) for p in prompts]
    t0 = time.time()
    done = engine.run()
    dt = time.time() - t0
    total_toks = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests / {total_toks} tokens in {dt:.2f}s "
          f"({total_toks / dt:.1f} tok/s on CPU, 3 slots)")
    for r in done[:4]:
        print(f"  req {r.rid}: prompt_len={len(r.prompt)} -> {r.out_tokens}")
    assert len(done) == len(prompts)
    print("OK")


if __name__ == "__main__":
    main()

"""Serve a small TT-compressed model with continuous batching: ring vs paged.

    PYTHONPATH=src python examples/serve_batched.py

Eight requests with different prompt lengths share 3 decode slots; finished
requests free resources for queued ones mid-flight.  The same workload runs
through both engines:

* ``Engine`` — per-slot ring caches, single-sequence prefill (reference)
* ``PagedEngine`` — paged KV blocks + block tables, batched chunked prefill,
  one ragged decode call per tick (DESIGN.md §6)

and their greedy outputs are asserted token-identical.
"""
import time

import jax

from repro.configs import get_config
from repro.models import get_model
from repro.serve.engine import Engine, PagedEngine


def serve(engine, prompts):
    reqs = [engine.submit(p, max_tokens=12) for p in prompts]
    t0 = time.time()
    done = engine.run()
    wall = time.time() - t0
    assert len(done) == len(prompts)
    toks = sum(len(r.out_tokens) for r in done)
    ftl = sum(r.t_first - r.t_submit for r in reqs) / len(reqs)
    print(f"  {type(engine).__name__:12s}: {toks} tokens in {wall:.2f}s "
          f"({toks / wall:.1f} tok/s, mean first-token {ftl * 1e3:.0f}ms)")
    return [r.out_tokens for r in reqs]


def main():
    cfg = get_config("tinyllama-1.1b", reduced=True).replace(
        compute_dtype="float32", param_dtype="float32")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = [[1 + i, 2, 3 + i] + list(range(4, 4 + i)) for i in range(8)]

    print(f"serving {len(prompts)} requests on 3 slots (CPU):")
    ring_out = serve(Engine(model, params, slots=3, max_len=96), prompts)
    paged_out = serve(PagedEngine(model, params, slots=3, max_len=96,
                                  block_size=8, prefill_batch=2,
                                  prefill_chunk=8), prompts)
    assert ring_out == paged_out, "paged outputs diverged from ring reference"
    for rid, out in enumerate(ring_out[:4]):
        print(f"  req {rid}: prompt_len={len(prompts[rid])} -> {out}")
    print("OK (ring and paged token-identical)")


if __name__ == "__main__":
    main()

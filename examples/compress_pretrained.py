"""The paper's pipeline end-to-end at laptop scale (§II + §V.A + §V.C):

  1. pretrain a small *dense* LM,
  2. TT-SVD-compress its linears (attn-O + MLP, paper recipe) + the
     embedding table (TensorGPT-style vocab-axis TT) + int4-quantize the
     rest,
  3. print the Table-I-style CR report,
  4. evaluate perplexity before/after, with a short core fine-tune,
  5. checkpoint the compressed tree *with its target cfg*, load it back,
     and serve it through the unified engine (the compression → serving
     handoff, DESIGN.md §11).

    PYTHONPATH=src python examples/compress_pretrained.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import QuantConfig, TrainConfig, TTDConfig
from repro.configs import get_config
from repro.core.compress import (
    compress_model,
    compression_report,
    load_compressed,
    save_compressed,
    validate_compressed_params,
)
from repro.data.pipeline import DataConfig, make_source
from repro.models import build_model
from repro.serve.engine import Engine
from repro.train.losses import chunked_cross_entropy
from repro.train.step import build_train_step, init_train_state


def eval_ppl(model, params, src, steps=6):
    tot = cnt = 0.0
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in src.batch(10_000 + i).items()}
        hidden, _ = model.forward(params, b)
        _, m = chunked_cross_entropy(hidden, model.head_weight(params),
                                     b["targets"], b["loss_mask"])
        tot += float(m["ce"]) * float(m["tokens"])
        cnt += float(m["tokens"])
    return float(np.exp(tot / cnt))


def main():
    cfg_d = get_config("llama2-7b", reduced=True).replace(
        compute_dtype="float32", param_dtype="float32",
        ttd=TTDConfig(enabled=False), quant=QuantConfig(enabled=False))
    model_d = build_model(cfg_d)
    tc = TrainConfig(global_batch=8, seq_len=64, lr=3e-3, warmup_steps=10,
                     total_steps=150, optimizer="adamw", remat="none")
    state = init_train_state(model_d, tc, jax.random.PRNGKey(0))
    step = jax.jit(build_train_step(model_d, tc))
    src = make_source(DataConfig(vocab_size=cfg_d.vocab_size, seq_len=64,
                                 global_batch=8, seed=0))
    print("pretraining dense model (150 steps)…")
    for i in range(150):
        state, m = step(state, {k: jnp.asarray(v) for k, v in src.batch(i).items()})
    print(f"  final train loss {float(m['loss']):.3f}")
    base_ppl = eval_ppl(model_d, state.params, src)

    # --- the paper's compression recipe (+ TensorGPT TT embedding) ---
    cfg_t = cfg_d.replace(ttd=TTDConfig(enabled=True, rank=8, d=3, embed=True),
                          quant=QuantConfig(enabled=True, group_size=32))
    model_t = build_model(cfg_t)
    params_t = compress_model(state.params, cfg_d, cfg_t, svd_method="svd")

    rep = compression_report(cfg_t)
    print(f"\nCR report (paper Table I analogue for {cfg_t.name} reduced):")
    for r in rep.roles:
        print(f"  {r.role:8s} {r.kind:5s} {r.n_in}x{r.n_out:<6d} CR={r.cr:8.2f}")
    print(f"  block CR {rep.block_cr:.2f}  network CR {rep.network_cr:.2f} "
          f"(+embed: {rep.network_cr_with_embed:.2f}, bits: {rep.network_cr_bits:.2f})")

    ppl_t = eval_ppl(model_t, params_t, src)
    print(f"\nPPL: dense {base_ppl:.2f} -> compressed {ppl_t:.2f}")

    n_dense = sum(x.size for x in jax.tree.leaves(state.params))
    n_tt = sum(x.size for x in jax.tree.leaves(params_t))
    print(f"param count: {n_dense:,} -> {n_tt:,} "
          f"({n_dense / n_tt:.2f}x fewer numbers incl. int4 packing)")

    # --- compression → serving handoff: the target cfg rides the ckpt ---
    with tempfile.TemporaryDirectory() as ckpt_dir:
        save_compressed(ckpt_dir, params_t, cfg_t)
        params_s, cfg_s = load_compressed(ckpt_dir)
        assert cfg_s == cfg_t  # the tree is only meaningful with *this* cfg
        try:  # validating against the dense cfg names the offending leaves
            validate_compressed_params(cfg_d, params_s)
        except ValueError as e:
            print(f"\nmismatch detection: {str(e).splitlines()[0]}")
        eng = Engine(build_model(cfg_s), params=params_s, slots=2, max_len=64,
                     prefill_chunk=8)
        for i in range(3):
            eng.submit([1 + i, 2, 3, 4 + i], max_tokens=6)
        done = eng.run()
        print("served compressed checkpoint:",
              [r.out_tokens for r in done])
    print("OK")


if __name__ == "__main__":
    main()
